"""CI benchmark regression gate: current BENCH JSON vs committed baselines.

    python scripts/bench_compare.py --results $BENCH_DIR \
        [--baselines benchmarks/baselines] [--threshold 0.30]

For every baseline file `benchmarks/baselines/<name>.json` that has a
matching `<name>.json` in --results, the comparable metrics are checked:

* serve_throughput_*:  engine.agg_tok_s      (higher is better)
* serve_latency_*:     overlap.stream_tok_s  (higher is better)
* pipeline_overhead:   decode.fused_tok_s    (higher is better, if present)
* spec_decode*:        spec_decode.tokens_per_dispatch (higher is better;
                       deterministic for the same-config-draft smoke row)

The job FAILS (exit 1) when a current metric drops more than
`--threshold` (default 30%) below its committed baseline -- the AutoDSE
lesson applied to CI: regressions are caught by stored measurements, not
eyeballed.  Missing counterparts (a benchmark not run in this job, a new
benchmark without a baseline yet) are reported and skipped, never failed.
A DAMAGED payload, on the other hand, fails loudly with a one-line
diagnostic (never a traceback): an unreadable/corrupt JSON file or a
zero/negative metric value would otherwise make the gate vacuous -- a
zero baseline accepts any regression, a zero candidate is a broken run,
and a traceback buries which file was at fault.
Absolute smoke throughput is host-dependent, so payloads carry a
`host_class` stamp (benchmarks/common.py) and a baseline recorded on a
DIFFERENT host class is warned about and skipped, never compared; refresh
benchmarks/baselines/ from the CI artifact of the runner class the gate
should bind to.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _metric(name: str, payload: dict):
    """(dotted path, value) for the file's comparable metric, or None."""
    if name.startswith("serve_throughput"):
        try:
            return "engine.agg_tok_s", float(payload["engine"]["agg_tok_s"])
        except (KeyError, TypeError, ValueError):
            return None
    if name.startswith("serve_latency"):
        try:
            return ("overlap.stream_tok_s",
                    float(payload["overlap"]["stream_tok_s"]))
        except (KeyError, TypeError, ValueError):
            return None
    if name.startswith("pipeline_overhead"):
        try:
            return ("decode.fused_tok_s",
                    float(payload["decode"]["fused_tok_s"]))
        except (KeyError, TypeError, ValueError):
            return None
    if name.startswith("spec_decode"):
        # tokens emitted per target dispatch is DETERMINISTIC for the
        # same-config-draft smoke row (acceptance is a pure function of
        # seed/rid/prefix, never of host speed), so its baseline omits
        # the host_class stamp and the gate arms on every runner
        try:
            return ("spec_decode.tokens_per_dispatch",
                    float(payload["spec_decode"]["tokens_per_dispatch"]))
        except (KeyError, TypeError, ValueError):
            return None
    return None


def _load_payload(path: pathlib.Path, role: str):
    """(payload, None) or (None, one-line diagnostic) -- never raises."""
    try:
        payload = json.loads(path.read_text())
    except OSError as e:
        return None, f"BAD {path.stem}: unreadable {role} {path}: {e}"
    except ValueError as e:
        return None, f"BAD {path.stem}: corrupt {role} JSON {path}: {e}"
    if not isinstance(payload, dict):
        return None, (f"BAD {path.stem}: {role} {path} is not a JSON "
                      f"object (got {type(payload).__name__})")
    return payload, None


def compare(baselines: pathlib.Path, results: pathlib.Path,
            threshold: float) -> int:
    if not baselines.is_dir():
        print(f"bench_compare: baselines directory {baselines} does not "
              f"exist")
        return 1
    if not results.is_dir():
        print(f"bench_compare: results directory {results} does not exist "
              f"(did the benchmark step run / export $BENCH_DIR?)")
        return 1
    failures = []
    checked = skipped = 0
    for base_file in sorted(baselines.glob("*.json")):
        name = base_file.stem
        cur_file = results / base_file.name
        if not cur_file.exists():
            print(f"SKIP {name}: no result file in this job")
            skipped += 1
            continue
        base_payload, err = _load_payload(base_file, "baseline")
        if err is None:
            cur_payload, err = _load_payload(cur_file, "candidate")
        if err is not None:
            print(err)
            failures.append(name)
            continue
        bhost = base_payload.get("host_class")
        chost = cur_payload.get("host_class")
        if bhost and chost and bhost != chost:
            # absolute smoke throughput is host-bound: comparing across
            # runner classes would gate on hardware, not code.  Baselines
            # without the stamp (pre-host-class files) still compare.
            print(f"SKIP {name}: host-class mismatch -- baseline "
                  f"{bhost} vs current {chost}; refresh the baseline "
                  f"from this runner class to re-arm the gate")
            skipped += 1
            continue
        base = _metric(name, base_payload)
        cur = _metric(name, cur_payload)
        if base is None or cur is None:
            print(f"SKIP {name}: no comparable metric")
            skipped += 1
            continue
        path, base_v = base
        _, cur_v = cur
        bad_vals = [f"baseline {path}={base_v}" if base_v <= 0 else None,
                    f"candidate {path}={cur_v}" if cur_v <= 0 else None]
        bad_vals = [b for b in bad_vals if b]
        if bad_vals:
            # a zero/negative baseline makes the floor vacuous; a
            # zero/negative candidate is a broken benchmark run
            print(f"BAD {name}: non-positive metric "
                  f"({'; '.join(bad_vals)}) -- gate cannot arm")
            failures.append(name)
            continue
        floor = base_v * (1.0 - threshold)
        status = "OK" if cur_v >= floor else "FAIL"
        print(f"{status} {name}: {path} current={cur_v:.1f} "
              f"baseline={base_v:.1f} floor={floor:.1f}")
        checked += 1
        if cur_v < floor:
            failures.append(name)
    print(f"bench_compare: {checked} checked, {skipped} skipped, "
          f"{len(failures)} failed (threshold {threshold:.0%})")
    if failures:
        print("failed benchmarks:", ", ".join(failures))
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", required=True,
                    help="directory of this job's BENCH JSON files "
                         "($BENCH_DIR)")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed baseline JSON files")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="maximum tolerated fractional regression "
                         "(default 0.30 = 30%%)")
    args = ap.parse_args()
    return compare(pathlib.Path(args.baselines), pathlib.Path(args.results),
                   args.threshold)


if __name__ == "__main__":
    sys.exit(main())
