"""Render dry-run/roofline/perf tables into EXPERIMENTS.md from the JSON
results (idempotent: replaces the marker sections)."""
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    p = os.path.join(ROOT, path)
    return json.load(open(p)) if os.path.exists(p) else []


def fmt_bytes_gb(x):
    return f"{x:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile s | args GB/chip | temp GB/chip "
           "| HLO GFLOP/chip | coll GB/chip | while-trips |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("variants") or r.get("quant"):
            continue
        if r.get("status") != "run":
            reason = r["status"].split(":", 1)[1].strip()
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP — {reason} ||||||")
            continue
        m, h = r["memory"], r["hlo"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']} | {m['args_gb']:.2f} | {m['temp_gb']:.2f} | "
            f"{h['dot_flops_per_chip'] / 1e9:.1f} | "
            f"{h['coll_bytes_per_chip'] / 2**30:.2f} | "
            f"{'×'.join(str(t) for t in h['trip_counts']) or '-'} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful-FLOPs ratio | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        ("moe", "train"): "grouped (shard-local) MoE dispatch kills the "
                          "cross-shard sort/gather traffic (see §Perf A)",
        ("moe", "prefill"): "grouped MoE dispatch (§Perf A applies equally)",
        ("moe", "decode"): "KV-cache model-axis sharding + int8 KV (§Perf C)",
        ("dense", "train"): "chunked attention bounds score traffic; FSDP "
                            "prefetch overlap",
        ("dense", "prefill"): "chunked attention (§Perf bonus: −75 % memory "
                              "term on command-r)",
        ("dense", "decode"): "KV model-axis sharding, int8 KV, w4a8 weights "
                             "(§Perf C)",
        ("ssm", "train"): "larger SSD chunk = fewer scan steps; state in "
                          "VMEM via Pallas scan fusion",
        ("ssm", "prefill"): "same as train; chunk 256→512 halves scan "
                            "overhead",
        ("ssm", "decode"): "state + weights are tiny: batch up decode "
                           "requests",
        ("hybrid", "train"): "grouped MoE dispatch + SSD chunk tuning",
        ("hybrid", "prefill"): "grouped MoE dispatch",
        ("hybrid", "decode"): "KV sharding for the 4 attention layers",
        ("encdec", "train"): "chunked cross/self attention",
        ("encdec", "prefill"): "chunked encoder attention",
        ("encdec", "decode"): "cross-KV is static: precompute + int8",
        ("vlm", "train"): "chunked attention + FSDP prefetch",
        ("vlm", "prefill"): "chunked attention",
        ("vlm", "decode"): "KV model-axis sharding + int8 KV",
    }
    fam = {r["arch"]: None for r in rows}
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro import configs
    for a in list(fam):
        fam[a] = configs.get_config(a).family
    kind = {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("variants") or r.get("quant"):
            continue
        if r.get("status") != "run":
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        f = fam[r["arch"]]
        note = notes.get((f if f != "moe" else "moe", kind[r["shape"]]),
                         notes.get((f, kind[r["shape"]]), ""))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | {t['dominant'].replace('_s','')} | "
            f"{ratio:.4f} | {note} |")
    return "\n".join(out)


def main():
    base = load("results/dryrun_baseline.json")
    md_path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(md_path).read()
    md = re.sub(r"<!-- DRYRUN_TABLE -->.*?(?=\n## |$)",
                "<!-- DRYRUN_TABLE -->\n\n" + dryrun_table(base) + "\n\n",
                md, flags=re.S)
    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |$)",
                "<!-- ROOFLINE_TABLE -->\n\n" + roofline_table(base) + "\n\n",
                md, flags=re.S)
    open(md_path, "w").write(md)
    print("rendered EXPERIMENTS.md tables")


if __name__ == "__main__":
    main()
