"""End-to-end training example: SmolLM-135M (the full assigned config, a
~135M-parameter llama-family model) for a few hundred steps on the synthetic
pipeline, with checkpoint/restart and straggler telemetry enabled.

    PYTHONPATH=src python examples/train_lm.py                # full 135M run
    PYTHONPATH=src python examples/train_lm.py --smoke        # seconds-scale

This is a thin veneer over the production driver (repro.launch.train); on a
real TPU pod the same driver runs with --mesh 16x16.
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + few steps (CI-friendly)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ns = ap.parse_args()

    args = argparse.Namespace(
        arch="smollm-135m",
        reduced=ns.smoke,
        steps=ns.steps or (30 if ns.smoke else 300),
        batch=2 if ns.smoke else 4,
        seq=64 if ns.smoke else 256,
        lr=3e-4, microbatches=1, mesh="1x1", seed=0,
        ckpt_dir=ns.ckpt_dir,
        ckpt_every=10 if ns.smoke else 50,
        log_every=5 if ns.smoke else 10,
        simulate_failures="", max_restarts=3, sim_hosts=4)
    out = train_mod.run(args)
    print("history:", [round(x, 3) for x in out["history"]])


if __name__ == "__main__":
    main()
