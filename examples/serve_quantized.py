"""Quantized serving example: batched greedy decoding with w4a8 packed
weights (two int4 per int8 word -- the paper's packing insight applied to
the HBM-bound decode path) and the SILVIA passes enabled on the decode
step function.

    PYTHONPATH=src python examples/serve_quantized.py
    PYTHONPATH=src python examples/serve_quantized.py --arch qwen1.5-0.5b
"""
import argparse
import sys

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--quant", default="w4a8",
                    choices=["bf16", "w8a8", "w4a8"])
    ap.add_argument("--full", action="store_true",
                    help="full config instead of the reduced smoke config")
    ns = ap.parse_args()

    sys.argv = ["serve",
                "--arch", ns.arch,
                "--quant", ns.quant,
                "--silvia", "all",
                "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    if not ns.full:
        sys.argv.append("--reduced")
    serve_mod.main()


if __name__ == "__main__":
    main()
