"""Continuous-batching engine example: ragged synthetic traffic served
through bucketed, segmented fused decode (launch/engine.py), printing
per-request latency and the compiled-graph census.

Any registered slot-state family serves through the same engine
(models/slot_state.py): pass --arch mamba2-2.7b (pure SSM --
constant-size pages, no length bucketing) or --arch jamba-v0.1-52b
(hybrid mamba+attention+MoE pages).

    PYTHONPATH=src python examples/serve_engine.py
    PYTHONPATH=src python examples/serve_engine.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_engine.py --silvia all --chunked
    PYTHONPATH=src python examples/serve_engine.py --chaos segment:2

With --chaos (a $REPRO_CHAOS-style schedule), dispatches fail mid-run
and the engine recovers by re-prefill + bit-exact replay; the printed
robustness counters show what happened (launch/resilience.py).
"""
import argparse

import jax

from repro import configs
from repro.launch import resilience, scheduler
from repro.launch.engine import ServeEngine
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--silvia", default="off",
                    choices=["off", "add", "muladd", "all"])
    ap.add_argument("--chunked", action="store_true",
                    help="prefill prompts through the decode path, 8 "
                         "tokens per dispatch")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject faults, e.g. 'segment:2' or "
                         "'rate=0.1,seed=3,max=2'")
    ns = ap.parse_args()

    cfg = configs.get_reduced_config(ns.arch)
    params = quantize_tree_for_serving(
        lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=136), "w8a8")
    eng = ServeEngine(params, cfg, n_slots=4, max_cache_len=128,
                      segment_len=8, silvia_passes=ns.silvia,
                      prefill_chunk=8 if ns.chunked else None,
                      chaos=resilience.ChaosSchedule.parse(ns.chaos)
                      if ns.chaos else "env")
    traffic = scheduler.synthetic_traffic(
        seed=0, n_requests=ns.n_requests, rate=25.0,
        prompt_lens=(8, 16, 32), gen_lens=(4, 8, 16), vocab=cfg.vocab)
    eng.warmup(prompt_lens=sorted({r.prompt_len for r in traffic}))

    out = eng.run(traffic, clock=scheduler.FastForwardClock())
    for r in eng.finished:
        print(f"req {r.rid:2d}  prompt {r.prompt_len:3d}  "
              f"gen {r.max_new_tokens:3d}  latency {r.latency():6.3f}s  "
              f"tokens {out[r.rid][:6].tolist()}...")
    info = eng.cache_info()
    print(f"\nfamily {info['family']} "
          f"(length axis: {info['has_length_axis']}); "
          f"compiled graphs: {info['graphs']} "
          f"(bound {info['graph_bound']}); "
          f"batch buckets {info['batch_buckets']}, "
          f"len buckets {info['len_buckets']}; "
          f"compactions {info['compactions']}")
    outcomes: dict = {}
    for r in eng.finished:
        outcomes[r.outcome] = outcomes.get(r.outcome, 0) + 1
    hot = {k: v for k, v in info["robustness"].items() if v}
    print(f"outcomes: {outcomes}; robustness counters: {hot or 'all zero'}")
    from repro.kernels import registry
    print("active lowerings:",
          registry.census_str(),
          "(force via REPRO_LOWERING=<op>=<id>,... or '*=<id>')")


if __name__ == "__main__":
    main()
