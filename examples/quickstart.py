"""Quickstart: the paper's Fig. 1 running example on the SILVIA-for-JAX flow.

    PYTHONPATH=src python examples/quickstart.py

Two 8-bit multiplications sharing an operand are written naively; the
SILVIA pass discovers the superword-level parallelism and packs them into a
single `silvia_packed_muladd` unit (one i32 multiply lane on TPU = one DSP
on the paper's FPGA).  No change to the "source" function.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as silvia
from repro.core import opcount


def fig1(a0, a1, b):
    """c[i] = a[i] * b  -- the unrolled loop body of paper Fig. 1a."""
    c0 = a0.astype(jnp.int32) * b.astype(jnp.int32)
    c1 = a1.astype(jnp.int32) * b.astype(jnp.int32)
    return c0, c1


def main():
    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.integers(-128, 128, (8,)), jnp.int8)
            for _ in range(3)]

    print("=== original jaxpr (two multiplies, Fig. 4a) ===")
    print(jax.make_jaxpr(fig1)(*args))

    stats = []
    packed = silvia.optimized_jaxpr(
        fig1, *args, passes=[silvia.PassConfig(op="muladd")], stats=stats)
    print("\n=== SILVIA-optimized jaxpr (one packed call, Fig. 4c) ===")
    print(packed)
    print("\npass stats:", stats)

    before = opcount.count_ops(jax.make_jaxpr(fig1)(*args))
    after = opcount.count_ops(packed)
    print(f"\nOps/Unit (paper Table 1 metric): "
          f"{before.mul_density:.2f} -> {after.mul_density:.2f}")

    fast = silvia.optimize(fig1, [silvia.PassConfig(op="muladd")])
    ok = all(bool((a == b).all())
             for a, b in zip(fast(*args), fig1(*args)))
    print("numerics identical:", ok)
    assert ok

    from repro.kernels import registry
    print("active lowerings:", registry.census_str(),
          "(the packed call above ran on its op's lowering; force with "
          "REPRO_LOWERING)")


if __name__ == "__main__":
    main()
