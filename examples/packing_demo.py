"""Deep-dive demo: every SILVIA pass + the Fig. 5 II edge-case analyzer.

    PYTHONPATH=src python examples/packing_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as silvia
from repro.core import ddg


def demo_add_packing(rng):
    print("=" * 70)
    print("SILVIAAdd: four int8 additions -> one four8 SWAR unit")

    def adds(xs, ys):
        return tuple(x + y for x, y in zip(xs, ys))

    xs = tuple(jnp.asarray(rng.integers(-128, 128, (16,)), jnp.int8)
               for _ in range(4))
    ys = tuple(jnp.asarray(rng.integers(-128, 128, (16,)), jnp.int8)
               for _ in range(4))
    print(silvia.optimized_jaxpr(adds, xs, ys,
                                 passes=[silvia.PassConfig(op="add",
                                                           op_size=8)]))


def demo_mad_chain(rng):
    print("=" * 70)
    print("SILVIAMuladd: two 4-leaf MAD trees -> packed chains + adder tree")
    print("(paper sec. 3.3: Eq. 2 bound splits the chain; external adds)")

    def trees(a, b, c):
        f = lambda x: x.astype(jnp.int32)
        ta = [f(a[i]) * f(c[i]) for i in range(4)]
        tb = [f(b[i]) * f(c[i]) for i in range(4)]
        return (ta[0] + ta[1]) + (ta[2] + ta[3]), \
               (tb[0] + tb[1]) + (tb[2] + tb[3])

    mk = lambda: tuple(jnp.asarray(rng.integers(-128, 128, (8,)), jnp.int8)
                       for _ in range(4))
    print(silvia.optimized_jaxpr(trees, mk(), mk(), mk(),
                                 passes=[silvia.PassConfig(op="muladd")]))


def demo_mul4(rng):
    print("=" * 70)
    print("SILVIAMul4: four 4-bit multiplications by a shared factor")

    def fn(a, b):
        f = lambda x: silvia.width_hint(x, 4).astype(jnp.int32)
        b4 = f(b)
        return tuple(f(a[i]) * b4 for i in range(4))

    a = tuple(jnp.asarray(rng.integers(-8, 8, (8,)), jnp.int8)
              for _ in range(4))
    b = jnp.asarray(rng.integers(-8, 8, (8,)), jnp.int8)
    print(silvia.optimized_jaxpr(fn, a, b,
                                 passes=[silvia.PassConfig(op="mul4")]))


def demo_fig5_ii():
    print("=" * 70)
    print("Fig. 5 edge case: packing that raises the initiation interval")
    lat = [1, 1, 1, 1]
    edges = [(0, 2, 0), (2, 3, 0), (1, 3, 0), (3, 1, 1)]
    g = ddg.ddg_from_edges(lat, edges)
    print(f"II_min original: {g.ii_min()}")
    print(f"II_min after packing {{a, b}}: {g.with_merged([0, 1]).ii_min()}")
    print(f"would_increase_ii -> {ddg.would_increase_ii(g, [0, 1])} "
          "(the conservative filter the paper leaves to future work)")


if __name__ == "__main__":
    rng = np.random.default_rng(0)
    demo_add_packing(rng)
    demo_mad_chain(rng)
    demo_mul4(rng)
    demo_fig5_ii()
