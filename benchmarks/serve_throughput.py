"""Continuous-batching engine vs static batched generate() under ragged
synthetic traffic (Poisson arrivals, mixed prompt/gen lengths).

The engine packs an ever-changing request mix into bucketed compiled decode
segments (launch/engine.py); the static path forms fixed batches in arrival
order, waits for each batch to fill, pads prompts/gens to the batch max,
and pays one compiled graph per distinct batch shape.  The gap between the
two is the serving analogue of the DSP under-utilization the paper's passes
reclaim.

`--family {dense,ssm,hybrid,encdec}` picks the model family served through
the SAME engine (the slot-state registry, models/slot_state.py); ssm/hybrid
rows demonstrate the family-agnostic slot layer (ssm: constant-size pages,
batch-bucket-only graph growth); encdec rows carry per-request encoder
features through the same segment loop.

`--mesh DxM` (e.g. `--mesh 8x1`, `--mesh 2x4`; a bare `8` means `8x1`)
serves the ENGINE row on a ("data", "model") mesh via the sharded
shard_map bundles (DESIGN.md sec. 7) -- on CI this runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8.  The static row stays
single-device, so the speedup column also reflects the device-packing win;
outputs remain bit-identical either way (tests/test_sharded_serve.py).

Emits one machine-readable line:  BENCH {json}  with the family, aggregate
tok/s, p50/p99 per-request latency, mean slot occupancy, compiled-graph
counts (the engine's is bounded by its bucket sets), the **active lowering
census** {op: lowering id} from kernels/registry.py, the packed-op
dispatch census (nonzero: the quantized path really bound packed matmuls),
and the mesh layout when sharded.  With $BENCH_DIR set the payload is also
written to $BENCH_DIR/serve_throughput_<family>[_<mesh>].json for the CI
artifact + scripts/bench_compare.py regression gate.

`--chaos [SPEC]` serves the engine row under an injected-fault schedule
(launch/resilience.py ChaosSchedule; default spec exercises a few
deterministic seeded faults) plus a TTL mix on the traffic, and reports
the robustness counters: shed/expired/recovered requests, replayed
tokens, and `recovery_overhead` (replayed / delivered tokens -- the cost
of bit-exact recovery-as-replay).  The BENCH file gains a `_chaos`
suffix so the regression gate tracks chaos throughput separately.

`--device-loss [SPEC]` (requires `--mesh`) additionally KILLS devices
mid-run (distributed/elastic.py DeviceLossInjector; the default arm
loses half the mesh at the second decode segment) and reports the
elastic-serving metrics: degradation count, re-shard latency
(`reshard_s`), the final degraded mesh shape, and `post_shrink_tok_s`
(throughput after the last degrade -- what the shrunken mesh sustains).
Streams stay bit-identical throughout (tests/test_elastic.py).  The
BENCH file gains an `_elastic` suffix so the gate tracks degraded-mesh
throughput against its own baseline.

`--prefix-reuse` swaps the Poisson traffic for zipfian shared-prefix
traffic (scheduler.shared_prefix_traffic: a few hot system-prompt-style
prefixes dominate, fresh random tails) and serves it TWICE -- once with
the cross-request prefix cache on (launch/prefix_cache.py; this is the
gated `engine` row) and once cold (`engine_cold`).  The `prefix` block
reports the cache hit rate, prefill tokens skipped, warm-vs-cold p50
TTFT, and `bit_exact` (the warm token streams must equal the cold ones
byte for byte -- the pool's correctness bar).  The BENCH file gains a
`_prefix` suffix so the gate tracks warm throughput against its own
baseline.  Composes with `--chaos` and `--mesh`.  `--admit-budget N`
additionally caps uncached prefill tokens per admission round (the
fairness dial; deferral counts land in the engine row).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]
        [--family {dense,ssm,hybrid,encdec}] [--silvia {off,add,muladd,all}]
        [--mesh DxM] [--chaos [SPEC]] [--device-loss [SPEC]]
        [--prefix-reuse] [--admit-budget N] [--n-requests N] [--rate R]
"""
from __future__ import annotations

import argparse
import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import configs
from repro.distributed import context as dctx
from repro.distributed import elastic
from repro.kernels import registry
from repro.launch import resilience, scheduler, serve
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def _percentiles(latencies) -> dict:
    lat = np.asarray(sorted(latencies))
    return {"p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2)}


def _summary(requests, elapsed: float) -> dict:
    useful = sum(r.max_new_tokens for r in requests)
    return {
        "requests": len(requests),
        "useful_tokens": useful,
        "elapsed_s": round(elapsed, 3),
        "agg_tok_s": round(useful / max(elapsed, 1e-9), 1),
        **_percentiles([r.latency() for r in requests]),
    }


def parse_mesh(spec: str):
    """"8x1" / "2x4" -> (data, model); a bare "8" means data-only."""
    parts = spec.lower().split("x")
    if len(parts) == 1:
        parts = [parts[0], "1"]
    if len(parts) != 2 or not all(p.isdigit() for p in parts):
        raise ValueError(f"--mesh wants DxM (e.g. 2x4), got {spec!r}")
    return int(parts[0]), int(parts[1])


def run_engine(params, cfg, requests, *, n_slots, max_cache_len,
               segment_len, silvia_passes, prefill_chunk=None,
               enc_len=None, mesh=None, warmup=True, chaos=None,
               prefix_cache=None, admit_token_budget=None,
               return_tokens=False):
    kw = {"enc_len": enc_len} if enc_len is not None else {}
    scope = contextlib.nullcontext()
    if mesh is not None:
        mesh_obj = make_mesh(tuple(mesh), ("data", "model"))
        scope = dctx.mesh_scope(mesh_obj, ("data",), "model")
    with scope:
        eng = ServeEngine(params, cfg, n_slots=n_slots,
                          max_cache_len=max_cache_len,
                          segment_len=segment_len,
                          silvia_passes=silvia_passes,
                          prefill_chunk=prefill_chunk,
                          prefix_cache=prefix_cache,
                          admit_token_budget=admit_token_budget,
                          chaos=chaos if chaos is not None else "env", **kw)
    if warmup:
        # startup pre-compilation over the advertised traffic profile --
        # the static path below gets the matching per-shape warm pass
        eng.warmup(prompt_lens=sorted({r.prompt_len for r in requests}))
    clock = scheduler.FastForwardClock()
    t0 = clock.now()
    eng.run(requests, clock)
    end = clock.now()
    elapsed = end - t0
    info = eng.cache_info()
    out = _summary(eng.finished, elapsed)
    out["mean_occupancy"] = round(float(np.mean(eng.occupancy)), 3) \
        if eng.occupancy else 0.0
    ttfts = [r.first_token_time - r.arrival_time for r in eng.finished
             if r.first_token_time is not None]
    out["ttft_p50_ms"] = round(float(np.percentile(ttfts, 50)) * 1e3, 2) \
        if ttfts else None
    out["graphs"] = info["graphs"]
    out["graph_bound"] = info["graph_bound"]
    out["graph_keys"] = [" ".join(map(str, k)) for k in info["graph_keys"]]
    out["has_length_axis"] = info["has_length_axis"]
    out["compactions"] = info["compactions"]
    out["lowerings"] = info["lowerings"]
    if "prefix_cache" in info:
        out["prefix_cache"] = info["prefix_cache"]
    if admit_token_budget is not None:
        out["admission"] = info["admission"]
    if "mesh" in info:
        out["mesh"] = info["mesh"]
    if "silvia" in info:
        out["silvia_trace"] = {k: info["silvia"][k]
                               for k in ("trace_hits", "trace_misses")}
    if chaos is not None:
        rb = info["robustness"]
        delivered = sum(len(r.tokens) for r in eng.finished)
        outcomes: dict = {}
        for r in eng.finished:
            outcomes[r.outcome] = outcomes.get(r.outcome, 0) + 1
        out["robustness"] = rb
        out["outcomes"] = outcomes
        out["delivered_tokens"] = delivered
        out["shed_rate"] = round(
            rb["shed"] / max(len(eng.finished), 1), 3)
        # cost of bit-exact recovery-as-replay: tokens regenerated with
        # teacher forcing per token actually delivered
        out["recovery_overhead"] = round(
            rb["replayed_tokens"] / max(delivered, 1), 3)
    degrade_at = out.get("mesh", {}).get("degrade_at", [])
    if degrade_at:
        # throughput the SHRUNKEN mesh sustained: tokens delivered after
        # the last degrade over the remaining serving time
        t_d = max(degrade_at)
        post = sum(len(r.tokens) for r in eng.finished
                   if r.finish_time is not None and r.finish_time >= t_d)
        out["post_shrink_tok_s"] = round(post / max(end - t_d, 1e-9), 1)
        out["degraded"] = info["mesh"]["degraded"]
        out["reshard_s"] = round(info["mesh"]["reshard_s"], 4)
        out["final_mesh"] = "x".join(
            str(v) for v in info["mesh"]["shape"].values())
    if return_tokens:
        return out, {r.rid: list(r.tokens) for r in eng.finished}
    return out


def run_static(params, cfg, requests, *, n_slots, silvia_passes,
               enc_len=None, warmup=True) -> dict:
    """PR-1 static path: batches of n_slots in arrival order; each batch
    waits until its last request arrives, pads every prompt/gen to the
    batch max, and decodes gen_max steps for every row."""
    encdec = cfg.family == "encdec"
    reqs = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    batches = [reqs[i:i + n_slots] for i in range(0, len(reqs), n_slots)]
    shapes = set()
    for batch in batches:
        pl = max(r.prompt_len for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        shapes.add((len(batch), pl, gen, pl + gen))

    def inputs_for(batch, pl):
        prompts = np.zeros((len(batch), pl), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :r.prompt_len] = r.prompt
        if not encdec:
            return jnp.asarray(prompts)
        feats = np.stack([np.asarray(r.features, np.float32)
                          for r in batch])
        return (jnp.asarray(feats).astype(jnp.dtype(cfg.dtype)),
                jnp.asarray(prompts))

    if warmup:
        for (b, pl, gen, cl) in sorted(shapes):
            prompts = jnp.zeros((b, pl), jnp.int32)
            if encdec:
                feats = jnp.zeros((b, enc_len, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
                prompts = (feats, prompts)
            jax.block_until_ready(serve.generate(
                params, prompts, cfg, gen=gen, cache_len=cl,
                silvia_passes=silvia_passes))
    clock = scheduler.FastForwardClock()
    t0 = clock.now()
    for batch in batches:
        clock.wait_until(max(r.arrival_time for r in batch))
        pl = max(r.prompt_len for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        toks = serve.generate(params, inputs_for(batch, pl), cfg, gen=gen,
                              cache_len=pl + gen,
                              silvia_passes=silvia_passes)
        toks = np.asarray(toks)
        done = clock.now()
        for i, r in enumerate(batch):
            r.tokens = [int(t) for t in toks[i, :r.max_new_tokens]]
            r.finish_time = done
    elapsed = clock.now() - t0
    out = _summary(reqs, elapsed)
    out["graphs"] = len(shapes)
    return out


FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}


# one pinned mid-run fault so even the tiny smoke trace exercises the
# recovery path, plus a seeded random schedule on top
DEFAULT_CHAOS = "segment:1,rate=0.04,seed=11,max=4"
# TTL mix for chaos rows: mostly deadline-free, a slice of generous TTLs
# so the deadline machinery runs without starving the throughput metric
CHAOS_TTLS = (None, None, None, 5.0)


def run(smoke: bool = False, silvia_passes: str = "off",
        n_requests: int | None = None, rate: float | None = None,
        family: str = "dense", mesh=None, chaos: str | None = None,
        device_loss: str | None = None, prefix_reuse: bool = False,
        admit_budget: int | None = None, trace_seed: int = 0) -> dict:
    arch = FAMILY_ARCHS[family]
    cfg = configs.get_reduced_config(arch)
    rate_arg = rate
    if smoke:
        n_req = n_requests or 8
        rate = rate or 50.0
        n_slots, seg, max_len = 2, 4, 64
        prompt_lens, gen_lens = (4, 8, 12), (2, 4, 8)
    else:
        n_req = n_requests or 32
        rate = rate or 20.0
        n_slots, seg, max_len = 4, 8, 128
        prompt_lens, gen_lens = (8, 16, 32, 48), (2, 8, 16, 32)
    if mesh is not None:
        # the slot axis must split over the data shards
        n_slots = max(n_slots, mesh[0])
    if device_loss is not None:
        if mesh is None:
            raise ValueError("--device-loss needs --mesh (there is no mesh "
                             "to shrink on a single device)")
        if device_loss == "auto":
            # lose half the mesh at the second decode segment
            device_loss = f"lose@segment:1={max(1, mesh[0] * mesh[1] // 2)}"
        chaos = device_loss if chaos is None else f"{chaos};{device_loss}"
    enc_len = None
    if family == "encdec":
        enc_len = 16 if smoke else 32
    # --prefix-reuse: zipfian shared-prefix traffic + chunked prefill for
    # chunkable families, so chain (per-chunk) sharing engages; others
    # share at exact-repeat (terminal) granularity
    pchunk = None
    if prefix_reuse:
        # denser trace + longer shared prefix than the plain rows: the
        # cache's win is queueing relief from skipped prefill chunks, so
        # the trace needs enough simultaneous arrivals (and enough shared
        # chunks per arrival) for the delta to clear run-to-run noise
        if smoke:
            n_prefixes, zipf_a, prefix_len, tail_lens = 3, 1.4, 32, (2, 6, 10)
            pchunk = 8 if family == "dense" else None
            n_req = n_requests or 16
            rate = rate_arg or 200.0
        else:
            n_prefixes, zipf_a, prefix_len, tail_lens = 4, 1.4, 64, (4, 8, 16)
            pchunk = 16 if family == "dense" else None
            n_req = n_requests or 48
            rate = rate_arg or 100.0
    rng = jax.random.PRNGKey(0)
    registry.reset_dispatch_counts()
    # force=True: reduced-config weights all sit under the production
    # quantization floors -- without it these "quantized" rows serve
    # bf16 graphs with zero packed-matmul dispatches (ROADMAP no-op)
    params = quantize_tree_for_serving(
        lm.init_params(rng, cfg, max_seq=max_len + 8), "w8a8", force=True)

    def traffic():
        if prefix_reuse:
            reqs = scheduler.shared_prefix_traffic(
                seed=trace_seed, n_requests=n_req, rate=rate,
                n_prefixes=n_prefixes, prefix_len=prefix_len,
                tail_lens=tail_lens, gen_lens=gen_lens, vocab=cfg.vocab,
                zipf_a=zipf_a,
                ttls=CHAOS_TTLS if chaos is not None else None)
        else:
            reqs = scheduler.synthetic_traffic(
                seed=trace_seed, n_requests=n_req, rate=rate,
                prompt_lens=prompt_lens, gen_lens=gen_lens, vocab=cfg.vocab,
                ttls=CHAOS_TTLS if chaos is not None else None)
        if family == "encdec":
            frng = np.random.default_rng(1)
            if prefix_reuse:
                # a small feature pool (assigned by rid) so exact repeats
                # can terminal-hit -- the features digest is part of the
                # pool key, fresh-noise features would force all-miss
                pool = [frng.standard_normal(
                    (enc_len, cfg.d_model)).astype(np.float32)
                    for _ in range(2)]
                for r in reqs:
                    r.features = pool[r.rid % 2]
            else:
                for r in reqs:
                    r.features = frng.standard_normal(
                        (enc_len, cfg.d_model)).astype(np.float32)
        return reqs

    def chaos_obj():
        # a fresh stateful schedule per engine run (fired-site bookkeeping
        # must not leak from the warm run into the cold one)
        if chaos is None:
            return None
        if "lose" in chaos:
            return elastic.DeviceLossInjector.parse(chaos)
        return resilience.ChaosSchedule.parse(chaos)

    result = {
        "config": {"arch": f"{arch}(reduced)", "family": family,
                   "n_requests": n_req,
                   "rate_req_s": rate, "n_slots": n_slots,
                   "segment_len": seg, "max_cache_len": max_len,
                   "prompt_lens": list(prompt_lens),
                   "gen_lens": list(gen_lens), "quant": "w8a8(forced)",
                   "silvia": silvia_passes, "enc_len": enc_len,
                   "mesh": None if mesh is None else f"{mesh[0]}x{mesh[1]}",
                   "chaos": chaos, "device_loss": device_loss,
                   "prefix_reuse": prefix_reuse,
                   "prefill_chunk": pchunk,
                   "admit_budget": admit_budget,
                   "devices": jax.device_count(),
                   "backend": jax.default_backend(),
                   "lowerings": registry.active_lowerings()},
    }
    if prefix_reuse:
        result["config"]["prefix_traffic"] = {
            "n_prefixes": n_prefixes, "prefix_len": prefix_len,
            "tail_lens": list(tail_lens), "zipf_a": zipf_a}
    engine_kw = dict(n_slots=n_slots, max_cache_len=max_len,
                     segment_len=seg, silvia_passes=silvia_passes,
                     enc_len=enc_len, mesh=mesh, prefill_chunk=pchunk,
                     admit_token_budget=admit_budget)
    if prefix_reuse:
        # the gated `engine` row is the WARM (pool-backed) run; the cold
        # run rides along for the TTFT delta and the bit-exactness bar
        warm, warm_toks = run_engine(params, cfg, traffic(),
                                     prefix_cache=256, chaos=chaos_obj(),
                                     return_tokens=True, **engine_kw)
        cold, cold_toks = run_engine(params, cfg, traffic(),
                                     chaos=chaos_obj(),
                                     return_tokens=True, **engine_kw)
        result["engine"] = warm
        result["engine_cold"] = cold
        result["prefix"] = {
            "hit_rate": warm["prefix_cache"]["hit_rate"],
            "prefill_tokens_skipped": warm["prefix_cache"]["tokens_skipped"],
            "pages_resident": warm["prefix_cache"]["pages_resident"],
            "pages_evicted": warm["prefix_cache"]["pages_evicted"],
            "ttft_warm_ms": warm["ttft_p50_ms"],
            "ttft_cold_ms": cold["ttft_p50_ms"],
            "bit_exact": (set(warm_toks) == set(cold_toks)
                          and all(warm_toks[k] == cold_toks[k]
                                  for k in warm_toks)),
        }
    else:
        result["engine"] = run_engine(params, cfg, traffic(),
                                      chaos=chaos_obj(), **engine_kw)
    result["static"] = run_static(params, cfg, traffic(), n_slots=n_slots,
                                  silvia_passes=silvia_passes,
                                  enc_len=enc_len)
    result["speedup_tok_s"] = round(
        result["engine"]["agg_tok_s"]
        / max(result["static"]["agg_tok_s"], 1e-9), 2)
    result["graphs_bounded"] = (result["engine"]["graphs"]
                                <= result["engine"]["graph_bound"])
    # packed-op dispatch census: nonzero quant_matmul proves the forced
    # quantization actually bound packed GEMMs into the compiled graphs
    result["packed_dispatches"] = registry.dispatch_counts()
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/traffic (CI)")
    ap.add_argument("--family", default="dense",
                    choices=sorted(FAMILY_ARCHS),
                    help="model family served through the engine's "
                         "slot-state registry")
    ap.add_argument("--silvia", default="off",
                    choices=list(serve.SILVIA_PASS_SETS))
    ap.add_argument("--mesh", default=None,
                    help="serve the engine row sharded over a DxM "
                         "(data, model) mesh, e.g. 8x1 or 2x4 (needs that "
                         "many visible devices)")
    ap.add_argument("--chaos", nargs="?", const=DEFAULT_CHAOS, default=None,
                    metavar="SPEC",
                    help="serve the engine row under an injected-fault "
                         "schedule (resilience.ChaosSchedule syntax, e.g. "
                         "'segment:2;prefill:1' or 'rate=0.05,seed=3'); "
                         f"bare --chaos uses '{DEFAULT_CHAOS}'")
    ap.add_argument("--device-loss", nargs="?", const="auto", default=None,
                    metavar="SPEC",
                    help="kill mesh devices mid-run and serve on the "
                         "re-planned degraded mesh (DeviceLossInjector "
                         "syntax, e.g. 'lose@segment:1=4'); bare "
                         "--device-loss loses half the mesh at segment 1; "
                         "requires --mesh")
    ap.add_argument("--prefix-reuse", action="store_true",
                    help="zipfian shared-prefix traffic served warm (with "
                         "the cross-request prefix cache) AND cold; "
                         "reports hit rate, prefill tokens skipped, "
                         "warm/cold TTFT and bit-exactness")
    ap.add_argument("--admit-budget", type=int, default=None,
                    metavar="N",
                    help="cap uncached prefill tokens per admission round "
                         "(token-budget admission fairness; deferrals are "
                         "reported in the engine row)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="seed for the synthetic/shared-prefix traffic "
                         "trace (one knob for BOTH builders; baselines "
                         "use the default 0)")
    args = ap.parse_args()
    mesh = parse_mesh(args.mesh) if args.mesh else None
    if mesh is not None and mesh[0] * mesh[1] > jax.device_count():
        raise SystemExit(
            f"--mesh {args.mesh} needs {mesh[0] * mesh[1]} devices, have "
            f"{jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N to simulate)")
    if args.device_loss is not None and mesh is None:
        raise SystemExit("--device-loss needs --mesh (no mesh to shrink)")
    result = run(smoke=args.smoke, silvia_passes=args.silvia,
                 n_requests=args.n_requests, rate=args.rate,
                 family=args.family, mesh=mesh, chaos=args.chaos,
                 device_loss=args.device_loss,
                 prefix_reuse=args.prefix_reuse,
                 admit_budget=args.admit_budget,
                 trace_seed=args.trace_seed)
    print(json.dumps(result, indent=2))
    name = f"serve_throughput_{args.family}"
    if args.mesh:
        name += f"_{args.mesh}"
    if args.device_loss is not None:
        name += "_elastic"
    elif args.chaos is not None:
        name += "_chaos"
    if args.prefix_reuse:
        name += "_prefix"
    common.write_bench_json(result, name)
    print("BENCH " + json.dumps(result))


if __name__ == "__main__":
    main()
