"""Continuous-batching engine vs static batched generate() under ragged
synthetic traffic (Poisson arrivals, mixed prompt/gen lengths).

The engine packs an ever-changing request mix into bucketed compiled decode
segments (launch/engine.py); the static path forms fixed batches in arrival
order, waits for each batch to fill, pads prompts/gens to the batch max,
and pays one compiled graph per distinct batch shape.  The gap between the
two is the serving analogue of the DSP under-utilization the paper's passes
reclaim.

`--family {dense,ssm,hybrid}` picks the model family served through the
SAME engine (the slot-state registry, models/slot_state.py); ssm/hybrid
rows demonstrate the family-agnostic slot layer (ssm: constant-size pages,
batch-bucket-only graph growth).

Emits one machine-readable line:  BENCH {json}  with the family, aggregate
tok/s, p50/p99 per-request latency, mean slot occupancy, compiled-graph
counts (the engine's is bounded by its bucket sets), and the **active
lowering census** {op: lowering id} from kernels/registry.py -- every
throughput row is attributable to the kernel lowerings it ran on
(REPRO_LOWERING=... rows are distinguishable from auto-resolved ones).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]
        [--family {dense,ssm,hybrid}] [--silvia {off,add,muladd,all}]
        [--n-requests N] [--rate R]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import registry
from repro.launch import scheduler, serve
from repro.launch.engine import ServeEngine
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def _percentiles(latencies) -> dict:
    lat = np.asarray(sorted(latencies))
    return {"p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2)}


def _summary(requests, elapsed: float) -> dict:
    useful = sum(r.max_new_tokens for r in requests)
    return {
        "requests": len(requests),
        "useful_tokens": useful,
        "elapsed_s": round(elapsed, 3),
        "agg_tok_s": round(useful / max(elapsed, 1e-9), 1),
        **_percentiles([r.latency() for r in requests]),
    }


def run_engine(params, cfg, requests, *, n_slots, max_cache_len,
               segment_len, silvia_passes, prefill_chunk=None,
               warmup=True) -> dict:
    eng = ServeEngine(params, cfg, n_slots=n_slots,
                      max_cache_len=max_cache_len, segment_len=segment_len,
                      silvia_passes=silvia_passes,
                      prefill_chunk=prefill_chunk)
    if warmup:
        # startup pre-compilation over the advertised traffic profile --
        # the static path below gets the matching per-shape warm pass
        eng.warmup(prompt_lens=sorted({r.prompt_len for r in requests}))
    clock = scheduler.FastForwardClock()
    t0 = clock.now()
    eng.run(requests, clock)
    elapsed = clock.now() - t0
    info = eng.cache_info()
    out = _summary(eng.finished, elapsed)
    out["mean_occupancy"] = round(float(np.mean(eng.occupancy)), 3) \
        if eng.occupancy else 0.0
    out["graphs"] = info["graphs"]
    out["graph_bound"] = info["graph_bound"]
    out["graph_keys"] = [" ".join(map(str, k)) for k in info["graph_keys"]]
    out["has_length_axis"] = info["has_length_axis"]
    out["compactions"] = info["compactions"]
    out["lowerings"] = info["lowerings"]
    if "silvia" in info:
        out["silvia_trace"] = {k: info["silvia"][k]
                               for k in ("trace_hits", "trace_misses")}
    return out


def run_static(params, cfg, requests, *, n_slots, silvia_passes,
               warmup=True) -> dict:
    """PR-1 static path: batches of n_slots in arrival order; each batch
    waits until its last request arrives, pads every prompt/gen to the
    batch max, and decodes gen_max steps for every row."""
    reqs = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    batches = [reqs[i:i + n_slots] for i in range(0, len(reqs), n_slots)]
    shapes = set()
    for batch in batches:
        pl = max(r.prompt_len for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        shapes.add((len(batch), pl, gen, pl + gen))
    if warmup:
        for (b, pl, gen, cl) in sorted(shapes):
            prompts = jnp.zeros((b, pl), jnp.int32)
            jax.block_until_ready(serve.generate(
                params, prompts, cfg, gen=gen, cache_len=cl,
                silvia_passes=silvia_passes))
    clock = scheduler.FastForwardClock()
    t0 = clock.now()
    for batch in batches:
        clock.wait_until(max(r.arrival_time for r in batch))
        pl = max(r.prompt_len for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        prompts = np.zeros((len(batch), pl), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :r.prompt_len] = r.prompt
        toks = serve.generate(params, jnp.asarray(prompts), cfg, gen=gen,
                              cache_len=pl + gen,
                              silvia_passes=silvia_passes)
        toks = np.asarray(toks)
        done = clock.now()
        for i, r in enumerate(batch):
            r.tokens = [int(t) for t in toks[i, :r.max_new_tokens]]
            r.finish_time = done
    elapsed = clock.now() - t0
    out = _summary(reqs, elapsed)
    out["graphs"] = len(shapes)
    return out


FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b"}


def run(smoke: bool = False, silvia_passes: str = "off",
        n_requests: int | None = None, rate: float | None = None,
        family: str = "dense") -> dict:
    arch = FAMILY_ARCHS[family]
    cfg = configs.get_reduced_config(arch)
    if smoke:
        n_req = n_requests or 8
        rate = rate or 50.0
        n_slots, seg, max_len = 2, 4, 64
        prompt_lens, gen_lens = (4, 8, 12), (2, 4, 8)
    else:
        n_req = n_requests or 32
        rate = rate or 20.0
        n_slots, seg, max_len = 4, 8, 128
        prompt_lens, gen_lens = (8, 16, 32, 48), (2, 8, 16, 32)
    rng = jax.random.PRNGKey(0)
    params = quantize_tree_for_serving(
        lm.init_params(rng, cfg, max_seq=max_len + 8), "w8a8")

    def traffic():
        return scheduler.synthetic_traffic(
            seed=0, n_requests=n_req, rate=rate,
            prompt_lens=prompt_lens, gen_lens=gen_lens, vocab=cfg.vocab)

    result = {
        "config": {"arch": f"{arch}(reduced)", "family": family,
                   "n_requests": n_req,
                   "rate_req_s": rate, "n_slots": n_slots,
                   "segment_len": seg, "max_cache_len": max_len,
                   "prompt_lens": list(prompt_lens),
                   "gen_lens": list(gen_lens), "quant": "w8a8",
                   "silvia": silvia_passes,
                   "backend": jax.default_backend(),
                   "lowerings": registry.active_lowerings()},
        "engine": run_engine(params, cfg, traffic(), n_slots=n_slots,
                             max_cache_len=max_len, segment_len=seg,
                             silvia_passes=silvia_passes),
        "static": run_static(params, cfg, traffic(), n_slots=n_slots,
                             silvia_passes=silvia_passes),
    }
    result["speedup_tok_s"] = round(
        result["engine"]["agg_tok_s"]
        / max(result["static"]["agg_tok_s"], 1e-9), 2)
    result["graphs_bounded"] = (result["engine"]["graphs"]
                                <= result["engine"]["graph_bound"])
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/traffic (CI)")
    ap.add_argument("--family", default="dense",
                    choices=sorted(FAMILY_ARCHS),
                    help="model family served through the engine's "
                         "slot-state registry")
    ap.add_argument("--silvia", default="off",
                    choices=list(serve.SILVIA_PASS_SETS))
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s)")
    args = ap.parse_args()
    result = run(smoke=args.smoke, silvia_passes=args.silvia,
                 n_requests=args.n_requests, rate=args.rate,
                 family=args.family)
    print(json.dumps(result, indent=2))
    print("BENCH " + json.dumps(result))


if __name__ == "__main__":
    main()
