"""Paper Table 1b: multiplication/MAD-intensive benchmarks.

Each workload is written the way the corresponding HLS design exposes it to
the compiler (unrolled loops -> parallel narrow ops).  The factor-2 packing
needs two op streams sharing an operand, which in these designs comes from
output unrolling (two output rows/channels consume the same input).

Paper results on this group: Ops/Unit 1.00 -> ~2.0 (4.0 for the 4-bit MMM),
~50 % unit reduction; axpy's extra adds stay unpacked (sec. 4.1), GSM/RTM
pack only partially.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_case
from repro import core as silvia

PASSES_MAD = [silvia.PassConfig(op="muladd")]
PASSES_4B = [silvia.PassConfig(op="mul4")]


def _f(x):
    return x.astype(jnp.int32)


# --- BLAS ------------------------------------------------------------------

def mvm(w_even, w_odd, x):
    """192x192 int8 matrix-vector product, output-unrolled by 2: the row
    pair shares x (paper Eq. 1 with N=1)."""
    y_e = jnp.sum(_f(w_even) * _f(x)[None, :], axis=1)
    y_o = jnp.sum(_f(w_odd) * _f(x)[None, :], axis=1)
    return y_e, y_o


def mmm(a_even, a_odd, b):
    """192x192x192 int8 matmul, row-unrolled by 2, k blocked via scan:
    the scan body holds two muls sharing b_k."""
    def body(acc, inp):
        a_e, a_o, b_k = inp
        ce = acc[0] + _f(a_e)[:, None] * _f(b_k)[None, :]
        co = acc[1] + _f(a_o)[:, None] * _f(b_k)[None, :]
        return (ce, co), None

    n = b.shape[1]
    acc0 = (jnp.zeros((a_even.shape[0], n), jnp.int32),
            jnp.zeros((a_odd.shape[0], n), jnp.int32))
    (ce, co), _ = jax.lax.scan(
        body, acc0, (a_even.T, a_odd.T, b))
    return ce, co


def mmm_4b(a0, a1, a2, a3, b):
    """4-bit MMM: four row streams share b_k -> factor-4 packing."""
    wh = lambda t: silvia.width_hint(t, 4)

    def body(acc, inp):
        a_s, b_k = inp[:4], inp[4]
        bk = _f(wh(b_k))
        outs = tuple(acc[i] + _f(wh(a_s[i]))[:, None] * bk[None, :]
                     for i in range(4))
        return outs, None

    n = b.shape[1]
    acc0 = tuple(jnp.zeros((a0.shape[0], n), jnp.int32) for _ in range(4))
    outs, _ = jax.lax.scan(body, acc0, (a0.T, a1.T, a2.T, a3.T, b))
    return outs


def scal(x_even, x_odd, alpha):
    """BLAS scal on 512 int8 elements, unrolled by 2 sharing alpha."""
    return _f(x_even) * _f(alpha), _f(x_odd) * _f(alpha)


def axpy(x_even, x_odd, y_even, y_odd, alpha):
    """alpha*x + y: muls pack (shared alpha); the +y adds cannot join the
    packed MAD (paper sec. 4.1: axpy keeps LUT adders)."""
    return (_f(x_even) * _f(alpha) + _f(y_even),
            _f(x_odd) * _f(alpha) + _f(y_odd))


# --- GSM (CHStone): LTP cross-correlation flavour ---------------------------

def gsm(d_even, d_odd, wt, prev):
    """Long-term-predictor style: two lag streams share the window `wt`;
    one extra unshared scaling mul stays unpacked (partial packing, paper
    Ops/Unit 1.58)."""
    l0 = jnp.sum(_f(d_even) * _f(wt))
    l1 = jnp.sum(_f(d_odd) * _f(wt))
    scale = _f(prev) * _f(prev)          # unshared -> not packable
    return l0, l1, scale


# --- RTM: 3D 7-point stencil -------------------------------------------------

def rtm(p_a, p_b, taps_a, taps_b, c_center, c_axis):
    """Forward RTM step on two wavefield streams (ping-pong buffers).
    Center-tap muls share coefficients across streams and pack; the six
    axis taps are summed first (adds), leaving one mul per stream -- mostly
    unpackable, matching the paper's low 1.14 density for RTM."""
    lap_a = sum(taps_a[1:], taps_a[0])
    lap_b = sum(taps_b[1:], taps_b[0])
    out_a = _f(p_a) * _f(c_center) + _f(lap_a) * _f(c_axis)
    out_b = _f(p_b) * _f(c_center) + _f(lap_b) * _f(c_axis)
    return out_a, out_b


# --- GAT (FlowGNN) -----------------------------------------------------------

def gat(h_even, h_odd, att, w_self):
    """Graph-attention score kernel: neighbour feature pairs share the
    attention vector."""
    e0 = jnp.sum(_f(h_even) * _f(att), axis=1)
    e1 = jnp.sum(_f(h_odd) * _f(att), axis=1)
    s0 = jnp.sum(_f(h_even) * _f(w_self), axis=1)
    s1 = jnp.sum(_f(h_odd) * _f(w_self), axis=1)
    return e0, e1, s0, s1


def run():
    rng = np.random.default_rng(1)
    i8 = lambda *s: jnp.asarray(rng.integers(-128, 128, s), jnp.int8)
    i4 = lambda *s: jnp.asarray(rng.integers(-8, 8, s), jnp.int8)
    rows = []
    rows.append(bench_case("MVM", mvm, (i8(96, 192), i8(96, 192), i8(192)),
                           PASSES_MAD))
    rows.append(bench_case("MMM", mmm,
                           (i8(96, 192), i8(96, 192), i8(192, 192)),
                           PASSES_MAD))
    rows.append(bench_case(
        "MMM-4b", mmm_4b,
        (i4(48, 192), i4(48, 192), i4(48, 192), i4(48, 192), i4(192, 192)),
        PASSES_4B))
    rows.append(bench_case("scal", scal,
                           (i8(256), i8(256), jnp.int8(3)), PASSES_MAD))
    rows.append(bench_case(
        "axpy", axpy, (i8(256), i8(256), i8(256), i8(256), jnp.int8(3)),
        PASSES_MAD))
    rows.append(bench_case("GSM", gsm, (i8(40), i8(40), i8(40), i8(40)),
                           PASSES_MAD))
    taps = lambda: tuple(i8(16, 16, 16) for _ in range(6))
    rows.append(bench_case(
        "RTM", rtm, (i8(16, 16, 16), i8(16, 16, 16), taps(), taps(),
                     jnp.int8(5), jnp.int8(2)), PASSES_MAD))
    rows.append(bench_case(
        "GAT", gat, (i8(128, 64), i8(128, 64), i8(64), i8(64)), PASSES_MAD))
    return rows
