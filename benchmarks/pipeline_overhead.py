"""Pass-pipeline + serving-overhead benchmark: the "zero-cost drop-in" claim.

Measures, on a reduced decoder config:

* pass-pipeline wall time: first `optimize()` call (trace + SILVIA rewrite
  + compile) vs steady-state calls that hit the trace cache,
* the trace/sub-jaxpr/analysis cache hit counters,
* decode throughput: per-step dispatch loop vs the fused lax.scan loop.

Emits one machine-readable line:  BENCH {json}

    PYTHONPATH=src python -m benchmarks.pipeline_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import configs
from repro import core as silvia
from repro.launch import serve
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def _ms(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e3, out


def measure_pipeline_overhead(cfg, params, cache_len: int, batch: int,
                              steady_iters: int = 10) -> dict:
    """Per-call overhead of the optimize()-wrapped decode step: call 1 pays
    trace + rewrite + compile; calls 2..N must hit the trace cache."""
    def decode_fn(p, tok, kv, pos):
        return lm.decode_step(p, tok, kv, pos, cfg)

    opt = silvia.optimize(decode_fn, silvia.DEFAULT_PASSES)
    tok = jnp.zeros((batch, 1), jnp.int32)
    cache = lm.init_cache(cfg, batch, cache_len)
    pos = jnp.full((batch,), 1, jnp.int32)

    first_ms, (_, cache) = _ms(opt, params, tok, cache, pos)
    steady = []
    for _ in range(steady_iters):
        dt, (_, cache) = _ms(opt, params, tok, cache, pos)
        steady.append(dt)
    steady_ms = sorted(steady)[len(steady) // 2]          # median
    info = opt.cache_info()
    calls = info["trace_hits"] + info["trace_misses"]
    return {
        "first_call_ms": round(first_ms, 2),
        "steady_call_ms": round(steady_ms, 2),
        "overhead_ratio": round(first_ms / max(steady_ms, 1e-6), 1),
        "rewrite_ms": round(info["rewrite_ms"], 2),
        "trace_cache_hit_rate": round(info["trace_hits"] / calls, 3),
        **{k: info[k] for k in ("trace_hits", "trace_misses",
                                "subjaxpr_hits", "subjaxpr_misses",
                                "analysis_builds", "analysis_hits")},
    }


def measure_decode_tps(cfg, params, prompts, gen: int, cache_len: int,
                       silvia_passes: str = "off") -> dict:
    """tok/s of the per-step dispatch loop vs the fused lax.scan loop
    (warm: one throwaway run each so compile time is excluded)."""
    b = prompts.shape[0]
    out = {}
    for fused in (False, True):
        run = lambda: serve.generate(params, prompts, cfg, gen=gen,
                                     cache_len=cache_len,
                                     silvia_passes=silvia_passes,
                                     fused=fused)
        jax.block_until_ready(run())                      # warm-up/compile
        dt, _ = _ms(run)
        out["fused_tok_s" if fused else "stepwise_tok_s"] = round(
            b * gen / (dt / 1e3), 1)
    out["fused_speedup"] = round(out["fused_tok_s"]
                                 / max(out["stepwise_tok_s"], 1e-6), 2)
    return out


def run(smoke: bool = False) -> dict:
    cfg = configs.get_reduced_config("smollm-135m")
    batch, prompt_len = (2, 8) if smoke else (4, 32)
    gen = 8 if smoke else 32
    cache_len = prompt_len + gen
    rng = jax.random.PRNGKey(0)
    params = quantize_tree_for_serving(
        lm.init_params(rng, cfg, max_seq=cache_len + 8), "w8a8")
    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab,
                                 dtype=jnp.int32)
    result = {
        "config": {"arch": "smollm-135m(reduced)", "batch": batch,
                   "prompt_len": prompt_len, "gen": gen, "quant": "w8a8",
                   "backend": jax.default_backend()},
        "pipeline": measure_pipeline_overhead(cfg, params, cache_len, batch),
        "decode": measure_decode_tps(cfg, params, prompts, gen, cache_len),
    }
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI)")
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    print(json.dumps(result, indent=2))
    common.write_bench_json(result, "pipeline_overhead")
    print("BENCH " + json.dumps(result))


if __name__ == "__main__":
    main()
