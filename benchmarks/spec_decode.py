"""Self-speculative decoding benchmark: acceptance rate and
tokens-per-target-dispatch speedup vs the non-speculative engine on the
SAME trace, with a byte-identity check over every stream.

    PYTHONPATH=src python benchmarks/spec_decode.py --smoke

Two draft variants are reported:

* ``same`` -- the target's own weights as draft.  Acceptance is a pure
  function of (seed, rid, token prefix), so tokens_per_dispatch is
  DETERMINISTIC: this is the row the CI regression gate
  (scripts/bench_compare.py, baseline spec_decode_dense_smoke.json)
  arms on.
* ``weak`` -- same config, fresh weights: frequently-wrong drafts that
  exercise the partial-acceptance rollback path and put a realistic
  floor under the acceptance numbers.

The streams of BOTH variants must equal the non-spec engine's bytes
(``bit_exact``); if they do not, the benchmark exits non-zero -- a perf
number for a wrong stream is not a number."""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from benchmarks import common
from repro import configs
from repro.launch import scheduler
from repro.launch.engine import ServeEngine, SpecDecodeConfig
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b"}


def _traffic(cfg, n_req, rate, prompt_lens, gen_lens, trace_seed):
    mix = (None,
           scheduler.SamplingParams(temperature=0.8, top_k=8, seed=5),
           scheduler.GREEDY,
           scheduler.SamplingParams(temperature=1.0, top_p=0.9, seed=2))
    return scheduler.synthetic_traffic(
        seed=trace_seed, n_requests=n_req, rate=rate,
        prompt_lens=prompt_lens, gen_lens=gen_lens, vocab=cfg.vocab,
        sampling_mix=mix)


def _run(params, cfg, requests, *, n_slots, max_len, seg, spec_decode):
    eng = ServeEngine(params, cfg, n_slots=n_slots, max_cache_len=max_len,
                      segment_len=seg, spec_decode=spec_decode)
    t0 = time.perf_counter()
    out = eng.run(requests, scheduler.FastForwardClock())
    elapsed = time.perf_counter() - t0
    return out, elapsed, eng.cache_info()


def run(smoke: bool = False, family: str = "dense", k: int = 3,
        n_requests: int | None = None, trace_seed: int = 0) -> dict:
    cfg = configs.get_reduced_config(FAMILY_ARCHS[family])
    if smoke:
        n_req = n_requests or 8
        n_slots, seg, max_len = 4, 4, 64
        prompt_lens, gen_lens = (5, 9, 12), (6, 8, 10)
    else:
        n_req = n_requests or 24
        n_slots, seg, max_len = 8, 8, 128
        prompt_lens, gen_lens = (8, 16, 24), (8, 16, 24)
    params = lm.init_params(jax.random.PRNGKey(0), cfg,
                            max_seq=max_len + 8)
    weak = lm.init_params(jax.random.PRNGKey(9), cfg, max_seq=max_len + 8)
    kw = dict(n_slots=n_slots, max_len=max_len, seg=seg)

    def trace():
        return _traffic(cfg, n_req, 1e9, prompt_lens, gen_lens,
                        trace_seed)

    ref, ref_s, ref_info = _run(params, cfg, trace(), spec_decode=None,
                                **kw)
    ref_dispatches = ref_info["dispatch_sites"]["segment"]
    result = {
        "config": {"family": family, "k": k, "n_requests": n_req,
                   "smoke": smoke, "trace_seed": trace_seed},
        "nonspec": {"elapsed_s": round(ref_s, 3),
                    "segment_dispatches": ref_dispatches},
    }
    ok = True
    for label, dparams in (("same", params), ("weak", weak)):
        sd = SpecDecodeConfig(draft_params=dparams, draft_cfg=cfg, k=k)
        out, sec, info = _run(params, cfg, trace(), spec_decode=sd, **kw)
        bit_exact = set(out) == set(ref) and all(
            np.array_equal(out[r], ref[r]) for r in ref)
        ok = ok and bit_exact
        row = dict(info["spec_decode"])
        row.pop("draft", None)
        row.update({
            "elapsed_s": round(sec, 3),
            "bit_exact": bit_exact,
            # dispatch-count speedup: target dispatches the non-spec
            # engine needed per target dispatch the spec engine needed
            "dispatch_speedup": round(
                ref_dispatches / max(info["spec_decode"]
                                     ["target_dispatches"], 1), 2),
        })
        result[label] = row
    # the gated metric lives at the payload top level under the name
    # bench_compare._metric reads: the DETERMINISTIC same-draft row
    result["spec_decode"] = {
        "tokens_per_dispatch": result["same"]["tokens_per_dispatch"],
        "acceptance_rate": result["same"]["acceptance_rate"],
    }
    result["bit_exact"] = ok
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/traffic (CI)")
    ap.add_argument("--family", default="dense",
                    choices=sorted(FAMILY_ARCHS))
    ap.add_argument("--k", type=int, default=3,
                    help="draft tokens per speculative round")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="seed for the traffic trace (baselines use the "
                         "default 0)")
    args = ap.parse_args()
    result = run(smoke=args.smoke, family=args.family, k=args.k,
                 n_requests=args.n_requests, trace_seed=args.trace_seed)
    print(json.dumps(result, indent=2))
    name = f"spec_decode_{args.family}"
    if args.smoke:
        name += "_smoke"
    common.write_bench_json(result, name)
    print("BENCH " + json.dumps(result))
    if not result["bit_exact"]:
        print("spec_decode: streams diverged from the non-spec engine",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
