"""Paper Table 1a: addition-intensive benchmarks.

* vadd -- the Xilinx example design: sum of two 192-element int8 vectors,
  unrolled by 8 (the HLS pragma unroll that exposes SLP).
* SNN  -- spiking convolutional layer (Ottati): binary spikes select which
  weights accumulate; the datapath is pure additions.  24x24x64 input,
  3x3 taps (channel counts reduced for CPU runtime; the op-density metric
  is independent of the channel count).

The paper reports Ops/Unit 1.00 -> ~3.3 and ~70 % DSP (here: packed-unit)
reduction on this group; we reproduce the metric with SILVIAAdd four8.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_case
from repro import core as silvia

PASSES = [silvia.PassConfig(op="add", op_size=8),
          silvia.PassConfig(op="add", op_size=16)]


def vadd_unrolled(a_lanes, b_lanes):
    """8 parallel int8 adds over 24-element lanes (192 total)."""
    return tuple(a + b for a, b in zip(a_lanes, b_lanes))


def snn_conv_taps(spikes, weights, accs):
    """Spiking conv: membrane += spike ? w : 0 per tap, 3x3 taps unrolled,
    channel dimension split into 4 independent accumulator lanes (the
    output-channel unroll that exposes the SLP the paper packs).

    spikes: tuple of 9 bool [H*W] maps (shifted input views, shared)
    weights: tuple of 9 tuples of 4 int8 [C/4] channel-block weights
    accs: tuple of 4 int8 [H*W, C/4] membrane accumulators
    """
    outs = list(accs)
    for s, w4 in zip(spikes, weights):
        for k in range(len(outs)):
            contrib = jnp.where(s[:, None], w4[k][None, :], 0
                                ).astype(jnp.int8)
            outs[k] = outs[k] + contrib     # independent across k -> four8
    return tuple(outs)


def run():
    rng = np.random.default_rng(0)
    rows = []
    lanes = 8
    a = tuple(jnp.asarray(rng.integers(-128, 128, (24,)), jnp.int8)
              for _ in range(lanes))
    b = tuple(jnp.asarray(rng.integers(-128, 128, (24,)), jnp.int8)
              for _ in range(lanes))
    rows.append(bench_case("vadd", vadd_unrolled, (a, b), PASSES,
                           kind="add"))

    hw, c = 24 * 24, 16
    spikes = tuple(jnp.asarray(rng.random((hw,)) > 0.7)
                   for _ in range(9))
    weights = tuple(tuple(jnp.asarray(rng.integers(-128, 128, (c // 4,)),
                                      jnp.int8) for _ in range(4))
                    for _ in range(9))
    accs = tuple(jnp.zeros((hw, c // 4), jnp.int8) for _ in range(4))
    rows.append(bench_case("SNN", snn_conv_taps, (spikes, weights, accs),
                           PASSES, kind="add"))
    return rows
