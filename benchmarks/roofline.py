"""Roofline report generator: reads the dry-run JSON (launch/dryrun.py
--out) and renders the EXPERIMENTS.md tables."""
from __future__ import annotations

import json
import os

HW_NOTE = ("v5e-class constants: 197 TFLOP/s bf16, 819 GB/s HBM, "
           "~50 GB/s/link ICI; all terms are per-chip seconds per step")


def load(path="results/dryrun_baseline.json"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_row(r) -> str:
    if r.get("status") != "run":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"SKIP: {r['status'].split(':', 1)[1].strip()} |||||")
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"FAIL: {r.get('error', '?')[:60]} |||||")
    t = r["roofline"]
    ratio = r.get("useful_flops_ratio")
    return ("| {arch} | {shape} | {mesh} | {c:.2e} | {m:.2e} | {k:.2e} | "
            "{dom} | {ratio} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=t["compute_s"], m=t["memory_s"], k=t["collective_s"],
        dom=t["dominant"].replace("_s", ""),
        ratio=f"{ratio:.3f}" if ratio else "-")


def report(path="results/dryrun_baseline.json"):
    rows = load(path)
    print("# Roofline (", HW_NOTE, ")")
    print("| arch | shape | mesh | compute_s | memory_s | collective_s | "
          "dominant | useful-FLOPs ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(fmt_row(r))
    ok = sum(1 for r in rows if r.get("ok"))
    skip = sum(1 for r in rows if r.get("status") != "run")
    fail = sum(1 for r in rows
               if r.get("status") == "run" and not r.get("ok"))
    print(f"\ncells: {ok} ok, {skip} skipped (documented), {fail} failed")
    return rows


if __name__ == "__main__":
    report()
