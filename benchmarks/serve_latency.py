"""Serving latency through the async streaming front-end: Poisson
open-loop replay, per-method TTFT and inter-token percentiles, and the
measured win of double-buffered dispatch (overlap) over a synchronous
serve loop on the SAME trace.

The trace (scheduler.method_traffic) mixes the three servable methods --
generate (streamed), score, embed -- with Poisson arrivals replayed
OPEN-LOOP against a real wall clock: each client task sleeps until its
arrival time and then submits, regardless of how backed up the server is,
so queueing delay shows up in TTFT instead of being hidden by a closed
loop.  Latencies are measured where they matter -- at the CLIENT side of
the per-stream asyncio queues: TTFT is first-token receipt (result
receipt for score/embed) minus submit, inter-token gaps are successive
stream receipts.

The same trace is served twice: ``overlap`` runs the front-end's
two-stage pipeline (host publish/planning under the in-flight device
segment, launch/frontend.py), ``no_overlap`` syncs every segment before
doing host work.  The ``improvement`` block is the ratio between the two
(>1 = pipeline wins) and ``overlap.hidden_host_ms`` is the direct
measurement of the pipeline: host time that ran UNDER an in-flight
segment instead of between segments.  On a single-core host the wall
clock ratios sit near 1.0 by construction (host and "device" timeshare
the only core, so hiding host work buys no wall time); the hidden-host
measurement and the multi-core ratios are the signal.  The gated
regression metric is ``overlap.stream_tok_s``.  ``bit_exact`` checks
the streamed generate tokens byte-for-byte against a plain batch
ServeEngine run of the same trace -- the pipeline must never buy
latency with a single changed bit.

    PYTHONPATH=src python -m benchmarks.serve_latency [--smoke]
        [--family {dense,ssm,hybrid,encdec}] [--silvia {off,add,muladd,all}]
        [--n-requests N] [--rate R]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.serve_throughput import FAMILY_ARCHS
from repro import configs
from repro.launch import scheduler, serve
from repro.launch.engine import ServeEngine
from repro.launch.frontend import AsyncFrontend
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def _pct(vals, q) -> float:
    return round(float(np.percentile(np.asarray(vals, np.float64), q)) * 1e3,
                 3)


def _latency_block(ttfts, gaps) -> dict:
    out = {"ttft_ms": {f"p{q}": _pct(ttfts, q) for q in (50, 95, 99)}
           if ttfts else None}
    if gaps:
        out["tok_ms"] = {f"p{q}": _pct(gaps, q) for q in (50, 95, 99)}
    return out


def make_engine(params, cfg, *, n_slots, max_cache_len, segment_len,
                silvia_passes, enc_len):
    kw = {"enc_len": enc_len} if enc_len is not None else {}
    return ServeEngine(params, cfg, n_slots=n_slots,
                       max_cache_len=max_cache_len, segment_len=segment_len,
                       silvia_passes=silvia_passes, prefix_cache=64, **kw)


async def _replay(frontend: AsyncFrontend, trace, enc_feats) -> dict:
    """Open-loop replay: every request is submitted at its trace arrival
    time on the real clock.  Returns per-method client-side latency
    samples and the streamed generate tokens."""
    t0 = time.perf_counter()
    ttfts: dict = {m: [] for m in ("generate", "score", "embed")}
    gaps: list = []
    stream_toks: dict = {}
    errors: list = []

    async def one(req):
        await asyncio.sleep(max(0.0, req.arrival_time
                                 - (time.perf_counter() - t0)))
        feats = enc_feats.get(req.rid) if enc_feats else None
        sub = time.perf_counter()
        try:
            if req.method == "generate":
                toks, prev = [], None
                async for t in frontend.generate_stream(
                        req.prompt, req.max_new_tokens, rid=req.rid,
                        features=feats):
                    now = time.perf_counter()
                    if prev is None:
                        ttfts["generate"].append(now - sub)
                    else:
                        gaps.append(now - prev)
                    prev = now
                    toks.append(t)
                stream_toks[req.rid] = toks
            elif req.method == "score":
                await frontend.score(req.prompt, req.score_tokens,
                                     rid=req.rid, features=feats)
                ttfts["score"].append(time.perf_counter() - sub)
            else:
                await frontend.embed(req.prompt, rid=req.rid,
                                     features=feats)
                ttfts["embed"].append(time.perf_counter() - sub)
        except Exception as e:  # noqa: BLE001 -- a shed/failed request
            errors.append(f"rid {req.rid}: {e}")

    await asyncio.gather(*(one(r) for r in trace))
    elapsed = time.perf_counter() - t0
    return {"ttfts": ttfts, "gaps": gaps, "stream_toks": stream_toks,
            "elapsed": elapsed, "errors": errors}


def run_frontend(params, cfg, trace, enc_feats, *, overlap,
                 engine_kw) -> dict:
    eng = make_engine(params, cfg, **engine_kw)
    eng.warmup(prompt_lens=sorted({r.prompt_len for r in trace}),
               methods=("generate", "score", "embed"))

    async def go():
        fe = AsyncFrontend(eng, overlap=overlap)
        async with fe:
            raw = await _replay(fe, trace, enc_feats)
        raw["stats"] = dict(fe.stats)
        return raw

    raw = asyncio.run(go())
    n_stream = sum(len(v) for v in raw["stream_toks"].values())
    out = {
        "elapsed_s": round(raw["elapsed"], 3),
        "stream_tok_s": round(n_stream / max(raw["elapsed"], 1e-9), 1),
        "streamed_tokens": n_stream,
        "overlapped_segments": raw["stats"]["overlapped_segments"],
        # host time that ran under an in-flight segment -- work a sync
        # loop serializes into the dispatch-to-dispatch path (0 in the
        # no_overlap row by construction)
        "hidden_host_ms": round(raw["stats"]["hidden_host_s"] * 1e3, 2),
        "methods": {m: _latency_block(raw["ttfts"][m],
                                      raw["gaps"] if m == "generate"
                                      else None)
                    for m in ("generate", "score", "embed")
                    if raw["ttfts"][m]},
        "errors": raw["errors"],
    }
    return out, raw["stream_toks"]


def run_batch(params, cfg, trace, enc_feats, *, engine_kw) -> dict:
    """Plain batch engine on the same trace -- the bit-exactness
    reference for the streamed generate tokens."""
    eng = make_engine(params, cfg, **engine_kw)
    clock = scheduler.FastForwardClock()
    for r in trace:
        if enc_feats:
            r.features = enc_feats.get(r.rid)
        eng.submit(r)
    want = len(trace)
    while len(eng.results()) < want:
        if not eng.step(clock):
            nxt = eng.next_arrival(clock.now())
            if nxt is not None:
                clock.wait_until(nxt)
    return {r.rid: list(r.tokens) for r in eng.finished
            if r.method == "generate"}


def run(smoke: bool = False, silvia_passes: str = "off",
        family: str = "dense", n_requests: int | None = None,
        rate: float | None = None, trace_seed: int = 0) -> dict:
    arch = FAMILY_ARCHS[family]
    cfg = configs.get_reduced_config(arch)
    if smoke:
        n_req = n_requests or 10
        rate = rate or 100.0
        n_slots, seg, max_len = 2, 4, 64
        prompt_lens, gen_lens = (4, 8, 12), (4, 8)
    else:
        n_req = n_requests or 32
        rate = rate or 40.0
        n_slots, seg, max_len = 4, 8, 128
        prompt_lens, gen_lens = (8, 16, 32), (8, 16, 24)
    enc_len = None
    if family == "encdec":
        enc_len = 16 if smoke else 32
    params = quantize_tree_for_serving(
        lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=max_len + 8),
        "w8a8", force=True)

    def trace():
        # a fresh Request list per run: engines mutate requests in place
        return scheduler.method_traffic(
            seed=trace_seed, n_requests=n_req, rate=rate,
            prompt_lens=prompt_lens,
            gen_lens=gen_lens, vocab=cfg.vocab)

    enc_feats = None
    if family == "encdec":
        frng = np.random.default_rng(1)
        # ragged encoder lengths: the enc-length bucketing path is part
        # of what this benchmark keeps honest
        enc_feats = {i: frng.standard_normal(
            (int(frng.integers(3, enc_len + 1)), cfg.d_model)
        ).astype(np.float32) for i in range(n_req)}
    engine_kw = dict(n_slots=n_slots, max_cache_len=max_len,
                     segment_len=seg, silvia_passes=silvia_passes,
                     enc_len=enc_len)

    overlap, toks_overlap = run_frontend(params, cfg, trace(), enc_feats,
                                         overlap=True, engine_kw=engine_kw)
    no_overlap, toks_sync = run_frontend(params, cfg, trace(), enc_feats,
                                         overlap=False, engine_kw=engine_kw)
    batch_toks = run_batch(params, cfg, trace(), enc_feats,
                           engine_kw=engine_kw)

    def ratio(a, b):
        return round(a / max(b, 1e-9), 3)

    gen_o = overlap["methods"].get("generate") or {}
    gen_s = no_overlap["methods"].get("generate") or {}
    improvement = {
        "stream_tok_s": ratio(overlap["stream_tok_s"],
                              no_overlap["stream_tok_s"]),
    }
    if gen_o.get("ttft_ms") and gen_s.get("ttft_ms"):
        improvement["ttft_p50"] = ratio(gen_s["ttft_ms"]["p50"],
                                        gen_o["ttft_ms"]["p50"])
        improvement["ttft_p95"] = ratio(gen_s["ttft_ms"]["p95"],
                                        gen_o["ttft_ms"]["p95"])
    if gen_o.get("tok_ms") and gen_s.get("tok_ms"):
        improvement["tok_p95"] = ratio(gen_s["tok_ms"]["p95"],
                                       gen_o["tok_ms"]["p95"])
    return {
        "config": {"arch": f"{arch}(reduced)", "family": family,
                   "n_requests": n_req, "rate_req_s": rate,
                   "n_slots": n_slots, "segment_len": seg,
                   "max_cache_len": max_len, "enc_len": enc_len,
                   "silvia": silvia_passes, "quant": "w8a8(forced)",
                   "backend": jax.default_backend()},
        "overlap": overlap,
        "no_overlap": no_overlap,
        "improvement": improvement,
        "bit_exact": (set(toks_overlap) == set(batch_toks)
                      and toks_overlap == batch_toks
                      and toks_sync == batch_toks),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/traffic (CI)")
    ap.add_argument("--family", default="dense",
                    choices=sorted(FAMILY_ARCHS))
    ap.add_argument("--silvia", default="off",
                    choices=list(serve.SILVIA_PASS_SETS))
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="seed for the method-mix traffic trace "
                         "(baselines use the default 0)")
    args = ap.parse_args()
    result = run(smoke=args.smoke, silvia_passes=args.silvia,
                 family=args.family, n_requests=args.n_requests,
                 rate=args.rate, trace_seed=args.trace_seed)
    print(json.dumps(result, indent=2))
    name = f"serve_latency_{args.family}"
    common.write_bench_json(result, name)
    print("BENCH " + json.dumps(result))


if __name__ == "__main__":
    main()
