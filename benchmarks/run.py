"""Benchmark harness: one section per paper table.

    PYTHONPATH=src python -m benchmarks.run            # tables 1a, 1b, 2
    PYTHONPATH=src python -m benchmarks.run --roofline # + dry-run roofline

Prints ``name,us_per_call,derived`` CSV per table (derived = the paper's
metric for that table: Ops/Unit + unit counts, or manual-vs-auto parity).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true",
                    help="also print the dry-run roofline table (requires "
                         "results/dryrun_baseline.json)")
    args = ap.parse_args()

    from benchmarks import table1a, table1b, table2_cnn
    from benchmarks.common import print_rows

    print_rows(table1a.run(),
               "Table 1a: addition-intensive (paper: Ops/Unit -> ~3.3, "
               "~70% unit reduction)")
    print_rows(table1b.run(),
               "Table 1b: mul/MAD-intensive (paper: Ops/Unit -> ~2.0, "
               "~50% unit reduction)")
    table2_cnn.print_rows(
        table2_cnn.run(),
        "Table 2: CNN accelerators, manual (M) vs automatic (S) packing "
        "(paper: S == M)")

    if args.roofline:
        from benchmarks import roofline
        roofline.report()


if __name__ == "__main__":
    main()
