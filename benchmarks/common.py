"""Shared benchmark utilities: timing, op-density reporting, CSV rows."""
from __future__ import annotations

import json
import os
import pathlib
import platform
import time

import jax
import numpy as np

from repro import core as silvia
from repro.core import opcount


def host_class() -> dict:
    """Coarse host fingerprint stamped into every BENCH payload.  Absolute
    smoke throughput is host-bound, so the regression gate
    (scripts/bench_compare.py) only compares a result against a baseline
    recorded on the SAME class and warns-and-skips otherwise."""
    dev = jax.devices()[0]
    return {
        "platform": platform.system().lower(),
        "machine": platform.machine(),
        "cpus": os.cpu_count(),
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
    }


def write_bench_json(result: dict, name: str) -> None:
    """Persist a benchmark's BENCH payload to $BENCH_DIR/<name>.json (CI
    uploads the directory as a workflow artifact and feeds it to
    scripts/bench_compare.py).  No-op when BENCH_DIR is unset, so local
    runs keep printing only.  The payload is stamped with `host_class`
    so the compare gate can refuse cross-host comparisons."""
    bench_dir = os.environ.get("BENCH_DIR")
    if not bench_dir:
        return
    payload = dict(result)
    payload.setdefault("host_class", host_class())
    path = pathlib.Path(bench_dir) / f"{name}.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def time_fn(fn, *args, iters: int = 5) -> float:
    """us per call, jit-compiled, synchronized."""
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jfn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def assert_equal_outputs(fn, opt_fn, args, atol=0):
    a = jax.tree_util.tree_leaves(fn(*args))
    b = jax.tree_util.tree_leaves(opt_fn(*args))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64), atol=atol)


def bench_case(name: str, fn, args, passes, kind: str = "mul"):
    """Run one Table-1-style benchmark: returns the CSV row dict.

    kind: which op class the benchmark stresses ("mul" | "add"), mirroring
    the paper's two benchmark groups."""
    before = opcount.count_ops(jax.make_jaxpr(fn)(*args))
    stats: list = []
    after_jaxpr = silvia.optimized_jaxpr(fn, *args, passes=passes,
                                         stats=stats)
    after = opcount.count_ops(after_jaxpr)
    opt_fn = silvia.optimize(fn, passes)
    assert_equal_outputs(fn, opt_fn, args)
    us = time_fn(opt_fn, *args)
    us_base = time_fn(fn, *args)
    if kind == "mul":
        density_b, density_s = before.mul_density, after.mul_density
        units_b = before.mul_units + before.madd_units
        units_s = after.mul_units + after.madd_units
    else:
        density_b, density_s = before.add_density, after.add_density
        units_b, units_s = before.add_units, after.add_units
    return {
        "name": name,
        "us_per_call": round(us, 1),
        "us_baseline": round(us_base, 1),
        "ops_per_unit_baseline": round(density_b, 2),
        "ops_per_unit_silvia": round(density_s, 2),
        "units_baseline": units_b,
        "units_silvia": units_s,
        "unit_reduction_pct": round(100 * (1 - units_s / units_b), 1)
        if units_b else 0.0,
        "packed_units": after.packed_units,
    }


def print_rows(rows, title):
    print(f"# {title}")
    print("name,us_per_call,derived")
    for r in rows:
        derived = (f"OpsPerUnit {r['ops_per_unit_baseline']}->"
                   f"{r['ops_per_unit_silvia']}; units {r['units_baseline']}"
                   f"->{r['units_silvia']} (-{r['unit_reduction_pct']}%)")
        print(f"{r['name']},{r['us_per_call']},{derived}")
