"""Paper Table 2 / Fig. 8: CNN accelerator case study.

NN2FPGA (ResNet8/20) and FINN (CNV-8b, MobileNet-4b) expose DSP packing as a
MANUAL, user-directed optimization.  The paper shows SILVIA matches the
manually packed designs automatically.  We reproduce that comparison:

  B  baseline  -- naive quantized conv layers (no packing)
  M  manual    -- the same layers hand-written against the packed primitives
                  (what NN2FPGA/FINN do at source/RTL level)
  S  silvia    -- the naive layers rewritten by silvia.optimize

Assertions (the paper's headline): packed-unit counts S == M, outputs
bit-exact across B/M/S.  Channel counts are reduced for CPU runtime; the
unit-count parity is what matters, not wall time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro import core as silvia
from repro.core import opcount, prims

PASSES = [silvia.PassConfig(op="muladd")]
PASSES4 = [silvia.PassConfig(op="mul4")]


def _f(x):
    return x.astype(jnp.int32)


def _shift_views(x, k=3):
    """x: [H, W] int8 -> tuple of k*k shifted views (zero padded)."""
    h, w = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1)))
    return tuple(xp[dy:dy + h, dx:dx + w]
                 for dy in range(k) for dx in range(k))


# --- naive conv pair (output channels unrolled by 2, shared input taps) ----

def conv3x3_pair_naive(x, w_even, w_odd):
    """x: [H, W] int8; w_*: [9] int8 per-tap weights for two out channels."""
    taps = _shift_views(x)
    ye = _f(taps[0]) * _f(w_even[0])
    yo = _f(taps[0]) * _f(w_odd[0])
    for t in range(1, 9):
        ye = ye + _f(taps[t]) * _f(w_even[t])
        yo = yo + _f(taps[t]) * _f(w_odd[t])
    return ye, yo


# --- manual packing: what NN2FPGA/FINN do by hand ---------------------------

def conv3x3_pair_manual(x, w_even, w_odd):
    taps = _shift_views(x)
    pa_parts, pb_parts = [], []
    for t in range(9):       # N_max(m=8,c=8)=1 on the i32 lane
        pa, pb = prims.packed_muladd(
            [w_even[t]], [w_odd[t]], [taps[t]], out_dtype="int32")
        pa_parts.append(pa)
        pb_parts.append(pb)
    ye = sum(pa_parts[1:], pa_parts[0])
    yo = sum(pb_parts[1:], pb_parts[0])
    return ye, yo


# --- 4-bit pointwise conv (MobileNet-4b): factor-4 --------------------------

def pw_conv4_naive(x, w4):
    """Pointwise 4-bit conv: 4 output channels share the input pixel.
    x: [N] int8(4-bit values); w4: [4] int8(4-bit)."""
    wh = lambda t: silvia.width_hint(t, 4)
    xx = _f(wh(x))
    return tuple(xx * _f(wh(w4[i])) for i in range(4))


def pw_conv4_manual(x, w4):
    return prims.packed_mul4([w4[0], w4[1], w4[2], w4[3]], x,
                             out_dtypes=("int32",) * 4,
                             a_signed=True, b_signed=True)


def _units(fn, args, passes=None):
    if passes is None:
        closed = jax.make_jaxpr(fn)(*args)
    else:
        closed = silvia.optimized_jaxpr(fn, *args, passes=passes)
    c = opcount.count_ops(closed)
    return c.mul_units + c.madd_units, c


def run():
    rng = np.random.default_rng(2)
    i8 = lambda *s: jnp.asarray(rng.integers(-128, 128, s), jnp.int8)
    i4 = lambda *s: jnp.asarray(rng.integers(-8, 8, s), jnp.int8)
    rows = []

    # ---- ResNet-style 8-bit conv pair (NN2FPGA) ----
    for name in ("ResNet8", "ResNet20"):
        x, we, wo = i8(16, 16), i8(9), i8(9)
        args = (x, we, wo)
        ub, _ = _units(conv3x3_pair_naive, args)
        um, _ = _units(conv3x3_pair_manual, args)
        us, _ = _units(conv3x3_pair_naive, args, PASSES)
        base = conv3x3_pair_naive(*args)
        man = conv3x3_pair_manual(*args)
        auto = silvia.optimize(conv3x3_pair_naive, PASSES)(*args)
        for a, b in zip(base, man):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(base, auto):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert um == us, (name, um, us)   # paper: S matches M exactly
        us_call = time_fn(silvia.optimize(conv3x3_pair_naive, PASSES), *args)
        rows.append({"name": name, "us_per_call": round(us_call, 1),
                     "units_B": ub, "units_M": um, "units_S": us,
                     "match": um == us})

    # ---- CNV-8b (FINN): same mechanism, wider layer ----
    x, we, wo = i8(24, 24), i8(9), i8(9)
    args = (x, we, wo)
    ub, _ = _units(conv3x3_pair_naive, args)
    um, _ = _units(conv3x3_pair_manual, args)
    us, _ = _units(conv3x3_pair_naive, args, PASSES)
    assert um == us
    rows.append({"name": "CNV-8b", "us_per_call": round(
        time_fn(silvia.optimize(conv3x3_pair_naive, PASSES), *args), 1),
        "units_B": ub, "units_M": um, "units_S": us, "match": um == us})

    # ---- MobileNet-4b (FINN): factor-4 pointwise ----
    x4, w4 = i4(512), i4(4)
    args4 = (x4, w4)
    ub, _ = _units(pw_conv4_naive, args4)
    um, _ = _units(pw_conv4_manual, args4)
    us, _ = _units(pw_conv4_naive, args4, PASSES4)
    base = pw_conv4_naive(*args4)
    man = pw_conv4_manual(*args4)
    auto = silvia.optimize(pw_conv4_naive, PASSES4)(*args4)
    for a, b in zip(base, man):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(base, auto):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert um == us
    rows.append({"name": "MobileNet-4b", "us_per_call": round(
        time_fn(silvia.optimize(pw_conv4_naive, PASSES4), *args4), 1),
        "units_B": ub, "units_M": um, "units_S": us, "match": um == us})
    return rows


def print_rows(rows, title):
    print(f"# {title}")
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},"
              f"units B={r['units_B']} M={r['units_M']} S={r['units_S']} "
              f"auto-matches-manual={r['match']}")
