"""Per-op, per-lowering throughput of the kernel registry, plus the cost
of resolution itself.

For every packed op the registry serves, time a jitted dispatch under each
forced lowering and report logical narrow-op throughput -- the Ops/Unit
economics of the paper measured across technology bindings instead of
across DSP shapes.  Also times `registry.resolve()` cold (first call after
`invalidate()`, pays the env parse) and warm (cached), verifying the
satellite claim that resolution is pay-once, not per-trace.

By default only lowerings that run NATIVELY on this host are timed (ref +
cpu-vector on CPU, plus tpu-/gpu-pallas on their own backends);
``--interpret`` adds the foreign Pallas families in interpret mode (their
timings measure the interpreter, not the kernel -- useful only as a
liveness check).

Emits one machine-readable line:  BENCH {json}

    PYTHONPATH=src python -m benchmarks.lowering_matrix [--smoke]
        [--interpret] [--iters N]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ref, registry, timings


def _native_lowerings() -> list:
    native = registry.native_lowering()
    return ["ref"] + ([native] if native else [])


def _cases(smoke: bool):
    """op -> ((args, kwargs), logical narrow-op count per call)."""
    rng = np.random.default_rng(0)
    shape = (64, 128) if smoke else (512, 1024)
    m, k, n = (16, 128, 64) if smoke else (256, 1024, 1024)
    size = int(np.prod(shape))

    i8 = lambda lo, hi, s: jnp.asarray(rng.integers(lo, hi, s), jnp.int8)
    xs = [i8(-128, 128, shape) for _ in range(4)]
    ys = [i8(-128, 128, shape) for _ in range(4)]
    ma = [i8(-8, 8, shape) for _ in range(4)]
    mb = [i8(-8, 8, shape) for _ in range(4)]
    mc = [i8(-128, 128, shape) for _ in range(4)]
    a4 = [i8(-8, 8, shape) for _ in range(4)]
    b4 = i8(-8, 8, shape)
    x_q = i8(-128, 128, (m, k))
    w_q = i8(-128, 128, (k, n))
    w_p = ref.pack_w4(i8(-8, 8, (k, n)))
    x_s = jnp.asarray(rng.random((m, 1)), jnp.float32)
    w_s = jnp.asarray(rng.random((1, n)), jnp.float32)

    return {
        "simd_add": (((xs, ys), {"lane_bits": 8}), 4 * size),
        # chain n=4: 2n muls + 2(n-1) adds per element (paper Eq. 1)
        "muladd2": (((ma, mb, mc), {}), (2 * 4 + 2 * 3) * size),
        "mul4": (((a4, b4), {}), 4 * size),
        "quant_matmul": (((x_q, w_q, x_s, w_s), {}), 2 * m * k * n),
        "packed_w4_matmul": (((x_q, w_p, x_s, w_s), {}), 2 * m * k * n),
    }


def _time_dispatch(op, args, kwargs, lid, iters: int) -> float:
    """us per jitted dispatch under the forced lowering."""
    with registry.force(**{op: lid}):
        fn = jax.jit(lambda *a: registry.dispatch(op, *a, **kwargs))
        out = fn(*args)                      # trace+compile inside force
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _resolution_overhead(iters: int = 200) -> dict:
    registry.invalidate()
    t0 = time.perf_counter()
    registry.resolve("simd_add", lane_bits=8)
    cold_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        registry.resolve("simd_add", lane_bits=8)
    warm_us = (time.perf_counter() - t0) / iters * 1e6
    return {"cold_us": round(cold_us, 2), "warm_us": round(warm_us, 3)}


def run(smoke: bool = False, interpret: bool = False,
        iters: int = 20, record: bool = False) -> dict:
    lids = _native_lowerings()
    if interpret:
        lids += [l for l in ("tpu-pallas", "gpu-pallas") if l not in lids]
    backend = jax.default_backend()
    rows = []
    for op, ((args, kwargs), n_ops) in _cases(smoke).items():
        for lid in lids:
            us = _time_dispatch(op, args, kwargs, lid, iters)
            rows.append({
                "op": op, "lowering": lid, "us_per_call": round(us, 1),
                "gops_s": round(n_ops / us * 1e-3, 2),
            })
            if record and not smoke:
                # persist serving-scale timings only: smoke shapes are
                # the noise PR 4 refused to flip priorities on
                timings.record(backend, op, lid, us, shape="full",
                               iters=iters)
    if record and not smoke:
        registry.invalidate()   # stored winners now steer CPU defaults
    return {
        "config": {"backend": backend, "smoke": smoke,
                   "iters": iters, "lowerings_timed": lids,
                   "recorded": bool(record and not smoke)},
        "active_lowerings": registry.active_lowerings(),
        "resolution": _resolution_overhead(),
        "rows": rows,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, few iters (CI)")
    ap.add_argument("--interpret", action="store_true",
                    help="also time foreign Pallas families in interpret "
                         "mode (liveness check, not a perf number)")
    ap.add_argument("--record", action="store_true",
                    help="persist per-(op, lowering) timings to the "
                         "kernels/timings.py cache so registry auto-"
                         "defaults use measurements (full shapes only; "
                         "--smoke runs never record)")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()
    iters = args.iters or (5 if args.smoke else 20)
    result = run(smoke=args.smoke, interpret=args.interpret, iters=iters,
                 record=args.record)
    print(json.dumps(result, indent=2))
    common.write_bench_json(result, "lowering_matrix")
    print("BENCH " + json.dumps(result))


if __name__ == "__main__":
    main()
