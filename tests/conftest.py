"""Shared fixtures.  NOTE: no XLA_FLAGS here -- tests see the real single
CPU device (tests/test_sharded_serve.py skips itself unless the caller
forces more, as CI's tier1-sharded job does); only launch/dryrun.py
forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(autouse=True, scope="session")
def _hermetic_lowering_timings(tmp_path_factory):
    """Point the stored-lowering-timings cache at an empty per-session
    file: a developer's recorded ~/.cache/repro/lowering_timings.json
    must not steer auto-resolution inside the suite (tests assert the
    no-record default: ref on CPU)."""
    import os
    from repro.kernels import registry, timings
    path = tmp_path_factory.mktemp("timings") / "lowering_timings.json"
    old = os.environ.get("REPRO_LOWERING_TIMINGS")
    os.environ["REPRO_LOWERING_TIMINGS"] = str(path)
    registry.invalidate()
    yield
    if old is None:
        os.environ.pop("REPRO_LOWERING_TIMINGS", None)
    else:
        os.environ["REPRO_LOWERING_TIMINGS"] = old
    registry.invalidate()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
