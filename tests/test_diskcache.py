"""Hardened persistent caches (kernels/diskcache.py and its consumers):
a damaged cache file -- corrupt JSON, truncation, a foreign schema
version, a checksum mismatch, an unwritable filesystem -- must WARN and
recompute, never crash an engine; writes are atomic and merge with
concurrent writers instead of clobbering them."""
import json
import pathlib

import pytest

from repro.kernels import autotune, diskcache, timings


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield tmp_path / "at.json"
    autotune._cache = None


@pytest.fixture
def timings_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_LOWERING_TIMINGS", str(tmp_path / "lt.json"))
    timings.invalidate()
    yield tmp_path / "lt.json"
    timings.invalidate()


# ---------------------------------------------------------------------------
# envelope units
# ---------------------------------------------------------------------------

def test_roundtrip(tmp_path):
    path = tmp_path / "c.json"
    entries = {"k": {"block": [64, 128]}}
    assert diskcache.store(path, 3, entries)
    assert diskcache.load(path, 3) == entries
    doc = json.loads(path.read_text())
    assert doc["schema"] == 3
    assert doc["checksum"] == diskcache.checksum(entries)


def test_missing_file_is_silent_empty(tmp_path, recwarn):
    assert diskcache.load(tmp_path / "never.json", 1) == {}
    assert not [w for w in recwarn if "cache file" in str(w.message)]


@pytest.mark.parametrize("text,why", [
    ("{ this is not json", "corrupt JSON"),
    ('{"schema": 1, "checksum"', "corrupt JSON"),       # truncated write
    ("[1, 2, 3]", "expected a JSON object"),
    ('{"v1:quant_matmul:8": {"block": [1]}}', "schema"),  # legacy flat file
    ('{"schema": 99, "checksum": "x", "entries": {}}', "schema"),
    ('{"schema": 1, "checksum": "sha256:0"}', "missing entries"),
    ('{"schema": 1, "checksum": "sha256:0", "entries": {"a": 1}}',
     "checksum mismatch"),
])
def test_damaged_file_warns_and_returns_empty(tmp_path, text, why):
    path = tmp_path / "c.json"
    path.write_text(text)
    with pytest.warns(UserWarning, match=why):
        assert diskcache.load(path, 1) == {}


def test_checksum_detects_edited_entries(tmp_path):
    path = tmp_path / "c.json"
    diskcache.store(path, 1, {"k": {"block": [64, 128]}})
    doc = json.loads(path.read_text())
    doc["entries"]["k"]["block"] = [9999, 9999]         # manual edit
    path.write_text(json.dumps(doc))
    with pytest.warns(UserWarning, match="checksum mismatch"):
        assert diskcache.load(path, 1) == {}


def test_store_unwritable_returns_false(tmp_path):
    blocker = tmp_path / "file"
    blocker.write_text("")
    # parent "directory" is a file: mkdir and the tempfile both fail
    assert diskcache.store(blocker / "c.json", 1, {}) is False


def test_store_leaves_no_tmp_droppings(tmp_path):
    path = tmp_path / "c.json"
    diskcache.store(path, 1, {"a": 1})
    diskcache.store(path, 1, {"a": 2})
    assert [p.name for p in tmp_path.iterdir()] == ["c.json"]
    assert diskcache.load(path, 1) == {"a": 2}


def test_locked_is_reentrant_across_cycles(tmp_path):
    path = tmp_path / "c.json"
    with diskcache.locked(path):
        diskcache.store(path, 1, {"a": 1})
    with diskcache.locked(path):
        assert diskcache.load(path, 1) == {"a": 1}
    assert pathlib.Path(str(path) + ".lock").exists()


# ---------------------------------------------------------------------------
# consumer integration: damaged caches warn-and-recompute, never raise
# ---------------------------------------------------------------------------

def test_autotune_survives_corrupt_cache(tuner_cache):
    tuner_cache.write_text("{ garbage...")
    with pytest.warns(UserWarning, match="corrupt JSON"):
        assert autotune.lookup("quant_matmul", 8, 128, 256) is None
    # tuning recomputes and replaces the damaged file with a valid envelope
    blk = autotune.tune("quant_matmul", 8, 128, 256,
                        candidates=((128, 128, 256),), iters=1)
    assert blk == (128, 128, 256)
    autotune._cache = None
    assert autotune.lookup("quant_matmul", 8, 128, 256) == blk


def test_autotune_ignores_legacy_flat_cache(tuner_cache):
    # pre-envelope format: entries at top level, no schema/checksum
    tuner_cache.write_text(json.dumps(
        {"v1:quant_matmul:8x128x256:cpu": {"block": [512, 512, 512]}}))
    with pytest.warns(UserWarning, match="schema"):
        assert autotune._load() == {}


def test_autotune_merges_concurrent_writers(tuner_cache):
    autotune.tune("simd_add", 8, 128, candidates=((64, 128),), iters=1)
    # a "second process" that never saw the first's in-memory cache
    autotune._cache = None
    autotune.tune("simd_add", 16, 128, candidates=((32, 128),), iters=1)
    autotune._cache = None                        # re-read the merged file
    assert autotune.lookup("simd_add", 8, 128) == (64, 128)
    assert autotune.lookup("simd_add", 16, 128) == (32, 128)


def test_timings_survive_corrupt_cache(timings_cache):
    timings_cache.write_text('{"schema": 1, "checksum": "nope", '
                             '"entries": {"a": 1}}')
    with pytest.warns(UserWarning, match="checksum mismatch"):
        assert timings.stored_best("packed_w8_matmul", "cpu") is None
    timings.record("cpu", "packed_w8_matmul", "cpu-vector", 12.5,
                   shape="8x128x256", iters=3)
    timings.invalidate()
    assert timings.stored_best("packed_w8_matmul", "cpu") == "cpu-vector"


def test_timings_merge_keeps_fastest(timings_cache):
    timings.record("cpu", "op", "ref", 20.0)
    timings.invalidate()                          # second recorder process
    timings.record("cpu", "op", "ref", 30.0)      # slower: must not clobber
    timings.record("cpu", "op", "cpu-vector", 10.0)
    timings.invalidate()
    entries = timings._load()
    assert entries[timings._key("cpu", "op")]["ref"]["us"] == 20.0
    assert timings.stored_best("op", "cpu") == "cpu-vector"
