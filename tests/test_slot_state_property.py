"""Hypothesis property: a masked decode step leaves every inactive slot's
state bit-identical, for ALL registered slot-state families -- the
invariant the serve engine's slot packing rests on (models/slot_state.py,
models/lm.decode_step `active`)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import slot_state  # noqa: E402
# pytest (prepend import mode) imports sibling test modules top-level
from test_slot_state import (  # noqa: E402
    MASK_FAMILIES, assert_inactive_slots_unchanged, masked_family_setup)

N_SLOTS = 4
_SETUP = {}


def _setup(fam):
    if fam not in _SETUP:
        _SETUP[fam] = masked_family_setup(fam, N_SLOTS)
    return _SETUP[fam]


def test_all_registered_families_covered():
    assert set(MASK_FAMILIES) >= set(slot_state.families()) - {
        "vlm", "sampling"}
    # vlm shares the dense block/cache path verbatim (BLOCK_FNS in lm.py);
    # "sampling" is engine metadata (per-slot RNG key + policy scalars,
    # launch/sampling.py) with no decode step to mask.


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_masked_update_property(data):
    fam = data.draw(st.sampled_from(MASK_FAMILIES), label="family")
    cfg, params, spec, state, step = _setup(fam)
    active = np.asarray(data.draw(
        st.lists(st.booleans(), min_size=N_SLOTS, max_size=N_SLOTS),
        label="active"))
    toks = np.asarray(data.draw(
        st.lists(st.integers(0, cfg.vocab - 1), min_size=N_SLOTS,
                 max_size=N_SLOTS), label="tokens"), np.int32)[:, None]
    pos = np.asarray(data.draw(
        st.lists(st.integers(0, 24), min_size=N_SLOTS, max_size=N_SLOTS),
        label="pos"), np.int32)
    _, new_state = step(params, jnp.asarray(toks), state,
                        jnp.asarray(pos), jnp.asarray(active))
    assert_inactive_slots_unchanged(spec, state, new_state, active, fam)
