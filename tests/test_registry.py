"""Lowering registry: resolution order, capability gating, forced
overrides (env + context), cached resolution with explicit invalidation,
and the compiled-bundle fingerprint."""
import warnings

import jax
import pytest

from repro.kernels import registry


@pytest.fixture
def clean_registry(monkeypatch):
    """Isolate resolution state: no env overrides, empty caches; restore
    the table and drop cached resolutions afterwards."""
    monkeypatch.delenv("REPRO_LOWERING", raising=False)
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    registry.invalidate()
    registry._ensure_loaded()   # snapshot the POPULATED table
    saved = {op: dict(registry._TABLE[op]) for op in registry.ops()}
    yield
    for op in registry.ops():
        registry._TABLE[op].clear()
        registry._TABLE[op].update(saved[op])
    registry.invalidate()


# ---------------------------------------------------------------------------
# table + default resolution
# ---------------------------------------------------------------------------

def test_every_op_registers_all_four_families(clean_registry):
    for op in registry.ops():
        ids = set(registry.lowering_ids(op))
        assert ids == {"tpu-pallas", "gpu-pallas", "cpu-vector", "ref"}, op


def test_default_resolution_is_backend_gated(clean_registry):
    backend = jax.default_backend()
    census = registry.active_lowerings()
    assert set(census) == set(registry.ops())
    # Pallas families auto-select only on their native backends; on CPU
    # the oracle stays the conservative auto-default (cpu-vector sits
    # below ref until measurements justify flipping -- lowerings.py)
    want = {"tpu": "tpu-pallas", "gpu": "gpu-pallas"}.get(backend, "ref")
    assert all(lid == want for lid in census.values()), census


def test_priority_and_predicate_order(clean_registry):
    # a higher-priority lowering whose predicate fails must be skipped...
    registry.register("simd_add", "never", priority=99,
                      predicate=lambda env: False)(lambda *a, **k: None)
    assert registry.resolve("simd_add").lid != "never"
    # ...and one whose predicate passes must win
    registry.register("simd_add", "always", priority=100)(
        lambda *a, **k: None)
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "always"


def test_predicate_sees_resolution_attrs(clean_registry):
    seen = {}

    def pred(env):
        seen["lane_bits"] = env.attr("lane_bits")
        return False

    registry.register("simd_add", "probe", priority=99, predicate=pred)(
        lambda *a, **k: None)
    registry.resolve("simd_add", lane_bits=16)
    assert seen["lane_bits"] == 16


def test_unknown_op_and_duplicate_registration(clean_registry):
    with pytest.raises(KeyError):
        registry.resolve("not_an_op")
    with pytest.raises(KeyError):
        registry.register("not_an_op", "x", priority=0)(lambda: None)
    with pytest.raises(ValueError, match="twice"):
        registry.register("simd_add", "ref", priority=0)(lambda: None)


# ---------------------------------------------------------------------------
# forcing: context manager + env vars
# ---------------------------------------------------------------------------

def test_force_context_scopes_and_nests(clean_registry):
    base = registry.resolve("simd_add").lid
    with registry.force("ref"):
        assert registry.resolve("simd_add").lid == "ref"
        assert registry.resolve("quant_matmul").lid == "ref"
        with registry.force(simd_add="tpu-pallas"):   # inner wins per op
            assert registry.resolve("simd_add").lid == "tpu-pallas"
            assert registry.resolve("quant_matmul").lid == "ref"
        assert registry.resolve("simd_add").lid == "ref"
    assert registry.resolve("simd_add").lid == base


def test_inner_wildcard_force_overrides_outer_per_op(clean_registry):
    """Regression: an inner force("ref") must beat an OUTER per-op force --
    layers are consulted innermost-first, not flattened into one dict."""
    with registry.force(simd_add="tpu-pallas"):
        with registry.force("ref"):
            assert registry.resolve("simd_add").lid == "ref"
        assert registry.resolve("simd_add").lid == "tpu-pallas"


def test_force_context_overrides_env(clean_registry, monkeypatch):
    monkeypatch.setenv("REPRO_LOWERING", "*=tpu-pallas")
    registry.invalidate()
    with registry.force(simd_add="ref"):
        assert registry.resolve("simd_add").lid == "ref"
        assert registry.resolve("mul4").lid == "tpu-pallas"  # env still on
    assert registry.resolve("simd_add").lid == "tpu-pallas"


def test_force_bypasses_predicates(clean_registry):
    # tpu-pallas is not legal on CPU/GPU hosts, but forcing selects it
    # anyway (it runs in interpret mode)
    with registry.force(mul4="tpu-pallas"):
        assert registry.resolve("mul4").lid == "tpu-pallas"


def test_force_rejects_unknown_names(clean_registry):
    with pytest.raises(KeyError):
        with registry.force(not_an_op="ref"):
            pass
    with registry.force(simd_add="no-such-lowering"):
        with pytest.raises(ValueError, match="registered"):
            registry.resolve("simd_add")


def test_env_spec_per_op_and_wildcard(clean_registry, monkeypatch):
    monkeypatch.setenv("REPRO_LOWERING", "simd_add=ref, mul4=tpu-pallas")
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "ref"
    assert registry.resolve("mul4").lid == "tpu-pallas"
    assert registry.resolve("muladd2").lid == \
        registry.active_lowerings()["muladd2"]  # untouched ops auto-resolve
    monkeypatch.setenv("REPRO_LOWERING", "*=ref,quant_matmul=cpu-vector")
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "ref"
    assert registry.resolve("quant_matmul").lid == "cpu-vector"


def test_env_spec_rejects_garbage(clean_registry, monkeypatch):
    monkeypatch.setenv("REPRO_LOWERING", "simd_add")
    registry.invalidate()
    with pytest.raises(ValueError, match="not <op>=<id>"):
        registry.resolve("simd_add")
    monkeypatch.setenv("REPRO_LOWERING", "frobnicate=ref")
    registry.invalidate()
    with pytest.raises(ValueError, match="unknown op"):
        registry.resolve("simd_add")


def test_force_pallas_alias_deprecated(clean_registry, monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    registry.invalidate()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert registry.resolve("simd_add").lid == "tpu-pallas"
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "0")
    registry.invalidate()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert registry.resolve("simd_add").lid == "ref"
    # REPRO_LOWERING wins over the alias when both are set
    monkeypatch.setenv("REPRO_LOWERING", "*=cpu-vector")
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "cpu-vector"
    # ...but a BLANK REPRO_LOWERING counts as unset, not as "force
    # nothing": the alias (still "0" -> ref here) must apply
    monkeypatch.setenv("REPRO_LOWERING", "  ")
    registry.invalidate()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert registry.resolve("simd_add").lid == "ref"
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    registry.invalidate()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert registry.resolve("simd_add").lid == "tpu-pallas"


# ---------------------------------------------------------------------------
# cached resolution + invalidation (the old per-trace env read is gone)
# ---------------------------------------------------------------------------

def test_resolution_is_cached_until_invalidate(clean_registry, monkeypatch):
    before = registry.resolve("simd_add").lid
    # mutating the env WITHOUT invalidate must not change resolution:
    # the env is read once, not per call
    monkeypatch.setenv("REPRO_LOWERING", "*=ref")
    assert registry.resolve("simd_add").lid == before
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "ref"


def test_fingerprint_tracks_forcing(clean_registry):
    base = registry.fingerprint()
    assert base == tuple(sorted(registry.active_lowerings().items()))
    # force an id that is NOT the auto-default on any backend's census
    with registry.force("cpu-vector"):
        forced = registry.fingerprint()
        assert forced != base
        assert dict(forced) == {op: "cpu-vector" for op in registry.ops()}
    assert registry.fingerprint() == base


def test_dispatch_rejects_unknown_op(clean_registry):
    with pytest.raises(KeyError):
        registry.dispatch("not_an_op")


# ---------------------------------------------------------------------------
# stored lowering timings -> measured CPU auto-defaults (kernels/timings.py)
# ---------------------------------------------------------------------------

def test_stored_timings_steer_cpu_defaults(clean_registry, monkeypatch,
                                           tmp_path):
    """A recorded measurement flips the CPU auto-default for that op; ops
    without a record (and every forced resolution) are untouched; deleting
    the record restores the ref fallback."""
    from repro.kernels import timings

    if jax.default_backend() != "cpu":
        pytest.skip("stored timings only steer CPU auto-defaults")
    cache = tmp_path / "lowering_timings.json"
    monkeypatch.setenv("REPRO_LOWERING_TIMINGS", str(cache))
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "ref"   # no record yet

    timings.record("cpu", "simd_add", "cpu-vector", 10.0, shape="full")
    timings.record("cpu", "simd_add", "ref", 25.0, shape="full")
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "cpu-vector"
    # un-recorded ops keep the priority default
    assert registry.resolve("mul4").lid == "ref"
    # forcing still outranks measurements
    with registry.force(simd_add="ref"):
        assert registry.resolve("simd_add").lid == "ref"
    # census/fingerprint reflect the measured default
    assert registry.active_lowerings()["simd_add"] == "cpu-vector"

    cache.unlink()
    registry.invalidate()
    assert registry.resolve("simd_add").lid == "ref"


def test_stored_timings_keep_best_and_ignore_pallas(clean_registry,
                                                    monkeypatch, tmp_path):
    from repro.kernels import timings

    if jax.default_backend() != "cpu":
        pytest.skip("stored timings only steer CPU auto-defaults")
    monkeypatch.setenv("REPRO_LOWERING_TIMINGS",
                       str(tmp_path / "t.json"))
    registry.invalidate()
    # min-keeping merge: the slower later recording must not overwrite
    timings.record("cpu", "mul4", "cpu-vector", 5.0)
    timings.record("cpu", "mul4", "cpu-vector", 50.0)
    assert timings.stored_best("mul4", "cpu") == "cpu-vector"
    # a foreign Pallas family recorded on CPU (interpret-mode timing)
    # must never become the auto-default
    timings.record("cpu", "muladd2", "tpu-pallas", 0.1)
    registry.invalidate()
    assert registry.resolve("muladd2").lid == "ref"


def test_dispatch_counts_census(clean_registry):
    import jax.numpy as jnp

    registry.reset_dispatch_counts()
    assert registry.dispatch_counts() == {op: 0 for op in registry.ops()}
    xs = [jnp.zeros((4, 4), jnp.int8)] * 2
    registry.dispatch("simd_add", xs, xs, lane_bits=8)
    assert registry.dispatch_counts()["simd_add"] == 1
    registry.reset_dispatch_counts()
    assert registry.dispatch_counts()["simd_add"] == 0
