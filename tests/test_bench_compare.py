"""scripts/bench_compare.py: the CI regression gate must fail LOUDLY and
legibly on damaged inputs -- one-line diagnostics, never a traceback, and
never a vacuously-armed gate (a zero baseline would accept any
regression)."""
import importlib.util
import json
import pathlib

import pytest

_SCRIPT = pathlib.Path(__file__).resolve().parents[1] / "scripts" \
    / "bench_compare.py"
_spec = importlib.util.spec_from_file_location("bench_compare", _SCRIPT)
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)


def _payload(tok_s, host_class="test-host"):
    return {"engine": {"agg_tok_s": tok_s}, "host_class": host_class}


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "baselines"
    cur = tmp_path / "results"
    base.mkdir()
    cur.mkdir()
    return base, cur


def _write(d, name, payload):
    (d / f"{name}.json").write_text(
        payload if isinstance(payload, str) else json.dumps(payload))


def test_ok_and_regression(dirs, capsys):
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(100.0))
    _write(cur, "serve_throughput_a", _payload(90.0))
    assert bench_compare.compare(base, cur, 0.30) == 0
    assert "OK serve_throughput_a" in capsys.readouterr().out
    _write(cur, "serve_throughput_a", _payload(50.0))   # > 30% drop
    assert bench_compare.compare(base, cur, 0.30) == 1
    assert "FAIL serve_throughput_a" in capsys.readouterr().out


def test_missing_counterpart_skips(dirs, capsys):
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(100.0))
    assert bench_compare.compare(base, cur, 0.30) == 0
    assert "SKIP serve_throughput_a: no result file" \
        in capsys.readouterr().out


def test_host_class_mismatch_skips(dirs, capsys):
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(100.0, "ci-runner"))
    _write(cur, "serve_throughput_a", _payload(1.0, "laptop"))
    assert bench_compare.compare(base, cur, 0.30) == 0
    assert "host-class mismatch" in capsys.readouterr().out


@pytest.mark.parametrize("junk", ['{"engine": {"agg_tok_s',  # truncated
                                  "not json at all",
                                  "[1, 2, 3]"])               # not an object
def test_corrupt_candidate_fails_one_line(dirs, capsys, junk):
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(100.0))
    _write(cur, "serve_throughput_a", junk)
    assert bench_compare.compare(base, cur, 0.30) == 1   # no traceback
    out = capsys.readouterr().out
    assert "BAD serve_throughput_a" in out
    diag = [ln for ln in out.splitlines() if ln.startswith("BAD")]
    assert len(diag) == 1


def test_corrupt_baseline_fails(dirs, capsys):
    base, cur = dirs
    _write(base, "serve_throughput_a", "{{{")
    _write(cur, "serve_throughput_a", _payload(100.0))
    assert bench_compare.compare(base, cur, 0.30) == 1
    assert "baseline" in capsys.readouterr().out


@pytest.mark.parametrize("bv,cv", [(0.0, 100.0), (100.0, 0.0),
                                   (-5.0, 100.0)])
def test_non_positive_metric_fails(dirs, capsys, bv, cv):
    """A zero baseline floor accepts ANY regression; a zero candidate is
    a broken benchmark run.  Both must fail the gate, not pass it."""
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(bv))
    _write(cur, "serve_throughput_a", _payload(cv))
    assert bench_compare.compare(base, cur, 0.30) == 1
    assert "non-positive metric" in capsys.readouterr().out


def test_non_numeric_metric_skips(dirs, capsys):
    base, cur = dirs
    _write(base, "serve_throughput_a", _payload(100.0))
    _write(cur, "serve_throughput_a",
           {"engine": {"agg_tok_s": "fast"}, "host_class": "test-host"})
    assert bench_compare.compare(base, cur, 0.30) == 0
    assert "no comparable metric" in capsys.readouterr().out


def test_missing_results_dir_fails(dirs, capsys, tmp_path):
    base, _ = dirs
    _write(base, "serve_throughput_a", _payload(100.0))
    missing = tmp_path / "never-created"
    assert bench_compare.compare(base, missing, 0.30) == 1
    assert "does not exist" in capsys.readouterr().out


def test_spec_decode_metric_gates_without_host_class(dirs, capsys):
    """The spec_decode baseline is committed WITHOUT a host_class stamp
    (tokens_per_dispatch is deterministic), so it must compare against a
    stamped candidate instead of skipping."""
    base, cur = dirs
    _write(base, "spec_decode_dense_smoke",
           {"spec_decode": {"tokens_per_dispatch": 10.5}})
    _write(cur, "spec_decode_dense_smoke",
           {"spec_decode": {"tokens_per_dispatch": 10.5},
            "host_class": "test-host"})
    assert bench_compare.compare(base, cur, 0.30) == 0
    assert "OK spec_decode_dense_smoke" in capsys.readouterr().out
    _write(cur, "spec_decode_dense_smoke",
           {"spec_decode": {"tokens_per_dispatch": 1.0},
            "host_class": "test-host"})
    assert bench_compare.compare(base, cur, 0.30) == 1
    assert "FAIL spec_decode_dense_smoke" in capsys.readouterr().out
