"""Property-based prefix-cache testing: for RANDOM shared-prefix traffic
mixes crossed with RANDOM fault schedules, a pooled (warm) engine's
streams must be byte-identical to the cold-cache fault-free run's
(DESIGN.md sec. 10 x sec. 8).

The mix strategy draws, per request, a prefix block, a tail length, and a
tail seed from a SMALL pool -- so the space contains partial prefix
overlaps (chain hits on the chunked dense engine), exact duplicates
(terminal hits, the only sharing a sequential-state family does), and
all-miss traffic.  The fault schedule can land on chunk sites mid-chunked
prefill, on the prefill site of the terminal path, and on decode
segments; recovery re-admits through the (now hot) pool, so replay
itself exercises hit-path admission."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b"}
N_REQ = 6
GENS = (5, 4, 6, 3, 5, 4)
PREFIX_LEN, CHUNK = 8, 4


@pytest.fixture(scope="module")
def setups():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
        out[fam] = (cfg, params)
    return out


def _traffic(cfg, mix):
    """mix: per-request (prefix_id, tail_len, tail_seed) over small pools
    -- duplicates and partial overlaps arise naturally."""
    rng = np.random.default_rng(7)
    prefixes = [rng.integers(0, cfg.vocab, size=PREFIX_LEN, dtype=np.int32)
                for _ in range(2)]
    tails = {}
    reqs = []
    for i, (pid, tlen, tseed) in enumerate(mix):
        key = (tlen, tseed)
        if key not in tails:
            trng = np.random.default_rng(100 + 10 * tlen + tseed)
            tails[key] = trng.integers(0, cfg.vocab, size=tlen,
                                       dtype=np.int32)
        prompt = np.concatenate([prefixes[pid], tails[key]])
        reqs.append(scheduler.Request(rid=i, prompt=prompt,
                                      max_new_tokens=GENS[i],
                                      arrival_time=0.01 * i))
    return reqs


def _run(cfg, params, mix, *, chaos=None, prefix_cache=None):
    eng = ServeEngine(
        params, cfg, n_slots=2, max_cache_len=64, segment_len=4,
        prefill_chunk=CHUNK if cfg.family == "dense" else None,
        chaos=chaos, prefix_cache=prefix_cache)
    out = eng.run(_traffic(cfg, mix), clock=scheduler.FastForwardClock())
    return eng, out


# cold-cache fault-free reference streams per (family, mix): neither the
# drawn fault schedule nor the pool may change a single byte
_REF_CACHE: dict = {}


def _reference(setups, fam, mix):
    key = (fam, mix)
    if key not in _REF_CACHE:
        cfg, params = setups[fam]
        _REF_CACHE[key] = _run(cfg, params, mix)[1]
    return _REF_CACHE[key]


_MIXES = st.lists(
    st.tuples(st.integers(0, 1),        # which shared prefix block
              st.integers(0, 4),        # tail length (0 = exact prefix)
              st.integers(0, 2)),       # tail seed (small pool -> dups)
    min_size=N_REQ, max_size=N_REQ)

_SCHEDULES = st.lists(
    st.tuples(st.sampled_from(sorted(res.ChaosSchedule.SITE_KINDS)),
              st.integers(0, 7)),
    min_size=0, max_size=3, unique=True)


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
@given(mix=_MIXES, sched=_SCHEDULES)
@settings(max_examples=6, deadline=None)
def test_warm_chaos_streams_equal_cold_fault_free(setups, fam, mix, sched):
    mix = tuple(mix)
    cfg, params = setups[fam]
    ref = _reference(setups, fam, mix)
    chaos = None
    if sched:
        chaos = res.ChaosSchedule(
            fail_at_sites=tuple(f"{k}:{i}" for k, i in sched))
    eng, out = _run(cfg, params, mix, chaos=chaos, prefix_cache=64)

    rb = eng.cache_info()["robustness"]
    assert rb["replay_divergence"] == 0
    info = eng.cache_info()["prefix_cache"]
    assert info["hits"] + info["misses"] >= N_REQ

    assert set(out) == set(ref) == set(range(N_REQ))
    for rid in range(N_REQ):
        np.testing.assert_array_equal(np.asarray(out[rid], np.int64),
                                      np.asarray(ref[rid], np.int64))
    assert all(r.outcome == res.OK for r in eng.finished)
