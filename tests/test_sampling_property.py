"""Hypothesis properties for per-request sampling (launch/sampling.py):

* random greedy/temperature/top-p policy mixes under random seeded-rate
  chaos fault schedules must stream BYTE-IDENTICAL tokens to the
  fault-free run (replay recomputes sampled tokens from counter-based
  keys -- DESIGN.md sec. 12's purity obligation); and
* an explicit greedy SamplingParams must equal the argmax (sampling=None)
  bits for ALL four model families -- the `jnp.where` greedy select is
  the literal pre-sampling op, not a temperature->0 limit."""
import numpy as np
import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import resilience as res  # noqa: E402
from repro.launch import scheduler  # noqa: E402
from repro.launch.engine import ServeEngine  # noqa: E402
from repro.models import lm  # noqa: E402

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16
N_REQS = 4
_SETUP = {}


def _setup(fam):
    if fam not in _SETUP:
        cfg = configs.get_reduced_config(FAMILY_ARCHS[fam])
        _SETUP[fam] = (cfg, lm.init_params(jax.random.PRNGKey(0), cfg,
                                           max_seq=80))
    return _SETUP[fam]


def _requests(cfg, mix):
    plens = (5, 12, 9, 7)
    gens = (6, 5, 7, 6)
    reqs = []
    for i in range(N_REQS):
        kw = {}
        if cfg.family == "encdec":
            rng = np.random.default_rng(i)
            kw["features"] = rng.standard_normal(
                (ENC_LEN, cfg.d_model)).astype(np.float32)
        reqs.append(scheduler.Request(
            rid=i,
            prompt=np.asarray(jax.random.randint(
                jax.random.PRNGKey(10 * i), (plens[i],), 0, cfg.vocab)),
            max_new_tokens=gens[i], sampling=mix[i], **kw))
    return reqs


def _engine(cfg, params, **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    return ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                       segment_len=4, **kw)


# fixed menus keep jit cache reuse high across examples (policies are
# device OPERANDS -- values, not shapes -- so any mix shares the graphs)
policy = st.one_of(
    st.none(),
    st.just(scheduler.GREEDY),
    st.builds(scheduler.SamplingParams,
              temperature=st.sampled_from((0.3, 0.8, 1.2)),
              top_k=st.sampled_from((0, 4, 8)),
              top_p=st.sampled_from((0.85, 1.0)),
              seed=st.integers(0, 3)))


@settings(max_examples=5, deadline=None)
@given(mix=st.lists(policy, min_size=N_REQS, max_size=N_REQS),
       chaos_seed=st.integers(0, 100),
       rate=st.sampled_from((0.3, 0.6)))
def test_random_mix_survives_random_chaos_byte_identical(
        mix, chaos_seed, rate):
    cfg, params = _setup("dense")
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, mix), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(rate=rate, seed=chaos_seed, max_failures=3)
    eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg, mix), clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["replay_divergence"] == 0
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_explicit_greedy_equals_argmax_bits_all_families(family):
    cfg, params = _setup(family)
    mix_none = [None] * N_REQS
    mix_greedy = [scheduler.SamplingParams(temperature=0.0)] * N_REQS
    a = _engine(cfg, params).run(_requests(cfg, mix_none),
                                 clock=scheduler.FastForwardClock())
    b = _engine(cfg, params).run(_requests(cfg, mix_greedy),
                                 clock=scheduler.FastForwardClock())
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])
