"""SILVIA pass behaviour: the paper's running examples + legality rules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as silvia
from repro.core import bounds, opcount
from repro.core.prims import silvia_packed_mul4_p


def i8(rng, shape, lo=-128, hi=128):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


def prim_names(closed):
    return [e.primitive.name for e in closed.jaxpr.eqns]


# ---------------------------------------------------------------------------
# Fig. 1 / Fig. 4: two muls with a shared operand -> one packed call
# ---------------------------------------------------------------------------

def test_fig1_running_example(rng):
    def fig1(a0, a1, b):
        c0 = a0.astype(jnp.int32) * b.astype(jnp.int32)
        c1 = a1.astype(jnp.int32) * b.astype(jnp.int32)
        return c0, c1

    args = [i8(rng, (16,)) for _ in range(3)]
    after = silvia.optimized_jaxpr(fig1, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    names = prim_names(after)
    assert names == ["silvia_packed_muladd"], names  # converts DCE'd too
    opt = silvia.optimize(fig1, [silvia.PassConfig(op="muladd")])
    for got, want in zip(opt(*args), fig1(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fig4_alap_rearrangement(rng):
    """Fig. 4a: first use of c0 precedes a1's definition chain -- without
    ALAP there is no insertion point; the pass must still pack."""
    def fn(a0, a1, b):
        c0 = a0.astype(jnp.int32) * b.astype(jnp.int32)
        u0 = c0 + 1           # early use of c0 (the "store")
        c1 = a1.astype(jnp.int32) * b.astype(jnp.int32)
        u1 = c1 + 2
        return u0, u1

    args = [i8(rng, (8,)) for _ in range(3)]
    after = silvia.optimized_jaxpr(fn, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    assert "silvia_packed_muladd" in prim_names(after)
    opt = silvia.optimize(fn, [silvia.PassConfig(op="muladd")])
    for got, want in zip(opt(*args), fn(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dependent_muls_not_packed(rng):
    """c1 depends on c0 -> no valid tuple (independence, sec. 3.2)."""
    def fn(a0, b):
        c0 = a0.astype(jnp.int32) * b.astype(jnp.int32)
        c1 = (c0.astype(jnp.int8)).astype(jnp.int32) * b.astype(jnp.int32)
        return c1

    args = [i8(rng, (8,)) for _ in range(2)]
    after = silvia.optimized_jaxpr(fn, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    assert "silvia_packed_muladd" not in prim_names(after)


def test_no_shared_operand_no_pack(rng):
    def fn(a0, a1, b0, b1):
        return (a0.astype(jnp.int32) * b0.astype(jnp.int32),
                a1.astype(jnp.int32) * b1.astype(jnp.int32))

    args = [i8(rng, (8,)) for _ in range(4)]
    after = silvia.optimized_jaxpr(fn, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    assert "silvia_packed_muladd" not in prim_names(after)


def test_wide_operands_not_packed(rng):
    """16-bit operands exceed the 8-bit muladd lanes."""
    def fn(a0, a1, b):
        return (a0.astype(jnp.int32) * b.astype(jnp.int32),
                a1.astype(jnp.int32) * b.astype(jnp.int32))

    args = [jnp.asarray(rng.integers(-30000, 30000, (8,)), jnp.int16)
            for _ in range(3)]
    after = silvia.optimized_jaxpr(fn, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    assert "silvia_packed_muladd" not in prim_names(after)


# ---------------------------------------------------------------------------
# MAD trees + Eq. 2 chain splitting (sec. 3.3)
# ---------------------------------------------------------------------------

def test_mad_tree_chain_split(rng):
    def trees(a, b, c):
        f = lambda x: x.astype(jnp.int32)
        ta = [f(a[i]) * f(c[i]) for i in range(4)]
        tb = [f(b[i]) * f(c[i]) for i in range(4)]
        pa = (ta[0] + ta[1]) + (ta[2] + ta[3])
        pb = (tb[0] + tb[1]) + (tb[2] + tb[3])
        return pa, pb

    mk = lambda: tuple(i8(rng, (32,)) for _ in range(4))
    args = [mk(), mk(), mk()]
    after = silvia.optimized_jaxpr(trees, *args,
                                   passes=[silvia.PassConfig(op="muladd")])
    names = prim_names(after)
    # 8-bit lanes on the 32-bit unit: N_max = 1 -> 4 packed units + ext adds
    assert names.count("silvia_packed_muladd") == 4
    assert names.count("add") == 6  # external adder tree (2 lanes x 3 adds)
    opt = silvia.optimize(trees, [silvia.PassConfig(op="muladd")])
    for got, want in zip(opt(*args), trees(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mad_tree_4bit_single_chain(rng):
    """4-bit packed operands: Eq. 2 gives N=31 -> one packed unit."""
    def trees(a, b, c):
        f = lambda x: x.astype(jnp.int32)
        wh = lambda x: silvia.width_hint(x, 4)
        ta = [f(wh(a[i])) * f(c[i]) for i in range(4)]
        tb = [f(wh(b[i])) * f(c[i]) for i in range(4)]
        pa = (ta[0] + ta[1]) + (ta[2] + ta[3])
        pb = (tb[0] + tb[1]) + (tb[2] + tb[3])
        return pa, pb

    mk4 = lambda: tuple(i8(rng, (16,), -8, 8) for _ in range(4))
    args = [mk4(), mk4(), tuple(i8(rng, (16,)) for _ in range(4))]
    after = silvia.optimized_jaxpr(
        trees, *args, passes=[silvia.PassConfig(op="muladd", m_bits=4)])
    names = prim_names(after)
    assert names.count("silvia_packed_muladd") == 1
    assert "add" not in names   # absorbed into the in-lane chain
    opt = silvia.optimize(trees, [silvia.PassConfig(op="muladd", m_bits=4)])
    for got, want in zip(opt(*args), trees(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_eq2_paper_parity():
    """The same Eq. 2 that bounds our lanes reproduces the paper's N<=7
    (18-bit low lane, signed 8-bit operands) and the TPU-lane numbers."""
    assert bounds.eq2_max_chain(8, 8, 18, signed=True) == 7     # paper 2.2
    assert bounds.muladd2_max_chain(8, 8) == 1                  # i32 lane
    assert bounds.muladd2_max_chain(4, 8) == 31                 # w4a8
    assert bounds.eq2_max_chain(4, 4, 8, signed=True) == 1


# ---------------------------------------------------------------------------
# SILVIAAdd
# ---------------------------------------------------------------------------

def test_four8_full_tuple(rng):
    def adds(xs, ys):
        return tuple(x + y for x, y in zip(xs, ys))

    xs = tuple(i8(rng, (16,)) for _ in range(4))
    ys = tuple(i8(rng, (16,)) for _ in range(4))
    after = silvia.optimized_jaxpr(
        adds, xs, ys, passes=[silvia.PassConfig(op="add", op_size=8)])
    names = prim_names(after)
    assert names == ["silvia_packed_add"]
    opt = silvia.optimize(adds, [silvia.PassConfig(op="add", op_size=8)])
    for got, want in zip(opt(xs, ys), adds(xs, ys)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_two16_and_sub(rng):
    def subs(x0, y0, x1, y1):
        return x0 - y0, x1 - y1

    args = [jnp.asarray(rng.integers(-30000, 30000, (8,)), jnp.int16)
            for _ in range(4)]
    after = silvia.optimized_jaxpr(
        subs, *args, passes=[silvia.PassConfig(op="add", op_size=16,
                                               inst="sub")])
    assert "silvia_packed_add" in prim_names(after)
    opt = silvia.optimize(subs, [silvia.PassConfig(op="add", op_size=16)])
    for got, want in zip(opt(*args), subs(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_partial_tuple_packs(rng):
    """3 adds still pack into a four8 unit (one idle lane)."""
    def adds(xs, ys):
        return tuple(x + y for x, y in zip(xs, ys))

    xs = tuple(i8(rng, (16,)) for _ in range(3))
    ys = tuple(i8(rng, (16,)) for _ in range(3))
    after = silvia.optimized_jaxpr(
        adds, xs, ys, passes=[silvia.PassConfig(op="add", op_size=8)])
    assert "silvia_packed_add" in prim_names(after)


def test_i32_adds_of_narrow_sources_pack_two16(rng):
    """int8 sources widened to i32: result needs 9 bits -> two16 mode."""
    def adds(x0, y0, x1, y1):
        f = lambda t: t.astype(jnp.int32)
        return f(x0) + f(y0), f(x1) + f(y1)

    args = [i8(rng, (16,)) for _ in range(4)]
    after8 = silvia.optimized_jaxpr(
        adds, *args, passes=[silvia.PassConfig(op="add", op_size=8)])
    assert "silvia_packed_add" not in prim_names(after8)  # 9 bits > 8 lane
    after16 = silvia.optimized_jaxpr(
        adds, *args, passes=[silvia.PassConfig(op="add", op_size=16)])
    assert "silvia_packed_add" in prim_names(after16)
    opt = silvia.optimize(adds, [silvia.PassConfig(op="add", op_size=16)])
    for got, want in zip(opt(*args), adds(*args)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# factor-4 (sec. 2.3) + default pipeline + recursion
# ---------------------------------------------------------------------------

def test_mul4(rng):
    def fn(a, b):
        f = lambda x: silvia.width_hint(x, 4).astype(jnp.int32)
        b4 = f(b)
        return tuple(f(a[i]) * b4 for i in range(4))

    a = tuple(i8(rng, (16,), -8, 8) for _ in range(4))
    b = i8(rng, (16,), -8, 8)
    after = silvia.optimized_jaxpr(fn, a, b,
                                   passes=[silvia.PassConfig(op="mul4")])
    assert prim_names(after).count("silvia_packed_mul4") == 1
    opt = silvia.optimize(fn, [silvia.PassConfig(op="mul4")])
    for got, want in zip(opt(a, b), fn(a, b)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_float_code_untouched(rng):
    def fn(x, y):
        return x * y + jnp.sin(x)

    x = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    before = jax.make_jaxpr(fn)(x, x)
    after = silvia.optimized_jaxpr(fn, x, x)
    assert prim_names(after) == [e.primitive.name for e in before.jaxpr.eqns]


def test_scan_body_optimized(rng):
    def fn(a, b):
        def body(c, xs):
            x, y = xs
            p0 = x.astype(jnp.int32) * y.astype(jnp.int32)
            p1 = (x + 1).astype(jnp.int32) * y.astype(jnp.int32)
            return c + p0.sum() + p1.sum(), p0
        return jax.lax.scan(body, jnp.int32(0), (a, b))

    a, b = i8(rng, (4, 16), -100, 100), i8(rng, (4, 16), -100, 100)
    after = silvia.optimized_jaxpr(fn, a, b,
                                   passes=[silvia.PassConfig(op="muladd")])
    scan_eqn = next(e for e in after.jaxpr.eqns if e.primitive.name == "scan")
    inner = [e.primitive.name for e in scan_eqn.params["jaxpr"].jaxpr.eqns]
    assert "silvia_packed_muladd" in inner
    opt = silvia.optimize(fn, [silvia.PassConfig(op="muladd")])
    for got, want in zip(opt(a, b), fn(a, b)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_optimize_under_jit_grad_compat(rng):
    """The rewritten function must stay jit-compatible."""
    def fn(a0, a1, b):
        return (a0.astype(jnp.int32) * b.astype(jnp.int32)
                + a1.astype(jnp.int32) * b.astype(jnp.int32))

    args = [i8(rng, (8,)) for _ in range(3)]
    opt = jax.jit(silvia.optimize(fn, [silvia.PassConfig(op="muladd")]))
    np.testing.assert_array_equal(np.asarray(opt(*args)),
                                  np.asarray(fn(*args)))


def test_ops_per_unit_metric(rng):
    def fn(a0, a1, b):
        return (a0.astype(jnp.int32) * b.astype(jnp.int32),
                a1.astype(jnp.int32) * b.astype(jnp.int32))

    args = [i8(rng, (8,)) for _ in range(3)]
    before = opcount.count_ops(jax.make_jaxpr(fn)(*args))
    after = opcount.count_ops(silvia.optimized_jaxpr(
        fn, *args, passes=[silvia.PassConfig(op="muladd")]))
    assert before.mul_density == 1.0
    assert after.mul_density == 2.0
    rep = opcount.density_report(before, after)
    assert rep["unit_reduction"] == 0.5
