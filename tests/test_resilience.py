"""Serving resilience: admission control (shedding, deadlines, duplicate
rids), chaos-injected fault recovery with BIT-EXACT replay for every
family (incl. SILVIA passes and the sharded mesh path), non-finite-logit
quarantine with slot scrubbing, drain, and snapshot/restore -- plus the
RestartPolicy backoff and ChaosSchedule parsing units.

The recovery contract under test is DESIGN.md sec. 8: any dispatch may
fail at any site, and every surviving request's token stream must equal
the fault-free run's bitwise (`replay_divergence == 0` is the engine's
own self-check of the same obligation)."""
import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.distributed.fault import RestartPolicy, SimulatedFailure
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_mesh
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16


@pytest.fixture(scope="module")
def family_setup():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
        out[fam] = (cfg, params)
    return out


def _requests(cfg, n=6, seed=0, stagger=0.02, gens=None, ttls=None):
    plens = (5, 12, 9, 16, 7, 11, 6, 14)[:n]
    gens = gens or (8, 6, 9, 5, 10, 7, 8, 6)[:n]
    reqs = []
    for i, (pl, g) in enumerate(zip(plens, gens)):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed + 10 * i), (pl,), 0, cfg.vocab))
        kw = {}
        if cfg.family == "encdec":
            rng = np.random.default_rng(seed + i)
            kw["features"] = rng.standard_normal(
                (ENC_LEN, cfg.d_model)).astype(np.float32)
        if ttls is not None and ttls[i % len(ttls)] is not None:
            kw["deadline"] = stagger * i + ttls[i % len(ttls)]
        reqs.append(scheduler.Request(rid=i, prompt=prompt,
                                      max_new_tokens=g,
                                      arrival_time=stagger * i, **kw))
    return reqs


def _engine(cfg, params, **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    return ServeEngine(params, cfg, **kw)


def _assert_bit_exact(ref, out):
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


# ---------------------------------------------------------------------------
# chaos recovery: bit-exact surviving streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_chaos_recovery_bit_exact(family_setup, family):
    """Faults at segment AND prefill sites mid-traffic: every stream must
    match the fault-free run bitwise, and the engine's own replay check
    must agree (zero divergence)."""
    cfg, params = family_setup[family]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, stagger=0.0), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(
        fail_at_sites=("prefill:0", "segment:2", "segment:5"))
    eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg, stagger=0.0),
                  clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    # prefill:0 and segment:2 always occur; segment:5 only if recovery
    # stretches the run that far (dispatch counts are pace-dependent)
    assert rb["faults_injected"] >= 2
    assert rb["recoveries"] == rb["faults_injected"]
    assert rb["replay_divergence"] == 0
    assert rb["replayed_tokens"] > 0
    assert all(r.outcome == res.OK for r in eng.finished)
    _assert_bit_exact(ref, out)


def test_chaos_recovery_bit_exact_silvia_all(family_setup):
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, silvia_passes="all", chaos=None).run(
        _requests(cfg, stagger=0.0), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("segment:1", "segment:4"))
    eng = _engine(cfg, params, silvia_passes="all", chaos=chaos)
    out = eng.run(_requests(cfg, stagger=0.0),
                  clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_chaos_recovery_bit_exact_chunked_prefill(family_setup):
    """Chunk-site faults (chunked prefill dispatches) recover too."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, prefill_chunk=4, chaos=None).run(
        _requests(cfg, stagger=0.0), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("chunk:1", "segment:3"))
    eng = _engine(cfg, params, prefill_chunk=4, chaos=chaos)
    out = eng.run(_requests(cfg, stagger=0.0),
                  clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert "chunk:1" in chaos.failed       # the chunk-site fault fired
    assert rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_chaos_rate_schedule_bit_exact(family_setup):
    """Deterministic seeded-rate chaos (the $REPRO_CHAOS form CI uses):
    whatever fires, surviving streams stay bit-identical."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, stagger=0.0), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(rate=0.5, seed=7, max_failures=4)
    eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg, stagger=0.0),
                  clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["faults_injected"] >= 1      # rate=0.5 over >=8 sites
    assert rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="sharded chaos needs >1 device (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")
def test_sharded_chaos_recovery_bit_exact(family_setup):
    """Faults under the shard_map'd engine on a (data, model) mesh: the
    rebuilt sharded state must replay to the single-device streams."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, stagger=0.0), clock=scheduler.FastForwardClock())
    dp = min(2, jax.device_count())
    mesh = make_mesh((dp, 1), ("data", "model"))
    chaos = res.ChaosSchedule(fail_at_sites=("segment:2", "prefill:1"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg, stagger=0.0),
                  clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["faults_injected"] == 2 and rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_recovery_budget_exhaustion(family_setup):
    """A request that keeps riding recoveries past max_recoveries ends
    FAILED (structured), never crashes, and the engine still finishes."""
    cfg, params = family_setup["dense"]
    chaos = res.ChaosSchedule(fail_at_sites=tuple(
        f"segment:{i}" for i in range(8)))
    eng = _engine(cfg, params,
                  resilience=res.ResilienceConfig(max_recoveries=1),
                  chaos=chaos)
    eng.run(_requests(cfg, n=2, stagger=0.0, gens=(12, 12)),
            clock=scheduler.FastForwardClock())
    outcomes = {r.rid: r.outcome for r in eng.finished}
    assert res.FAILED in outcomes.values()
    failed = [r for r in eng.finished if r.outcome == res.FAILED]
    assert all("recovery budget" in r.error for r in failed)
    assert all(r.retries > 1 for r in failed)


# ---------------------------------------------------------------------------
# admission control: duplicates, shedding, deadlines
# ---------------------------------------------------------------------------

def test_duplicate_rid_rejected(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None)
    reqs = _requests(cfg, n=2)
    assert eng.submit(reqs[0]) == res.QUEUED
    dup = scheduler.Request(rid=reqs[0].rid, prompt=[1, 2, 3],
                            max_new_tokens=2)
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.submit(dup)
    assert eng.cache_info()["robustness"]["duplicate_rejects"] == 1
    # the original queued request is untouched
    assert eng.n_queued == 1


def test_shed_reject_new(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None,
                  resilience=res.ResilienceConfig(max_queue=2))
    reqs = _requests(cfg, n=4, stagger=0.0)
    outcomes = [eng.submit(r) for r in reqs]
    assert outcomes == [res.QUEUED, res.QUEUED, res.SHED, res.SHED]
    results = eng.results()
    assert results[2].outcome == res.SHED
    assert results[3].outcome == res.SHED
    assert results[2].tokens == []
    # shed requests are finished (structured), not silently dropped
    out = eng.run(clock=scheduler.FastForwardClock())
    assert set(out) == {0, 1, 2, 3}
    assert eng.results()[0].outcome == res.OK
    assert eng.cache_info()["robustness"]["shed"] == 2


def test_shed_drop_oldest(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None,
                  resilience=res.ResilienceConfig(max_queue=2,
                                                  shed_policy="drop-oldest"))
    reqs = _requests(cfg, n=4, stagger=0.0)
    outcomes = [eng.submit(r) for r in reqs]
    # newcomers always queue; the head of the queue is shed to make room
    assert outcomes == [res.QUEUED] * 4
    assert eng.results()[0].outcome == res.SHED
    assert eng.results()[1].outcome == res.SHED
    assert eng.n_queued == 2
    eng.run(clock=scheduler.FastForwardClock())
    assert eng.results()[2].outcome == res.OK
    assert eng.results()[3].outcome == res.OK


def test_deadline_expires_queued(family_setup):
    """A queued request whose deadline passes before a slot frees is
    EXPIRED with zero tokens and never dispatched."""
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None)
    reqs = _requests(cfg, n=3, stagger=0.0)
    reqs[2].deadline = -1.0          # already past at arrival
    for r in reqs:
        eng.submit(r)
    eng.run(clock=scheduler.FastForwardClock())
    assert eng.results()[2].outcome == res.EXPIRED
    assert eng.results()[2].tokens == []
    assert eng.results()[0].outcome == res.OK
    assert eng.cache_info()["robustness"]["expired_queued"] == 1


def test_deadline_cancels_inflight_keeps_partial(family_setup):
    """An in-flight request past its deadline is cancelled between
    segments via slot eviction, keeping the tokens already emitted; its
    co-residents are unperturbed (bitwise)."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, n=3, stagger=0.0, gens=(20, 20, 20)),
        clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, chaos=None)
    reqs = _requests(cfg, n=3, stagger=0.0, gens=(20, 20, 20))
    clock = scheduler.FastForwardClock()
    for r in reqs:
        eng.submit(r)
    eng.step(clock)                      # admit + first segment
    assert eng.n_active == 3
    victim = reqs[1]
    got = len(victim.tokens)
    assert got > 0
    victim.deadline = clock.now() - 1e-6     # lapse it mid-flight
    eng.run(clock=clock)
    assert eng.results()[1].outcome == res.EXPIRED
    # the partial stream is a PREFIX of the fault-free stream (bitwise)
    part = np.asarray(eng.results()[1].tokens)
    np.testing.assert_array_equal(part, np.asarray(ref[1])[:len(part)])
    # survivors still bit-exact
    np.testing.assert_array_equal(np.asarray(reqs[0].tokens), ref[0])
    np.testing.assert_array_equal(np.asarray(reqs[2].tokens), ref[2])
    assert eng.cache_info()["robustness"]["expired_inflight"] == 1


def test_default_ttl_applied_at_submit(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None,
                  resilience=res.ResilienceConfig(default_ttl_s=0.5))
    req = _requests(cfg, n=1)[0]
    eng.submit(req)
    assert req.deadline == req.arrival_time + 0.5
    # an explicit deadline is never overwritten
    eng2 = _engine(cfg, params, chaos=None,
                   resilience=res.ResilienceConfig(default_ttl_s=0.5))
    req2 = _requests(cfg, n=1)[0]
    req2.deadline = 9.0
    eng2.submit(req2)
    assert req2.deadline == 9.0


# ---------------------------------------------------------------------------
# NaN/inf quarantine
# ---------------------------------------------------------------------------

def test_nan_quarantine_isolates_and_scrubs(family_setup):
    """A request with poisoned (NaN) encoder features is FAILED with a
    structured error; co-resident and LATER tenants of the same slot stay
    bit-exact -- proving both masking isolation and the page scrub (a
    stale NaN page would leak: 0 * NaN = NaN)."""
    cfg, params = family_setup["encdec"]
    clean = _requests(cfg, n=4, stagger=0.0)
    ref = _engine(cfg, params, chaos=None, n_slots=2).run(
        clean, clock=scheduler.FastForwardClock())

    reqs = _requests(cfg, n=4, stagger=0.0)
    poison = scheduler.Request(
        rid=99, prompt=[3, 1, 4], max_new_tokens=6, arrival_time=0.0,
        features=np.full((ENC_LEN, cfg.d_model), np.nan, np.float32))
    eng = _engine(cfg, params, chaos=None, n_slots=2)
    for r in [poison] + reqs:
        eng.submit(r)
    out = eng.run(clock=scheduler.FastForwardClock())
    assert eng.results()[99].outcome == res.FAILED
    assert "non-finite" in eng.results()[99].error
    assert eng.cache_info()["robustness"]["quarantined"] == 1
    # with 2 slots the scrubbed slot is certainly reused by a clean
    # request; every clean stream is bit-identical to the poison-free run
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


def test_engine_loop_survives_unexpected_error(family_setup):
    """A real (non-injected) dispatch exception recovers too: the request
    is requeued and replayed, counted under `errors`."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, n=2), clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, chaos=None)
    calls = {"n": 0}
    real = eng._bundle.segment

    class Boom(RuntimeError):
        pass

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] == 2:
            raise Boom("transient device error")
        return real(*a, **k)

    object.__setattr__(eng._bundle, "segment", flaky)
    try:
        out = eng.run(_requests(cfg, n=2),
                      clock=scheduler.FastForwardClock())
    finally:
        object.__setattr__(eng._bundle, "segment", real)
    rb = eng.cache_info()["robustness"]
    assert rb["errors"] == 1 and rb["faults_injected"] == 0
    _assert_bit_exact(ref, out)


# ---------------------------------------------------------------------------
# drain + snapshot/restore
# ---------------------------------------------------------------------------

def test_drain_finishes_inflight_keeps_queued(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None, n_slots=2)
    reqs = _requests(cfg, n=4, stagger=0.0)
    clock = scheduler.FastForwardClock()
    for r in reqs:
        eng.submit(r)
    eng.step(clock)                      # 2 in flight, 2 queued
    assert eng.n_active == 2 and eng.n_queued == 2
    eng.drain(clock)
    assert eng.n_active == 0
    assert eng.n_queued == 2             # fresh requests stay queued
    done = {r.rid for r in eng.finished}
    assert len(done) == 2
    assert eng.cache_info()["robustness"]["drains"] == 1


def test_snapshot_restore_resumes_bit_exact(family_setup, tmp_path):
    """Rolling restart: snapshot mid-flight (partial tokens in slots +
    queued requests), restore into a FRESH engine, finish.  The union of
    streams matches the uninterrupted run bitwise -- device state is
    never serialized, restore replays (DESIGN.md sec. 8)."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None, n_slots=2).run(
        _requests(cfg, n=4, stagger=0.0), clock=scheduler.FastForwardClock())

    eng = _engine(cfg, params, chaos=None, n_slots=2)
    clock = scheduler.FastForwardClock()
    for r in _requests(cfg, n=4, stagger=0.0):
        eng.submit(r)
    eng.step(clock)                      # partial progress
    eng.snapshot(str(tmp_path), step=1)
    done_before = {r.rid: np.asarray(r.tokens, np.int32)
                   for r in eng.finished}

    eng2 = _engine(cfg, params, chaos=None, n_slots=2)
    n = eng2.restore(str(tmp_path))
    assert n + len(done_before) == 4
    out = eng2.run(clock=scheduler.FastForwardClock())
    merged = dict(done_before)
    merged.update(out)
    _assert_bit_exact(ref, merged)
    # restored in-flight requests carried their partial tokens
    assert eng2.cache_info()["robustness"]["restores"] == 1


def test_snapshot_roundtrip_preserves_request_fields(tmp_path):
    reqs = [scheduler.Request(rid=5, prompt=[1, 2, 3], max_new_tokens=9,
                              arrival_time=1.5, stop_tokens=(7,),
                              deadline=4.0)]
    reqs[0].tokens = [11, 12]
    reqs[0].retries = 2
    res.snapshot_requests(str(tmp_path), 0, reqs)
    back = res.restore_requests(str(tmp_path))
    assert len(back) == 1
    r = back[0]
    assert (r.rid, r.max_new_tokens, r.arrival_time) == (5, 9, 1.5)
    assert r.stop_tokens == (7,) and r.deadline == 4.0
    assert r.tokens == [11, 12] and r.retries == 2
    np.testing.assert_array_equal(r.prompt, [1, 2, 3])
    assert res.restore_requests(str(tmp_path / "empty")) == []


# ---------------------------------------------------------------------------
# observability: counters + warm census under chaos
# ---------------------------------------------------------------------------

def test_robustness_counters_reported(family_setup):
    cfg, params = family_setup["dense"]
    eng = _engine(cfg, params, chaos=None)
    info = eng.cache_info()
    assert set(info["robustness"]) >= {
        "shed", "expired_queued", "expired_inflight", "failed",
        "quarantined", "faults_injected", "errors", "recoveries",
        "replayed_tokens", "replay_divergence", "duplicate_rejects",
        "snapshots", "restores", "drains"}
    assert info["resilience"]["chaos"] is None
    assert info["resilience"]["shed_policy"] == "reject-new"


def test_warmup_bounds_graphs_under_chaos(family_setup):
    """A chaos-armed engine's warmup pre-compiles the recovery-replay
    grid too: after a faulty run, no graph key falls outside the warmed
    set and the census stays within graph_bound()."""
    cfg, params = family_setup["dense"]
    chaos = res.ChaosSchedule(fail_at_sites=("segment:1", "segment:3"))
    eng = _engine(cfg, params, chaos=chaos)
    reqs = _requests(cfg, stagger=0.0)
    eng.warmup(prompt_lens=sorted({r.prompt_len for r in reqs}))
    warmed = set(eng._graphs)
    eng.run(reqs, clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["faults_injected"] == 2
    assert eng._graphs == warmed
    assert len(eng._graphs) <= eng.graph_bound()


# ---------------------------------------------------------------------------
# units: queue ops, ChaosSchedule parsing, RestartPolicy backoff
# ---------------------------------------------------------------------------

def test_queue_pop_expired_and_oldest():
    reqs = [scheduler.Request(rid=i, prompt=[1], max_new_tokens=2,
                              arrival_time=float(i)) for i in range(4)]
    reqs[1].deadline = 0.5
    reqs[3].deadline = 0.5       # expires while still "in transit"
    q = scheduler.RequestQueue(reqs)
    dead = q.pop_expired(1.0)
    assert sorted(r.rid for r in dead) == [1, 3]
    assert q.pop_oldest().rid == 0
    assert [r.rid for r in q.pending()] == [2]
    assert scheduler.RequestQueue().pop_oldest() is None


def test_pop_ready_predicate_preserves_order():
    reqs = [scheduler.Request(rid=i, prompt=[1], max_new_tokens=2)
            for i in range(3)]
    reqs[1].tokens = [42]        # mid-recovery request
    q = scheduler.RequestQueue(reqs)
    got = q.pop_ready(0.0, limit=5, predicate=lambda r: bool(r.tokens))
    assert [r.rid for r in got] == [1]
    assert [r.rid for r in q.pending()] == [0, 2]


def test_chaos_schedule_parse():
    cs = res.ChaosSchedule.parse("segment:1;prefill:0,rate=0.25,seed=3,max=2")
    assert cs.fail_at_sites == ("segment:1", "prefill:0")
    assert (cs.rate, cs.seed, cs.max_failures) == (0.25, 3, 2)
    with pytest.raises(ValueError, match="bad site"):
        res.ChaosSchedule.parse("decode:1")
    with pytest.raises(ValueError, match="unknown key"):
        res.ChaosSchedule.parse("pace=0.5")
    with pytest.raises(SimulatedFailure):
        res.ChaosSchedule.parse("chunk:0").check_site("chunk:0")
    # fires at most once per site
    cs2 = res.ChaosSchedule.parse("chunk:0")
    with pytest.raises(SimulatedFailure):
        cs2.check_site("chunk:0")
    cs2.check_site("chunk:0")
    # max_failures caps rate-driven injections
    cs3 = res.ChaosSchedule(rate=1.0, max_failures=1)
    with pytest.raises(SimulatedFailure):
        cs3.check_site("segment:0")
    cs3.check_site("segment:1")


def test_chaos_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    assert res.chaos_from_env() is None
    monkeypatch.setenv("REPRO_CHAOS", "rate=0.1,seed=2")
    cs = res.chaos_from_env()
    assert cs.rate == 0.1 and cs.seed == 2


def test_restart_policy_backoff_and_reset():
    p = RestartPolicy(max_restarts=10, backoff_s=1.0, max_backoff_s=6.0,
                      jitter=0.0)
    seen = []
    for p.streak in (0, 1, 2, 3):
        seen.append(p.next_backoff())
    assert seen == [1.0, 2.0, 4.0, 6.0]           # doubled, then capped
    p.streak = 2
    p.reset()
    assert p.streak == 0 and p.next_backoff() == 1.0


def test_restart_policy_jitter_deterministic():
    a = RestartPolicy(backoff_s=1.0, jitter=0.5, seed=3)
    b = RestartPolicy(backoff_s=1.0, jitter=0.5, seed=3)
    c = RestartPolicy(backoff_s=1.0, jitter=0.5, seed=4)
    assert a.next_backoff() == b.next_backoff()   # reproducible
    assert a.next_backoff() != c.next_backoff()   # de-synchronized
    assert 1.0 <= a.next_backoff() < 1.5


def test_restart_policy_counts_granted_only():
    p = RestartPolicy(max_restarts=2)
    exc = SimulatedFailure("x")
    assert p.should_restart(exc) and p.should_restart(exc)
    # refusals do not burn attempts: restarts stays at the cap
    assert not p.should_restart(exc)
    assert not p.should_restart(exc)
    assert p.restarts == 2
