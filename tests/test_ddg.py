"""DDG / initiation-interval analysis (paper sec. 3.5.1, Fig. 5)."""
import jax
import jax.numpy as jnp

from repro.core import ddg


def test_fig5_packing_raises_ii():
    """Paper Fig. 5: nodes a,b,c,d; packing {a,b} adds a critical cycle.

        a = x + y ; b = x + d_prev ; c = w * a ; d = c + b
    """
    lat = [1, 1, 1, 1]                 # a, b, c, d
    edges = [
        (0, 2, 0),                     # a -> c
        (2, 3, 0),                     # c -> d
        (1, 3, 0),                     # b -> d
        (3, 1, 1),                     # d -> b (loop carried, distance 1)
    ]
    g = ddg.ddg_from_edges(lat, edges)
    assert g.ii_min() == 2             # cycle b->d->b: latency 2 / distance 1
    g2 = g.with_merged([0, 1])         # pack a and b into one super-node
    assert g2.ii_min() == 3            # new cycle (ab)->c->d->(ab): 3/1
    assert ddg.would_increase_ii(g, [0, 1])


def test_acyclic_ii_is_one():
    g = ddg.ddg_from_edges([1, 1, 1], [(0, 1, 0), (1, 2, 0)])
    assert g.ii_min() == 1


def test_long_latency_cycle():
    # cycle with total latency 6 over distance 2 -> II = 3
    g = ddg.ddg_from_edges([3, 3], [(0, 1, 0), (1, 0, 2)])
    assert g.ii_min() == 3


def test_merge_preserves_acyclicity():
    g = ddg.ddg_from_edges([1, 1, 1, 1], [(0, 2, 0), (1, 3, 0)])
    assert g.ii_min() == 1
    assert not ddg.would_increase_ii(g, [0, 1])


def test_ddg_from_scan_body():
    """Build the Fig. 5 pattern as a real jax scan and analyze its body."""
    def body(d, xy):
        x, y = xy
        a = x + y
        b = x + d
        c = 3 * a
        d_new = c + b
        return d_new, d_new

    closed = jax.make_jaxpr(
        lambda xs, ys: jax.lax.scan(body, jnp.int32(0), (xs, ys)))(
            jnp.arange(4, dtype=jnp.int32), jnp.arange(4, dtype=jnp.int32))
    scan_eqn = next(e for e in closed.jaxpr.eqns
                    if e.primitive.name == "scan")
    sub = scan_eqn.params["jaxpr"]
    g = ddg.ddg_from_scan_body(sub, num_carry=scan_eqn.params["num_carry"],
                               num_consts=scan_eqn.params["num_consts"])
    assert g.ii_min() == 2
    # find the two adds feeding the carry (a-equivalent and b-equivalent)
    names = [e.primitive.name for e in sub.jaxpr.eqns]
    a_idx = names.index("add")                  # first add (a = x + y)
    b_idx = names.index("add", a_idx + 1)       # second add (b = x + d)
    merged = g.with_merged([a_idx, b_idx])
    assert merged.ii_min() == 3
