"""Sharded serve: bit-exact parity of the mesh-aware engine against the
single-device engine (and hence static generate()) for every family.

Runs only when more than one device is visible -- CI's tier1-sharded job
sets XLA_FLAGS=--xla_force_host_platform_device_count=8; locally:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_sharded_serve.py

The matrix covers all four serve families (dense, ssm, hybrid, encdec),
data-only and data x model meshes (head/state tensor parallelism active
where the reduced configs divide the model axis), forced `*=ref` and
auto lowerings, SILVIA passes, and admission/eviction/compaction
mid-segment.  Equality is BITWISE on tokens: the sharded engine's only
collectives are exact concats (all_gather), never partitioned float
contractions (launch/engine.py module docstring, DESIGN.md sec. 7)."""
import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.kernels import registry
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_mesh
from repro.models import lm, slot_state
from repro.quant.qtensor import quantize_tree_for_serving

NDEV = jax.device_count()
pytestmark = pytest.mark.skipif(
    NDEV < 2,
    reason="sharded serve needs >1 device (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")

# (data, model) shapes testable on this host: data-only packing plus a
# data x model mix in both orientations (4,2) activates attention TP on
# the reduced GQA configs (n_kv=2), (2,4) activates SSD TP (8 heads)
MESHES = ([(8, 1), (2, 4), (4, 2)] if NDEV >= 8
          else [(NDEV, 1)])

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16


@pytest.fixture(scope="module")
def family_setup():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = quantize_tree_for_serving(
            lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80),
            "w8a8", force=True)
        out[fam] = (cfg, params)
    return out


def _requests(cfg, n=5, seed=0, stagger=0.02):
    """Ragged mix on purpose: more requests than slots (eviction +
    re-admission mid-run), staggered arrivals, varied prompt/gen."""
    plens = (5, 12, 9, 16, 7)[:n]
    gens = (3, 8, 1, 6, 9)[:n]
    reqs = []
    for i, (pl, g) in enumerate(zip(plens, gens)):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed + 10 * i), (pl,), 0, cfg.vocab))
        kw = {}
        if cfg.family == "encdec":
            rng = np.random.default_rng(seed + i)
            kw["features"] = rng.standard_normal(
                (ENC_LEN, cfg.d_model)).astype(np.float32)
        reqs.append(scheduler.Request(rid=i, prompt=prompt,
                                      max_new_tokens=g,
                                      arrival_time=stagger * i, **kw))
    return reqs


def _engine(cfg, params, *, mesh_shape=None, n_slots=2, segment_len=4,
            **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    if mesh_shape is None:
        return ServeEngine(params, cfg, n_slots=n_slots, max_cache_len=64,
                           segment_len=segment_len, **kw)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        return ServeEngine(params, cfg, n_slots=max(n_slots, mesh_shape[0]),
                           max_cache_len=64, segment_len=segment_len, **kw)


def _run(eng, reqs):
    return eng.run(reqs, scheduler.FastForwardClock())


# ---------------------------------------------------------------------------
# the parity matrix: every family x every mesh shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", MESHES)
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_sharded_matches_single_device(family_setup, family, mesh_shape):
    cfg, params = family_setup[family]
    base = _run(_engine(cfg, params), _requests(cfg))
    eng = _engine(cfg, params, mesh_shape=mesh_shape)
    out = _run(eng, _requests(cfg))
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])
    info = eng.cache_info()
    assert info["graphs"] <= info["graph_bound"]
    # the serving mesh accounts for every device: the live (possibly
    # degraded under a $REPRO_CHAOS device-loss arm) extents multiply to
    # the healthy count, and healthy + dead is the original mesh
    assert info["mesh"]["dp_size"] * info["mesh"]["shape"]["model"] \
        == info["mesh"]["n_devices"]
    assert info["mesh"]["n_devices"] + len(info["mesh"]["dead_devices"]) \
        == mesh_shape[0] * mesh_shape[1]


def test_tp_actually_activates():
    """The matrix above must not pass vacuously: on an 8-device host the
    (4,2) mesh tensor-parallelizes attention for the GQA configs and
    (2,4) the SSD heads (slot_state.tp_plan)."""
    if NDEV < 8:
        pytest.skip("needs 8 devices for the data x model shapes")
    assert slot_state.tp_plan(
        configs.get_reduced_config("jamba-v0.1-52b"), 2).attn
    assert slot_state.tp_plan(
        configs.get_reduced_config("mamba2-2.7b"), 4).ssm
    assert slot_state.tp_plan(
        configs.get_reduced_config("whisper-small"), 4).attn
    # and non-divisible head counts degrade gracefully to replication
    plan = slot_state.tp_plan(configs.get_reduced_config("smollm-135m"), 4)
    assert not plan.attn and not plan.ssm


# ---------------------------------------------------------------------------
# forced lowerings + SILVIA passes through the sharded bundles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_sharded_forced_ref_matches(family_setup, family):
    """REPRO_LOWERING-style forcing pins the sharded bundle's census the
    same way it pins the single-device one."""
    cfg, params = family_setup[family]
    mesh_shape = MESHES[-1]
    with registry.force("ref"):
        base = _run(_engine(cfg, params), _requests(cfg, n=3))
        eng = _engine(cfg, params, mesh_shape=mesh_shape)
        out = _run(eng, _requests(cfg, n=3))
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])
    assert all(lid == "ref" for lid in
               eng.cache_info()["lowerings"].values())


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_sharded_silvia_all_matches(family_setup, family):
    cfg, params = family_setup[family]
    mesh_shape = MESHES[-1]
    base = _run(_engine(cfg, params, silvia_passes="all"),
                _requests(cfg, n=3))
    out = _run(_engine(cfg, params, mesh_shape=mesh_shape,
                       silvia_passes="all"), _requests(cfg, n=3))
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])


# ---------------------------------------------------------------------------
# admission / eviction / compaction on sharded state
# ---------------------------------------------------------------------------

def test_sharded_compaction_preserves_outputs(family_setup):
    """Evictions leave holes; compaction permutes SHARDED slot pages
    downward and the surviving request stays bit-identical."""
    cfg, params = family_setup["dense"]
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(40 + i),
                                             (8,), 0, cfg.vocab))
               for i in range(4)]
    gens = (2, 2, 2, 12)   # slots 0..2 evict early -> holes under slot 3

    def reqs():
        return [scheduler.Request(rid=i, prompt=prompts[i],
                                  max_new_tokens=g)
                for i, g in enumerate(gens)]

    base = _run(ServeEngine(params, cfg, n_slots=4, max_cache_len=64,
                            segment_len=2), reqs())
    # dp=2 so the bucket CAN shrink (4 -> 2); a dp=4 floor would make
    # every hole bucket-neutral and compaction correctly skip itself
    mesh_shape = (2, 4) if NDEV >= 8 else (2, 1)
    eng = _engine(cfg, params, mesh_shape=mesh_shape, n_slots=4,
                  segment_len=2)
    out = _run(eng, reqs())
    assert eng.compactions >= 1
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])
    # the post-compaction segment ran at the dp-floored shrunken bucket
    dp = eng.cache_info()["mesh"]["dp_size"]
    seg_bbs = {k[1] for k in eng._graphs if k[0] == "segment"}
    assert min(seg_bbs) == dp, (seg_bbs, dp)


def test_sharded_chunked_prefill_matches(family_setup):
    cfg, params = family_setup["dense"]
    base = _run(_engine(cfg, params, prefill_chunk=4), _requests(cfg))
    out = _run(_engine(cfg, params, mesh_shape=MESHES[-1],
                       prefill_chunk=4), _requests(cfg))
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_indivisible_slots_rejected(family_setup):
    cfg, params = family_setup["dense"]
    mesh = make_mesh(MESHES[0], ("data", "model"))
    dp = MESHES[0][0]
    with dctx.mesh_scope(mesh, ("data",), "model"):
        with pytest.raises(ValueError, match="multiple"):
            ServeEngine(params, cfg, n_slots=dp + 1, max_cache_len=64)


def test_unmeshed_engine_unchanged(family_setup):
    """No ambient mesh_scope -> plain single-device bundles, no mesh info
    in the census."""
    cfg, params = family_setup["dense"]
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64)
    assert "mesh" not in eng.cache_info()
