"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting output shapes and finiteness, plus prefill/decode
consistency in f32."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init
from repro.training import TrainConfig, make_train_step

B, S = 2, 32


def _inputs(cfg, rng_key):
    if cfg.family == "encdec":
        return {"audio": jnp.zeros((B, S, cfg.d_model), jnp.float32),
                "tokens": jax.random.randint(rng_key, (B, S // 4 + 1), 0,
                                             cfg.vocab)}
    if cfg.frontend == "vision":
        return {"embeds": jax.random.normal(rng_key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(rng_key, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(rng_key, (B, S + 1), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg, max_seq=S * 2)
    batch = _inputs(cfg, rng)
    if cfg.family == "encdec":
        logits, _ = lm.forward(params, (batch["audio"],
                                        batch["tokens"][:, :-1]), cfg)
        assert logits.shape == (B, S // 4, cfg.vocab)
    elif cfg.frontend == "vision":
        logits, _ = lm.forward(params, batch["embeds"], cfg)
        assert logits.shape == (B, S, cfg.vocab)
    else:
        logits, _ = lm.forward(params, batch["tokens"][:, :-1], cfg)
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_one_train_step(arch):
    cfg = configs.get_reduced_config(arch)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), remat=False)
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(rng, cfg, max_seq=S * 2)
    opt = adamw_init(params, tcfg.optimizer)
    step = make_train_step(cfg, tcfg)
    params2, opt2, metrics = step(params, opt, _inputs(cfg, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2["step"]) == 1
    # params must actually change
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l[0].astype(jnp.float32)
                                       - l[1].astype(jnp.float32)).sum()),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, params2),
        0.0)
    assert delta > 0


DECODE_ARCHS = [a for a in configs.ARCHS]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency_f32(arch):
    cfg = dataclasses.replace(configs.get_reduced_config(arch),
                              dtype="float32")
    if cfg.moe is not None:
        # capacity-based token dropping is batch-size dependent by design;
        # disable drops so prefill-vs-decode routing is identical
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    rng = jax.random.PRNGKey(42)
    params = lm.init_params(rng, cfg, max_seq=S * 2)
    if cfg.family == "encdec":
        audio = jax.random.normal(rng, (B, S, cfg.d_model))
        toks = jax.random.randint(rng, (B, S // 4 + 1), 0, cfg.vocab)
        n = S // 4
        lg_full, _ = lm.forward(params, (audio, toks), cfg, remat=False)
        lg_pref, cache = lm.prefill(params, (audio, toks[:, :n]), cfg,
                                    cache_len=n + 4)
        lg_dec, _ = lm.decode_step(params, toks[:, n:n + 1], cache,
                                   jnp.full((B,), n, jnp.int32), cfg)
        scale = float(jnp.abs(lg_full).max())
        assert float(jnp.abs(lg_pref[:, 0] - lg_full[:, n - 1]).max()) \
            < 1e-4 * scale + 1e-5
        assert float(jnp.abs(lg_dec[:, 0] - lg_full[:, n]).max()) \
            < 1e-4 * scale + 1e-5
        return
    if cfg.frontend == "vision":
        # stub frontend: prefill from embeddings, decode from tokens
        emb = jax.random.normal(rng, (B, S, cfg.d_model))
        lg_pref, cache = lm.prefill(params, emb, cfg, cache_len=S + 4)
        lg_dec, _ = lm.decode_step(params, jnp.zeros((B, 1), jnp.int32),
                                   cache, jnp.full((B,), S, jnp.int32), cfg)
        assert bool(jnp.isfinite(lg_dec).all())
        return
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    lg_full, _ = lm.forward(params, toks, cfg, remat=False)
    lg_pref, cache = lm.prefill(params, toks[:, :S], cfg, cache_len=S + 4)
    lg_dec, _ = lm.decode_step(params, toks[:, S:S + 1], cache,
                               jnp.full((B,), S, jnp.int32), cfg)
    scale = float(jnp.abs(lg_full).max())
    assert float(jnp.abs(lg_pref[:, 0] - lg_full[:, S - 1]).max()) \
        < 1e-4 * scale + 1e-5
    assert float(jnp.abs(lg_dec[:, 0] - lg_full[:, S]).max()) \
        < 1e-4 * scale + 1e-5


def test_param_count_analytic_matches_init():
    """Analytic param_count (used for MODEL_FLOPS) vs actual init sizes."""
    for arch in configs.ARCHS:
        cfg = configs.get_reduced_config(arch)
        params = jax.eval_shape(
            lambda c=cfg: lm.init_params(jax.random.PRNGKey(0), c,
                                         max_seq=64))
        actual = sum(np.prod(l.shape) for l in
                     jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        # analytic model ignores small vectors (norms, biases, pos embeds)
        assert abs(actual - analytic) / actual < 0.25, (
            arch, actual, analytic)
