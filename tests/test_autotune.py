"""Block-size autotuner: tune -> persist -> reload, and kernel integration
via block=None (opt-in: defaults stay untouched when disabled)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import autotune, quant_matmul


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield
    autotune._cache = None   # don't leak tmp cache into other tests


def test_disabled_resolve_is_default(tuner_cache, monkeypatch):
    monkeypatch.setattr(autotune, "_enabled", False)
    assert autotune.resolve("quant_matmul", 8, 128, 256) == \
        autotune.DEFAULT_BLOCK


def test_tune_persists_and_reloads(tuner_cache):
    fast = ((128, 128, 256), (256, 256, 512))
    blk = autotune.tune("quant_matmul", 8, 128, 256, candidates=fast,
                        iters=1)
    assert blk in fast
    assert autotune.lookup("quant_matmul", 8, 128, 256) == blk
    autotune._cache = None                       # force re-read from disk
    assert autotune.lookup("quant_matmul", 8, 128, 256) == blk
    # resolve() now serves the persisted winner even with tuning disabled
    assert autotune.resolve("quant_matmul", 8, 128, 256) == blk


def test_block_none_uses_tuned_block_and_stays_correct(tuner_cache, rng):
    autotune.tune("quant_matmul", 8, 128, 256,
                  candidates=((128, 128, 256),), iters=1)
    x = jnp.asarray(rng.integers(-128, 128, (8, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (128, 256)), jnp.int8)
    got = quant_matmul.quant_matmul_acc(x, w)    # block=None -> tuned
    want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
