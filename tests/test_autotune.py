"""Block-size autotuner: tune -> persist -> reload, and kernel integration
via block=None (opt-in: defaults stay untouched when disabled) -- for the
GEMMs and the SWAR kernels (simd_add / mul4 / muladd2)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import (autotune, common, mul4, muladd2, quant_matmul,
                           ref, simd_add)


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield
    autotune._cache = None   # don't leak tmp cache into other tests


def test_disabled_resolve_is_default(tuner_cache, monkeypatch):
    monkeypatch.setattr(autotune, "_enabled", False)
    assert autotune.resolve("quant_matmul", 8, 128, 256) == \
        autotune.DEFAULT_BLOCK


def test_tune_persists_and_reloads(tuner_cache):
    fast = ((128, 128, 256), (256, 256, 512))
    blk = autotune.tune("quant_matmul", 8, 128, 256, candidates=fast,
                        iters=1)
    assert blk in fast
    assert autotune.lookup("quant_matmul", 8, 128, 256) == blk
    autotune._cache = None                       # force re-read from disk
    assert autotune.lookup("quant_matmul", 8, 128, 256) == blk
    # resolve() now serves the persisted winner even with tuning disabled
    assert autotune.resolve("quant_matmul", 8, 128, 256) == blk


def test_block_none_uses_tuned_block_and_stays_correct(tuner_cache, rng):
    autotune.tune("quant_matmul", 8, 128, 256,
                  candidates=((128, 128, 256),), iters=1)
    x = jnp.asarray(rng.integers(-128, 128, (8, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (128, 256)), jnp.int8)
    got = quant_matmul.quant_matmul_acc(x, w)    # block=None -> tuned
    want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------------------
# SWAR kernel coverage (2-D blocks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,dims", [
    ("simd_add", (8, 128)),
    ("mul4", (32, 128)),
    ("mul4_split", (32, 128)),
    ("muladd2", (2, 32, 128)),
])
def test_swar_tune_persists_and_reloads(tuner_cache, kind, dims):
    blk = autotune.tune(kind, *dims, candidates=((64, 128),), iters=1)
    assert blk == (64, 128)
    assert autotune.lookup(kind, *dims) == blk
    autotune._cache = None                       # force re-read from disk
    assert autotune.resolve(kind, *dims) == blk


def test_swar_disabled_resolve_is_2d_default(tuner_cache, monkeypatch):
    monkeypatch.setattr(autotune, "_enabled", False)
    for kind in ("simd_add", "mul4", "muladd2"):
        assert autotune.resolve(kind, 8, 128) == autotune.DEFAULT_BLOCK_2D


def test_cache_keys_separate_lowering_and_mode(tuner_cache):
    """Regression (v1 -> v2 keys): entries tuned for one lowering or
    execution mode must never shadow another -- interpret-mode CPU tuning
    used to collide with real TPU timings for the same shapes."""
    autotune.tune("quant_matmul", 8, 128, 256, candidates=((128, 128, 256),),
                  iters=1, lowering="tpu-pallas", interpret=True)
    # same kind+shape, different lowering / mode: all misses
    assert autotune.lookup("quant_matmul", 8, 128, 256,
                           lowering="gpu-pallas", interpret=True) is None
    assert autotune.lookup("quant_matmul", 8, 128, 256,
                           lowering="tpu-pallas", interpret=False) is None
    assert autotune.lookup("quant_matmul", 8, 128, 256,
                           lowering="tpu-pallas", interpret=True) == \
        (128, 128, 256)
    # the gpu lowering tunes into its own slot without clobbering
    autotune.tune("quant_matmul", 8, 128, 256, candidates=((64, 64, 64),),
                  iters=1, lowering="gpu-pallas", interpret=True)
    assert autotune.lookup("quant_matmul", 8, 128, 256,
                           lowering="gpu-pallas", interpret=True) == \
        (64, 64, 64)
    assert autotune.lookup("quant_matmul", 8, 128, 256,
                           lowering="tpu-pallas", interpret=True) == \
        (128, 128, 256)
    # every persisted key carries the v2 version tag
    assert all(k.startswith(f"v{autotune.CACHE_VERSION}:")
               for k in autotune._load())
    # non-Pallas lowerings have no tunable kernels: timing one would
    # persist a mislabeled entry, so tune() refuses outright
    with pytest.raises(ValueError, match="tunable"):
        autotune.tune("quant_matmul", 8, 128, 256, lowering="cpu-vector")


def test_simd_add_block_none_stays_correct(tuner_cache, rng):
    autotune.tune("simd_add", 8, 128, candidates=((64, 128),), iters=1)
    x = jnp.asarray(rng.integers(0, 1 << 32, (8, 128), dtype=np.uint32))
    y = jnp.asarray(rng.integers(0, 1 << 32, (8, 128), dtype=np.uint32))
    got = simd_add.simd_add_packed(x, y)         # block=None -> tuned
    lanes = zip(common.unpack_lanes(x, 8), common.unpack_lanes(y, 8))
    want = common.pack_lanes([a + b for a, b in lanes], 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_muladd2_block_none_stays_correct(tuner_cache, rng):
    autotune.tune("muladd2", 2, 32, 128, candidates=((64, 128),), iters=1)
    a = jnp.asarray(rng.integers(-8, 8, (2, 32, 128)), jnp.int8)
    b = jnp.asarray(rng.integers(-8, 8, (2, 32, 128)), jnp.int8)
    c = jnp.asarray(rng.integers(-128, 128, (2, 32, 128)), jnp.int8)
    pa, pb = muladd2.muladd2(a, b, c)            # block=None -> tuned
    ra, rb = ref.muladd2_ref(list(a), list(b), list(c))
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(rb))


def test_mul4_block_none_stays_correct(tuner_cache, rng):
    # full32 and split tune as SEPARATE kinds (different cost profiles)
    autotune.tune("mul4", 32, 128, candidates=((64, 128),), iters=1)
    autotune.tune("mul4_split", 32, 128, candidates=((128, 256),), iters=1)
    assert autotune.lookup("mul4", 32, 128) == (64, 128)
    assert autotune.lookup("mul4_split", 32, 128) == (128, 256)
    a = jnp.asarray(rng.integers(-8, 8, (4, 32, 128)), jnp.int8)
    b = jnp.asarray(rng.integers(-8, 8, (32, 128)), jnp.int8)
    want = ref.mul4_ref(list(a), b)
    for got in (mul4.mul4_full32(a, b),          # block=None -> tuned
                mul4.mul4_split(a, b)):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
