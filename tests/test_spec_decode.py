"""Self-speculative decoding (engine.SpecDecodeConfig): draft k tokens
per slot, verify all k in ONE batched target dispatch, accept/rollback as
a masked slot-state update -- SILVIA's pack-then-check rewrite at the
serve-loop level (DESIGN.md sec. 12).

The invariant every test leans on: emitted tokens are always the TARGET's
tokens under a teacher-forced prefix, so spec streams are byte-identical
to the non-speculative engine regardless of draft quality -- acceptance
only changes tokens-per-dispatch.  Run the mesh cases with
XLA_FLAGS=--xla_force_host_platform_device_count=8."""
import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine, SpecDecodeConfig
from repro.launch.mesh import make_mesh
from repro.models import lm

SP = scheduler.SamplingParams(temperature=0.8, top_k=6, seed=5)
MIX = (None, SP, scheduler.GREEDY, SP)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_reduced_config("smollm-135m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
    # same config, DIFFERENT weights: a draft that is frequently wrong,
    # exercising partial-acceptance rollback on every round
    weak = lm.init_params(jax.random.PRNGKey(9), cfg, max_seq=80)
    return cfg, params, weak


def _requests(cfg, n=6, stagger=0.0, mix=MIX):
    plens = (5, 12, 9, 16, 7, 11)[:n]
    gens = (8, 6, 9, 5, 10, 7)[:n]
    return [scheduler.Request(
        rid=i,
        prompt=np.asarray(jax.random.randint(
            jax.random.PRNGKey(20 + 10 * i), (pl,), 0, cfg.vocab)),
        max_new_tokens=g, arrival_time=stagger * i,
        sampling=mix[i % len(mix)])
        for i, (pl, g) in enumerate(zip(plens, gens))]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    return ServeEngine(params, cfg, **kw)


def _assert_bit_exact(ref, out):
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


def _sd(params, cfg, k=3):
    return SpecDecodeConfig(draft_params=params, draft_cfg=cfg, k=k)


# ---------------------------------------------------------------------------
# stream identity + speedup
# ---------------------------------------------------------------------------

def test_spec_streams_byte_identical_to_nonspec(setup):
    cfg, params, _ = setup
    ref = _engine(cfg, params).run(_requests(cfg),
                                   clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, spec_decode=_sd(params, cfg))
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)


def test_same_config_draft_beats_dispatch_bar(setup):
    """A same-config draft accepts ~always, so tokens-per-target-dispatch
    must clear the ISSUE's 1.3 bar deterministically."""
    cfg, params, _ = setup
    eng = _engine(cfg, params, spec_decode=_sd(params, cfg))
    eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    info = eng.cache_info()["spec_decode"]
    assert info["tokens_per_dispatch"] > 1.3
    assert info["acceptance_rate"] > 0.9
    assert info["rounds"] == info["target_dispatches"]


def test_weak_draft_rollback_still_byte_identical(setup):
    """Different-weight draft: partial acceptance forces the in-graph
    rollback select every round, and the streams must STILL equal the
    non-spec engine's bytes (emitted tokens are the target's)."""
    cfg, params, weak = setup
    ref = _engine(cfg, params).run(_requests(cfg),
                                   clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, spec_decode=_sd(weak, cfg))
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)
    info = eng.cache_info()["spec_decode"]
    assert info["acceptance_rate"] < 1.0    # the rollback path actually ran


@pytest.mark.parametrize("k", [1, 4])
def test_k_is_stream_invariant(setup, k):
    cfg, params, weak = setup
    ref = _engine(cfg, params).run(_requests(cfg, n=4),
                                   clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, spec_decode=_sd(weak, cfg, k=k))
    out = eng.run(_requests(cfg, n=4), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)


def test_spec_decode_config_validation(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError):
        SpecDecodeConfig(draft_params=params, draft_cfg=cfg, k=0)
    enc = configs.get_reduced_config("whisper-small")
    with pytest.raises(ValueError):
        _engine(enc, lm.init_params(jax.random.PRNGKey(0), enc,
                                    max_seq=80),
                enc_len=16, spec_decode=_sd(params, cfg))
    with pytest.raises(ValueError):
        _engine(cfg, params, spec_decode=_sd(params, cfg),
                prefix_cache=64)
    with pytest.raises(ValueError):
        _engine(cfg, params, spec_decode=_sd(params, cfg),
                prefill_chunk=4)


# ---------------------------------------------------------------------------
# chaos + replay
# ---------------------------------------------------------------------------

def test_chaos_on_spec_sites_replays_bit_exact(setup):
    """Faults at the draft and verify sites: recovery replays through the
    single-token chunk path (with the draft advancing in lockstep) and
    the surviving streams equal the fault-free non-spec run's bytes."""
    cfg, params, weak = setup
    ref = _engine(cfg, params).run(_requests(cfg),
                                   clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("draft:1", "verify:2"))
    eng = _engine(cfg, params, spec_decode=_sd(weak, cfg), chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["faults_injected"] == 2
    assert rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_chaos_rate_schedule_spec_bit_exact(setup):
    cfg, params, _ = setup
    ref = _engine(cfg, params).run(_requests(cfg),
                                   clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(rate=0.5, seed=7, max_failures=4)
    eng = _engine(cfg, params, spec_decode=_sd(params, cfg), chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


# ---------------------------------------------------------------------------
# mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="2x4 mesh needs 8 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")
def test_spec_streams_on_2x4_mesh_match_single_device(setup):
    cfg, params, weak = setup
    ref = _engine(cfg, params).run(_requests(cfg),
                                   clock=scheduler.FastForwardClock())
    mesh = make_mesh((2, 4), ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        eng = _engine(cfg, params, spec_decode=_sd(weak, cfg))
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)
