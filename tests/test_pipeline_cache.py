"""The caching pass-manager contract (compile-once / run-many):

* `optimize()` traces + rewrites ONCE across repeated calls with identical
  avals and re-traces on a shape (or structure) change,
* structurally identical sub-jaxprs are rewritten once (sub-jaxpr memo),
* the 4 default passes build each BB analysis (ALAP/def-use/width bundled
  in BBContext) exactly once per BB version and share it afterwards,
* the fused scan decode loop generates the same tokens as the per-step
  dispatch loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import core as silvia
from repro.core import pipeline
from repro.launch.serve import generate
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


def i8(rng, shape, lo=-100, hi=100):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


def muls(a0, a1, b):
    return (a0.astype(jnp.int32) * b.astype(jnp.int32),
            a1.astype(jnp.int32) * b.astype(jnp.int32))


# ---------------------------------------------------------------------------
# trace cache
# ---------------------------------------------------------------------------

def test_trace_cache_single_trace_across_calls(rng):
    opt = silvia.optimize(muls, [silvia.PassConfig(op="muladd")])
    args = [i8(rng, (16,)) for _ in range(3)]
    for _ in range(5):
        got = opt(*args)
    info = opt.cache_info()
    assert info["trace_misses"] == 1
    assert info["trace_hits"] == 4
    assert info["traces"] == 1
    for g, want in zip(got, muls(*args)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_trace_cache_retraces_on_shape_change(rng):
    opt = silvia.optimize(muls, [silvia.PassConfig(op="muladd")])
    opt(*[i8(rng, (16,)) for _ in range(3)])
    opt(*[i8(rng, (32,)) for _ in range(3)])
    opt(*[i8(rng, (32,)) for _ in range(3)])   # second 32-shape call: hit
    info = opt.cache_info()
    assert info["trace_misses"] == 2
    assert info["trace_hits"] == 1
    assert info["traces"] == 2


def test_trace_cache_retraces_on_dtype_change(rng):
    opt = silvia.optimize(lambda x, y: x + y)
    opt(i8(rng, (8,)), i8(rng, (8,)))
    opt(jnp.ones((8,), jnp.int16), jnp.ones((8,), jnp.int16))
    assert opt.cache_info()["trace_misses"] == 2


def test_cache_clear_forces_retrace(rng):
    opt = silvia.optimize(muls, [silvia.PassConfig(op="muladd")])
    args = [i8(rng, (16,)) for _ in range(3)]
    opt(*args)
    opt.cache_clear()
    opt(*args)
    info = opt.cache_info()
    assert info["trace_misses"] == 1 and info["trace_hits"] == 0


def test_cached_wrapper_still_jit_compatible(rng):
    opt = silvia.optimize(muls, [silvia.PassConfig(op="muladd")])
    args = [i8(rng, (8,)) for _ in range(3)]
    jopt = jax.jit(opt)
    for g, want in zip(jopt(*args), muls(*args)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


# ---------------------------------------------------------------------------
# sub-jaxpr rewrite memo
# ---------------------------------------------------------------------------

def _two_identical_scans(a, b):
    def body(c, xs):
        x, y = xs
        p0 = x.astype(jnp.int32) * y.astype(jnp.int32)
        p1 = (x + 1).astype(jnp.int32) * y.astype(jnp.int32)
        return c + p0.sum() + p1.sum(), None

    s1, _ = jax.lax.scan(body, jnp.int32(0), (a, b))
    s2, _ = jax.lax.scan(body, jnp.int32(0), (a, b))
    return s1 + s2


def test_identical_subjaxprs_rewritten_once(rng):
    a, b = i8(rng, (4, 16)), i8(rng, (4, 16))
    cache = pipeline.RewriteCache()
    closed = jax.make_jaxpr(_two_identical_scans)(a, b)
    passes = [silvia.PassConfig(op="muladd").instantiate()]
    out = pipeline.optimize_closed_jaxpr(closed, passes, cache=cache)
    assert cache.subjaxpr_misses == 1
    assert cache.subjaxpr_hits == 1
    # both scan bodies carry the SILVIA rewrite
    scans = [e for e in out.jaxpr.eqns if e.primitive.name == "scan"]
    assert len(scans) == 2
    for e in scans:
        inner = [q.primitive.name for q in e.params["jaxpr"].jaxpr.eqns]
        assert "silvia_packed_muladd" in inner


def test_subjaxpr_memo_persists_across_wrapper_calls(rng):
    opt = silvia.optimize(_two_identical_scans,
                          [silvia.PassConfig(op="muladd")])
    a, b = i8(rng, (4, 16)), i8(rng, (4, 16))
    got = opt(a, b)
    info = opt.cache_info()
    assert info["subjaxpr_hits"] == 1 and info["subjaxpr_misses"] == 1
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_two_identical_scans(a, b)))


def test_subjaxpr_memo_keyed_on_pass_list(rng):
    """A RewriteCache shared across DIFFERENT pass lists must not serve a
    body rewritten by the wrong passes."""
    a, b = i8(rng, (4, 16)), i8(rng, (4, 16))
    cache = pipeline.RewriteCache()
    closed = jax.make_jaxpr(_two_identical_scans)(a, b)
    muladd = [silvia.PassConfig(op="muladd").instantiate()]
    add16 = [silvia.PassConfig(op="add", op_size=16).instantiate()]
    out1 = pipeline.optimize_closed_jaxpr(closed, muladd, cache=cache)
    out2 = pipeline.optimize_closed_jaxpr(closed, add16, cache=cache)
    inner1 = [q.primitive.name
              for e in out1.jaxpr.eqns if e.primitive.name == "scan"
              for q in e.params["jaxpr"].jaxpr.eqns]
    inner2 = [q.primitive.name
              for e in out2.jaxpr.eqns if e.primitive.name == "scan"
              for q in e.params["jaxpr"].jaxpr.eqns]
    assert "silvia_packed_muladd" in inner1
    assert "silvia_packed_muladd" not in inner2


def test_cache_clear_resets_all_counters(rng):
    opt = silvia.optimize(_two_identical_scans,
                          [silvia.PassConfig(op="muladd")])
    a, b = i8(rng, (4, 16)), i8(rng, (4, 16))
    opt(a, b)
    opt.cache_clear()
    info = opt.cache_info()
    assert all(info[k] == 0 for k in ("trace_hits", "trace_misses",
                                     "subjaxpr_hits", "subjaxpr_misses",
                                     "analysis_builds", "analysis_hits"))


# ---------------------------------------------------------------------------
# shared BB analysis (ALAP/def-use/width built once per BB version)
# ---------------------------------------------------------------------------

def test_bb_analysis_built_once_across_default_passes(rng):
    """No default pass rewrites this float BB, so all 4 passes must share
    ONE BBContext: exactly 1 build, 3 hits."""
    def fn(x, y):
        return x * y + jnp.sin(x)

    x = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    cache = pipeline.RewriteCache()
    closed = jax.make_jaxpr(fn)(x, x)
    passes = [p.instantiate() for p in silvia.DEFAULT_PASSES]
    pipeline.optimize_closed_jaxpr(closed, passes, cache=cache)
    assert cache.analysis.builds == 1
    assert cache.analysis.hits == len(passes) - 1


def test_bb_analysis_patched_not_rebuilt_on_rewrite(rng):
    """A pass that rewrites the BB PATCHES the shared BBContext in place
    (def/use + widths repaired locally) instead of forcing a rebuild:
    still exactly one build, every later pass a hit, and the rewrite shows
    up in the `analysis_patched` counter."""
    def fn(a0, a1, b):
        c0, c1 = muls(a0, a1, b)
        return c0, c1

    args = [i8(rng, (16,)) for _ in range(3)]
    cache = pipeline.RewriteCache()
    closed = jax.make_jaxpr(fn)(*args)
    passes = [p.instantiate() for p in silvia.DEFAULT_PASSES]
    out = pipeline.optimize_closed_jaxpr(closed, passes, cache=cache)
    # muladd rewrites (one patch); mul4/add8/add16 find nothing more --
    # and nobody pays for a second analysis build.
    assert cache.analysis.builds == 1
    assert cache.analysis.hits == len(passes) - 1
    assert cache.analysis.patched == 1
    assert "silvia_packed_muladd" in [e.primitive.name
                                      for e in out.jaxpr.eqns]


def test_bb_analysis_patch_preserves_values_on_table2_pipeline(rng):
    """Patched >> rebuilt on a real pipeline: the table2_cnn conv pair
    (muladd then the remaining default passes) packs across several BBs
    while every BB analysis is built at most once -- and the rewritten
    function stays bit-exact."""
    from benchmarks import table2_cnn

    x = i8(rng, (8, 8))
    w_even = i8(rng, (9,), lo=-8, hi=8)
    w_odd = i8(rng, (9,), lo=-8, hi=8)
    want = table2_cnn.conv3x3_pair_naive(x, w_even, w_odd)

    opt = silvia.optimize(table2_cnn.conv3x3_pair_naive,
                          list(silvia.DEFAULT_PASSES))
    got = opt(x, w_even, w_odd)
    info = opt.cache_info()
    assert info["analysis_patched"] >= 1
    # incremental re-analysis: a rewrite no longer mints a new BB version,
    # so every pass beyond the first is a hit on the SAME context -- under
    # the old whole-BB invalidation each patch below would have been an
    # extra build instead.
    assert info["analysis_builds"] + info["analysis_hits"] \
        == info["analysis_builds"] * len(silvia.DEFAULT_PASSES)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# fused decode loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("silvia_passes", ["off", "all"])
def test_fused_scan_decode_matches_stepwise(silvia_passes):
    cfg = configs.get_reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    params = quantize_tree_for_serving(
        lm.init_params(rng, cfg, max_seq=64), "w8a8")
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    step = generate(params, prompts, cfg, gen=8, cache_len=32,
                    silvia_passes=silvia_passes, fused=False)
    fused = generate(params, prompts, cfg, gen=8, cache_len=32,
                     silvia_passes=silvia_passes, fused=True)
    np.testing.assert_array_equal(np.asarray(step), np.asarray(fused))
