"""II-aware tuple filtering (paper sec. 3.5.1 future work, implemented).

The Fig. 5 program as a real jax.lax.scan: packing the two adds {a, b}
would raise II_min from 2 to 3.  With filter_ii=True the pass must refuse
that tuple; with the paper's default behaviour it packs (and the paper
notes the II regression would be the scheduler's problem)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as silvia


def fig5_scan(xs, ys, w):
    """d = (w*(x+y)) + (x+d_prev) per step -- the Fig. 5 dependence shape,
    with int8 operands so SILVIAAdd sees candidates."""
    def body(d, xy):
        x, y = xy
        a = x + y                  # int8 add (candidate)
        b = x + d                  # int8 add (candidate, carried dep)
        c = (w * a).astype(jnp.int8)
        d_new = (c + b).astype(jnp.int8)
        return d_new, d_new
    return jax.lax.scan(body, jnp.int8(0), (xs, ys))


def _scan_inner_names(closed):
    eqn = next(e for e in closed.jaxpr.eqns if e.primitive.name == "scan")
    return [e.primitive.name for e in eqn.params["jaxpr"].jaxpr.eqns]


def test_fig5_packed_without_filter(rng):
    xs = jnp.asarray(rng.integers(-50, 50, (6,)), jnp.int8)
    ys = jnp.asarray(rng.integers(-50, 50, (6,)), jnp.int8)
    w = jnp.int8(3)
    passes = [silvia.PassConfig(op="add", op_size=8)]
    after = silvia.optimized_jaxpr(fig5_scan, xs, ys, w, passes=passes)
    assert "silvia_packed_add" in _scan_inner_names(after)
    opt = silvia.optimize(fig5_scan, passes)
    for g, want in zip(opt(xs, ys, w), fig5_scan(xs, ys, w)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_fig5_filtered_with_ii_guard(rng):
    xs = jnp.asarray(rng.integers(-50, 50, (6,)), jnp.int8)
    ys = jnp.asarray(rng.integers(-50, 50, (6,)), jnp.int8)
    w = jnp.int8(3)
    passes = [silvia.PassConfig(op="add", op_size=8, filter_ii=True)]
    stats = []
    after = silvia.optimized_jaxpr(fig5_scan, xs, ys, w, passes=passes,
                                   stats=stats)
    assert "silvia_packed_add" not in _scan_inner_names(after)
    assert any(s.get("ii_dropped", 0) > 0 for s in stats)
    # function unchanged -> trivially correct
    opt = silvia.optimize(fig5_scan, passes)
    for g, want in zip(opt(xs, ys, w), fig5_scan(xs, ys, w)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_ii_filter_keeps_safe_tuples(rng):
    """Independent adds with no carried cycle must still pack under the
    filter (the filter is not just 'disable packing in loops')."""
    def safe_scan(xs, ys):
        def body(c, xy):
            x, y = xy
            a = x + y
            b = y + jnp.int8(1)
            return (c + a.astype(jnp.int32).sum()
                    + b.astype(jnp.int32).sum()), (a, b)
        return jax.lax.scan(body, jnp.int32(0), (xs, ys))

    xs = jnp.asarray(rng.integers(-50, 50, (4, 8)), jnp.int8)
    ys = jnp.asarray(rng.integers(-50, 50, (4, 8)), jnp.int8)
    passes = [silvia.PassConfig(op="add", op_size=8, filter_ii=True)]
    after = silvia.optimized_jaxpr(safe_scan, xs, ys, passes=passes)
    assert "silvia_packed_add" in _scan_inner_names(after)
    opt = silvia.optimize(safe_scan, passes)
    for g, want in zip(jax.tree_util.tree_leaves(opt(xs, ys)),
                       jax.tree_util.tree_leaves(safe_scan(xs, ys))):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))
