"""Family-agnostic slot-state layer (models/slot_state.py): spec probing,
per-family engine-vs-static bit-exactness (ssm, hybrid, encdec), masked
slot-state updates leaving inactive slots bit-identical across every
registered family, stop-token early termination, and slot compaction."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import scheduler, serve
from repro.launch.engine import ServeEngine
from repro.models import lm, slot_state
from repro.quant.qtensor import quantize_tree_for_serving

ENC_LEN = 8


def _cfg(family):
    return configs.get_reduced_config({
        "dense": "smollm-135m",
        "moe": "granite-moe-1b-a400m",
        "ssm": "mamba2-2.7b",
        "hybrid": "jamba-v0.1-52b",
        "encdec": "whisper-small",
    }[family])


@pytest.fixture(scope="module")
def family_setup():
    """{family: (cfg, params)} for every family exercised here."""
    out = {}
    for fam in ("dense", "ssm", "hybrid", "encdec"):
        cfg = _cfg(fam)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=96)
        if fam in ("dense", "hybrid"):
            params = quantize_tree_for_serving(params, "w8a8")
        out[fam] = (cfg, params)
    return out


def _prompts(cfg, n, s, seed=0):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n, s),
                                         0, cfg.vocab))


def _features(cfg, n, seed=7):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (n, ENC_LEN, cfg.d_model)),
                      np.float32)


def _requests(cfg, prompts, gens, feats=None, **kw):
    return [scheduler.Request(
        rid=i, prompt=prompts[i], max_new_tokens=g,
        features=None if feats is None else feats[i], **kw)
        for i, g in enumerate(gens)]


def _static(cfg, params, prompts, gen, feats=None, silvia="off"):
    if cfg.family == "encdec":
        audio = jnp.asarray(feats).astype(jnp.dtype(cfg.dtype))
        inputs = (audio, jnp.asarray(prompts))
    else:
        inputs = jnp.asarray(prompts)
    return np.asarray(serve.generate(params, inputs, cfg, gen=gen,
                                     cache_len=prompts.shape[1] + gen,
                                     silvia_passes=silvia))


def _engine(cfg, params, **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    return ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                       segment_len=4, **kw)


# ---------------------------------------------------------------------------
# spec probing
# ---------------------------------------------------------------------------

def test_spec_probes_axes_per_family():
    # attention KV: slot axis 1, length axis 2 on every leaf
    spec = slot_state.spec_for(_cfg("dense"))
    assert spec.has_length_axis
    assert all(a == 1 for a in spec.batch_axes)
    assert all(a == 2 for a in spec.length_axes)
    # pure SSM: constant-size pages, no leaf has a length axis
    spec = slot_state.spec_for(_cfg("ssm"))
    assert not spec.has_length_axis
    assert all(a is None for a in spec.length_axes)
    assert not spec.prefill_chunkable
    # hybrid: mamba leaves (slot axis 2, no length) + attn KV leaves
    spec = slot_state.spec_for(_cfg("hybrid"))
    assert spec.has_length_axis
    assert set(spec.batch_axes) == {1, 2}
    assert None in spec.length_axes and 2 in spec.length_axes
    # encdec with fixed enc_len: self-KV slices, cross-KV is constant
    spec = slot_state.spec_for(_cfg("encdec"), s_enc=ENC_LEN)
    assert spec.has_length_axis and None in spec.length_axes


def test_spec_unregistered_family_points_to_registry():
    cfg = dataclasses.replace(_cfg("dense"), family="rwkv")
    with pytest.raises(ValueError, match="slot_state.register"):
        slot_state.spec_for(cfg)
    assert "ssm" in slot_state.families()


def test_slice_merge_admit_roundtrip():
    cfg = _cfg("hybrid")
    spec = slot_state.spec_for(cfg)
    state = spec.init_state(4, 32)
    leaves = jax.tree_util.tree_leaves(state)
    rnd = [jnp.asarray(np.random.default_rng(i).normal(size=l.shape),
                       l.dtype) for i, l in enumerate(leaves)]
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state), rnd)
    sub = spec.slice_live(state, 2, 16)
    back = spec.merge_live(state, sub, 2, 16)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # admit a fresh 1-row group into slot 3; other slots untouched
    rows = spec.slice_live(spec.init_state(1, 16), 1, 16)
    adm = spec.admit(state, rows, np.asarray([3]), 1, t_pre=16)
    keep = spec.slice_live(adm, 3)
    want = spec.slice_live(state, 3)
    for a, b in zip(jax.tree_util.tree_leaves(keep),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# engine vs static generate(), per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,silvia", [
    ("ssm", "off"), ("ssm", "all"),
    ("hybrid", "off"), ("hybrid", "all"),
    ("encdec", "off"), ("encdec", "all"),
])
def test_engine_matches_static_generate_per_family(family_setup, family,
                                                   silvia):
    """3 requests on 2 slots (forces eviction + re-admission) must produce
    bit-identical greedy tokens to one static generate() batch."""
    cfg, params = family_setup[family]
    prompts = _prompts(cfg, 3, 12)
    feats = _features(cfg, 3) if family == "encdec" else None
    static = _static(cfg, params, prompts, gen=8, feats=feats, silvia=silvia)
    eng = _engine(cfg, params, silvia_passes=silvia)
    out = eng.run(_requests(cfg, prompts, (8, 8, 8), feats))
    for i in range(3):
        np.testing.assert_array_equal(out[i], static[i])


@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_engine_ragged_matches_per_request_static(family_setup, family):
    """Ragged prompt/gen mix: every request must equal a dedicated static
    run of just that request (prompt-bucket padding must be invisible to
    sequential SSM state)."""
    cfg, params = family_setup[family]
    plens, gens = (5, 12, 9, 16), (3, 8, 1, 6)
    prompts = [_prompts(cfg, 1, s, seed=10 + i)[0]
               for i, s in enumerate(plens)]
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=g)
            for i, g in enumerate(gens)]
    eng = _engine(cfg, params)
    out = eng.run(reqs)
    for i, g in enumerate(gens):
        static = _static(cfg, params, prompts[i][None], gen=g)[0]
        np.testing.assert_array_equal(out[i], static)


def test_ssm_census_grows_with_batch_buckets_only(family_setup):
    """Constant-size SSM pages need no length bucketing: every segment
    graph key is (bb, None), and the census stays within the batch-bucket
    count alone."""
    cfg, params = family_setup["ssm"]
    eng = ServeEngine(params, cfg, n_slots=4, max_cache_len=64,
                      segment_len=4)
    assert not eng._spec.has_length_axis and eng.len_buckets == ()
    plens, gens = (4, 9, 14, 23), (2, 9, 17, 5)
    prompts = [_prompts(cfg, 1, s, seed=20 + i)[0]
               for i, s in enumerate(plens)]
    eng.run([scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=g)
             for i, g in enumerate(gens)])
    seg = [k for k in eng._graphs if k[0] == "segment"]
    assert seg and all(k[2] is None for k in seg)
    assert len(seg) <= len(eng.batch_buckets)
    info = eng.cache_info()
    assert info["graphs"] <= info["graph_bound"]
    assert not info["has_length_axis"]


def test_engine_warmup_covers_ssm_traffic(family_setup):
    cfg, params = family_setup["ssm"]
    plens, gens = (4, 8, 12), (2, 4, 8)
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=4)
    eng.warmup(prompt_lens=plens)
    warmed = set(eng._graphs)
    assert len(warmed) <= eng.graph_bound()
    reqs = scheduler.synthetic_traffic(seed=1, n_requests=6, rate=100.0,
                                       prompt_lens=plens, gen_lens=gens,
                                       vocab=cfg.vocab)
    eng.run(reqs)
    assert eng._graphs == warmed, "traffic compiled outside the warmed grid"


# ---------------------------------------------------------------------------
# stop tokens
# ---------------------------------------------------------------------------

def test_stop_token_truncates_at_static_prefix(family_setup):
    """With stop_tokens, the engine output must be the static run's tokens
    cut at (and including) the first stop token."""
    cfg, params = family_setup["dense"]
    prompts = _prompts(cfg, 3, 12, seed=4)
    static = _static(cfg, params, prompts, gen=16)
    # pick each row's 3rd generated token as its stop token: admission
    # (token 1) and harvest (later segments) paths both stay exercised
    stops = [int(static[i, 2]) for i in range(3)]
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=16,
                              stop_tokens=(stops[i],))
            for i in range(3)]
    eng = _engine(cfg, params)
    out = eng.run(reqs)
    for i in range(3):
        row = static[i]
        upto = int(np.nonzero(row == stops[i])[0][0]) + 1
        np.testing.assert_array_equal(out[i], row[:upto])
        assert len(out[i]) < 16
    assert eng.total_generated == sum(len(out[i]) for i in range(3))


def test_stop_token_on_first_token_finishes_at_admission(family_setup):
    cfg, params = family_setup["dense"]
    prompts = _prompts(cfg, 1, 8, seed=5)
    static = _static(cfg, params, prompts, gen=4)
    req = scheduler.Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                            stop_tokens=(int(static[0, 0]),))
    eng = _engine(cfg, params)
    out = eng.run([req])
    np.testing.assert_array_equal(out[0], static[0, :1])
    assert req.finish_time is not None and eng.n_active == 0


# ---------------------------------------------------------------------------
# slot compaction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_compaction_shrinks_bucket_and_preserves_outputs(family_setup,
                                                         family):
    """Evict the low slots of a full batch, admit nothing, and the next
    segment must run at the smaller batch bucket with surviving requests'
    outputs still bit-identical to static."""
    cfg, params = family_setup[family]
    prompts = _prompts(cfg, 4, 8, seed=6)
    static = _static(cfg, params, prompts, gen=12)
    # slots 0..2 finish after 2 tokens; slot 3 keeps going: holes at 0..2
    gens = (2, 2, 2, 12)
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=g)
            for i, g in enumerate(gens)]
    eng = ServeEngine(params, cfg, n_slots=4, max_cache_len=64,
                      segment_len=2)
    out = eng.run(reqs)
    assert eng.compactions >= 1
    seg_bbs = {k[1] for k in eng._graphs if k[0] == "segment"}
    assert 1 in seg_bbs, f"post-compaction bucket never shrank: {seg_bbs}"
    for i, g in enumerate(gens):
        np.testing.assert_array_equal(out[i], static[i, :g])


def test_compaction_skipped_when_bucket_unchanged(family_setup):
    """A hole that doesn't change the batch bucket isn't worth a gather."""
    cfg, params = family_setup["dense"]
    prompts = _prompts(cfg, 2, 8, seed=8)
    gens = (2, 6)   # slot 0 evicts early; bucket stays 2 -> 2? no: 2 -> 1
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=2)
    eng.run([scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=g)
             for i, g in enumerate(gens)])
    # hole at slot 0 with live slot 1: bucket 2 -> 1 shrink, so this DOES
    # compact; the no-op case is a hole above the live prefix
    assert eng.compactions >= 1
    prompts = _prompts(cfg, 2, 8, seed=9)
    eng2 = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                       segment_len=2)
    eng2.run([scheduler.Request(rid=i, prompt=prompts[i],
                                max_new_tokens=g)
              for i, g in enumerate((6, 2))])
    # hole at slot 1 leaves live prefix [0] already dense: no gather
    assert eng2.compactions == 0


# ---------------------------------------------------------------------------
# masked updates: inactive slots bit-identical (all registered families)
# ---------------------------------------------------------------------------
# Deterministic sweep here; tests/test_slot_state_property.py runs the same
# check under hypothesis with drawn masks/tokens/positions.

MASK_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec")


def masked_family_setup(fam, n_slots=4):
    """(cfg, params, noise-filled state, jitted masked step) for a family."""
    cfg = _cfg(fam)
    params = lm.init_params(jax.random.PRNGKey(1), cfg, max_seq=64)
    kw = {"s_enc": ENC_LEN} if fam == "encdec" else {}
    spec = slot_state.spec_for(cfg, **kw)
    state = spec.init_state(n_slots, 32)
    # fill with noise so "unchanged" is a real assertion, not 0 == 0
    leaves, td = jax.tree_util.tree_flatten(state)
    rng = np.random.default_rng(3)
    leaves = [jnp.asarray(rng.normal(size=l.shape).astype(l.dtype))
              if jnp.issubdtype(l.dtype, jnp.floating)
              else jnp.asarray(rng.integers(-3, 4, size=l.shape)
                               .astype(l.dtype))
              for l in leaves]
    state = jax.tree_util.tree_unflatten(td, leaves)
    step = jax.jit(lambda p, t, c, pos, a: lm.decode_step(
        p, t, c, pos, cfg, active=a))
    return cfg, params, spec, state, step


def assert_inactive_slots_unchanged(spec, state, new_state, active, fam):
    for ba, old, new in zip(spec.batch_axes,
                            jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(new_state)):
        o, n = np.asarray(old), np.asarray(new)
        for slot in np.nonzero(~np.asarray(active))[0]:
            np.testing.assert_array_equal(
                np.take(n, int(slot), axis=ba),
                np.take(o, int(slot), axis=ba),
                err_msg=f"{fam}: inactive slot {slot} mutated")


@pytest.mark.parametrize("fam", MASK_FAMILIES)
def test_masked_update_leaves_inactive_slots_bit_identical(fam):
    n_slots = 4
    cfg, params, spec, state, step = masked_family_setup(fam, n_slots)
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab, size=(n_slots, 1)).astype(np.int32)
    pos = rng.integers(0, 24, size=(n_slots,)).astype(np.int32)
    for active in ([True, False, True, False], [False] * 4,
                   [False, True, True, True]):
        active = np.asarray(active)
        _, new_state = step(params, jnp.asarray(toks), state,
                            jnp.asarray(pos), jnp.asarray(active))
        assert_inactive_slots_unchanged(spec, state, new_state, active, fam)
