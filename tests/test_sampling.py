"""Per-request sampling policies (launch/sampling.py): constant-size
slot-page registration, greedy bit-parity with the pre-sampling engine,
sampled-stream determinism (engine == static == repeat run), chaos-replay
byte-identity, and prefix-cache warm-run identity.

The contract under test is ISSUE/DESIGN sec. 12's purity obligation:
every sampled token is a pure function of (seed, rid, token index,
logits row), so recovery replay and warm admissions RECOMPUTE the same
bytes instead of restoring sampler state."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import resilience as res
from repro.launch import sampling, scheduler, serve
from repro.launch.engine import ServeEngine
from repro.models import lm, slot_state
from repro.quant.qtensor import quantize_tree_for_serving

SP = scheduler.SamplingParams(temperature=0.9, top_k=8, seed=11)
SP_NUCLEUS = scheduler.SamplingParams(temperature=0.7, top_p=0.9, seed=3)
MIX = (SP, None, SP_NUCLEUS, scheduler.GREEDY)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_reduced_config("smollm-135m")
    params = quantize_tree_for_serving(
        lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80), "w8a8")
    return cfg, params


def _requests(cfg, n=6, stagger=0.0, mix=MIX):
    plens = (5, 12, 9, 16, 7, 11, 6, 14)[:n]
    gens = (8, 6, 9, 5, 10, 7, 8, 6)[:n]
    return [scheduler.Request(
        rid=i,
        prompt=np.asarray(jax.random.randint(
            jax.random.PRNGKey(10 * i), (pl,), 0, cfg.vocab)),
        max_new_tokens=g, arrival_time=stagger * i,
        sampling=mix[i % len(mix)])
        for i, (pl, g) in enumerate(zip(plens, gens))]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    return ServeEngine(params, cfg, **kw)


def _assert_bit_exact(ref, out):
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


# ---------------------------------------------------------------------------
# the page: a registered constant-size slot-state family
# ---------------------------------------------------------------------------

def test_sampling_page_is_constant_size_slot_family():
    """The probed spec must show slot axis 0 and NO length axis on every
    leaf -- the page admits/permutes/slices with the model caches but
    never scales with cache_len (ISSUE: 'constant-size slot page')."""
    assert "sampling" in slot_state.families()
    spec = sampling.page_spec()
    assert all(b == 0 for b in spec.batch_axes)
    assert all(la is None for la in spec.length_axes)
    page = spec.init_state(4, 1)
    assert [leaf.shape[0] for leaf in page] == [4] * len(page)


def test_host_page_round_trip():
    """write/clear/permute keep the host page a faithful slot mirror."""
    page = sampling.host_page(4)
    req = scheduler.Request(rid=7, prompt=[1, 2, 3], max_new_tokens=2,
                            sampling=SP)
    sampling.write_row(page, 2, req)
    assert page[1][2] == np.float32(SP.temperature)
    assert page[2][2] == SP.top_k and page[4][2] == 3
    assert tuple(page[0][2]) == sampling.base_key(SP.seed, 7)
    perm = np.asarray([2, 0, 1, 3])
    page = sampling.permute(page, perm)
    assert page[2][0] == SP.top_k          # the row moved with its slot
    sampling.clear_row(page, 0)
    assert page[1][0] == 0.0 and page[3][0] == 1.0


def test_sample_host_matches_batch_row():
    """One [1,V] host evaluation must equal the same row inside a [B,V]
    batch -- the property replay verification rests on."""
    rows = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (4, 64)),
                      np.float32)
    key = np.asarray([sampling.base_key(SP.seed, r) for r in range(4)],
                     np.uint32)
    batch = sampling.sample(
        jnp.asarray(rows), jnp.asarray(key),
        jnp.full((4,), SP.temperature, jnp.float32),
        jnp.full((4,), SP.top_k, jnp.int32),
        jnp.full((4,), SP.top_p, jnp.float32),
        jnp.arange(4, dtype=jnp.int32))
    for r in range(4):
        assert int(batch[r]) == sampling.sample_host(rows[r], SP, r, r)


# ---------------------------------------------------------------------------
# engine streams
# ---------------------------------------------------------------------------

def test_greedy_rows_bit_identical_to_argmax_engine(setup):
    """Greedy rows in a mixed sampled batch carry the argmax bits -- the
    pre-sampling engine's stream, unchanged."""
    cfg, params = setup
    ref = _engine(cfg, params).run(
        _requests(cfg, mix=(None,)), clock=scheduler.FastForwardClock())
    out = _engine(cfg, params).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    for i, r in enumerate(_requests(cfg)):
        if sampling.is_greedy(r):
            np.testing.assert_array_equal(out[i], ref[i])


def test_sampled_streams_deterministic_across_runs(setup):
    cfg, params = setup
    a = _engine(cfg, params).run(_requests(cfg),
                                 clock=scheduler.FastForwardClock())
    b = _engine(cfg, params).run(_requests(cfg),
                                 clock=scheduler.FastForwardClock())
    _assert_bit_exact(a, b)
    # and the sampled rows actually differ from greedy (the policy bites)
    g = _engine(cfg, params).run(_requests(cfg, mix=(None,)),
                                 clock=scheduler.FastForwardClock())
    assert any(not np.array_equal(a[i], g[i]) for i in (0, 2, 4)
               if i in a)


def test_engine_matches_static_sampled_path(setup):
    """Continuous-batching sampled streams == the static serve.generate
    sampled path with the same (seed, rid) -- batch-composition
    invariance end to end."""
    cfg, params = setup
    n, s, gen = 3, 12, 8
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n, s), 0, cfg.vocab))
    mix = [SP, scheduler.GREEDY, SP_NUCLEUS]
    static = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, gen=gen, cache_len=32,
        sampling=mix, rids=list(range(n))))
    eng = _engine(cfg, params, n_slots=2)   # forces eviction/re-admission
    out = eng.run([scheduler.Request(rid=i, prompt=prompts[i],
                                     max_new_tokens=gen, sampling=mix[i])
                   for i in range(n)])
    for i in range(n):
        np.testing.assert_array_equal(out[i], static[i])


def test_static_sampled_unfused_matches_fused(setup):
    cfg, params = setup
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (2, 10), 0, cfg.vocab))
    kw = dict(gen=6, cache_len=32, sampling=[SP, SP_NUCLEUS], rids=[5, 9])
    fused = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, fused=True, **kw))
    loop = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, fused=False, **kw))
    np.testing.assert_array_equal(fused, loop)


# ---------------------------------------------------------------------------
# replay + prefix cache: recompute the same bytes
# ---------------------------------------------------------------------------

def test_chaos_replay_sampled_streams_bit_exact(setup):
    """Faults mid-stream: recovery replay must reproduce sampled tokens
    byte-identically (counter-based keys recompute, nothing restored)."""
    cfg, params = setup
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("segment:1", "segment:4"))
    eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["faults_injected"] == 2
    assert rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_chaos_rate_schedule_sampled_bit_exact(setup):
    """The seeded-rate chaos form CI drives via $REPRO_CHAOS."""
    cfg, params = setup
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(rate=0.5, seed=7, max_failures=4)
    eng = _engine(cfg, params, chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_prefix_cache_warm_sampled_streams_match_cold(setup):
    """Warm admissions over a shared prefix must emit the same sampled
    bytes as the cold run: the pool stores GREEDY argmax tok0 and
    policy-free pages; sampled tok0 is recomputed per request from the
    final prefill row."""
    cfg, params = setup

    def reqs():
        base = scheduler.shared_prefix_traffic(
            seed=4, n_requests=8, rate=1e9, n_prefixes=2, prefix_len=8,
            tail_lens=(3, 5), gen_lens=(6, 8), vocab=cfg.vocab)
        for i, r in enumerate(base):
            r.sampling = MIX[i % len(MIX)]
        return base

    cold = _engine(cfg, params, prefill_chunk=4).run(
        reqs(), clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64)
    warm = eng.run(reqs(), clock=scheduler.FastForwardClock())
    info = eng.cache_info()["prefix_cache"]
    assert info["hits"] > 0                 # chain sharing engaged
    _assert_bit_exact(cold, warm)


def test_snapshot_restore_preserves_sampling(setup, tmp_path):
    """resilience snapshot/restore round-trips SamplingParams so a
    restarted engine resumes the same sampled stream."""
    cfg, params = setup
    ref = _engine(cfg, params).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params)
    for r in _requests(cfg):
        eng.submit(r)
    eng.snapshot(str(tmp_path), step=1)
    eng2 = _engine(cfg, params)
    assert eng2.restore(str(tmp_path)) == len(ref)
    out = eng2.run(clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)
