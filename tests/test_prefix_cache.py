"""Cross-request prefix caching (launch/prefix_cache.py + engine
admission): content-addressed pool units (rolling chain keys, LRU +
pinning, peek), and the engine-level exactness bar -- a prefix-cache HIT
must reproduce the cold-prefill token stream BITWISE for every family,
with SILVIA passes on, under injected faults (recovery-as-replay), and
on a sharded mesh (DESIGN.md sec. 10).

The exactness argument under test: slot KV rows are a pure function of
the token prefix (per-row dynamic_update_slice + causal masking), so
pooled pages captured from one request's prefill are bit-identical to
what any same-prefix request would compute -- sharing is free, not
approximate."""
import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.distributed import elastic
from repro.launch import prefix_cache as pfx
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_mesh
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16
NDEV = jax.device_count()


@pytest.fixture(scope="module")
def setup():
    """Lazy per-family (cfg, params): only the families a test touches
    pay their init cost."""
    cache = {}

    def get(fam):
        if fam not in cache:
            cfg = configs.get_reduced_config(FAMILY_ARCHS[fam])
            cache[fam] = (cfg, lm.init_params(jax.random.PRNGKey(0), cfg,
                                              max_seq=96))
        return cache[fam]
    return get


def _engine(cfg, params, **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    kw.setdefault("chaos", None)
    return ServeEngine(params, cfg, **kw)


def _zipf_requests(cfg, n=10, seed=0, rate=300.0):
    """Shared-prefix (zipfian) traffic: chain sharing engages on chunked
    engines; prompts stay inside the test cache/prompt buckets."""
    return scheduler.shared_prefix_traffic(
        seed=seed, n_requests=n, rate=rate, n_prefixes=2, prefix_len=8,
        tail_lens=(2, 4, 6), gen_lens=(4, 6), vocab=cfg.vocab, zipf_a=1.3)


def _repeat_requests(cfg, n_unique=3, repeats=1, stagger=0.05, seed=0):
    """`n_unique` staggered prompts, each repeated EXACTLY `repeats` more
    times later in the trace -- the terminal-hit shape every family
    (including sequential-state ones) can share."""
    plens = (6, 11, 9, 14)[:n_unique]
    reqs = []
    rid = 0
    for rep in range(repeats + 1):
        for i, pl in enumerate(plens):
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed + 10 * i), (pl,), 0, cfg.vocab))
            kw = {}
            if cfg.family == "encdec":
                rng = np.random.default_rng(seed + i)   # per-prompt, not
                kw["features"] = rng.standard_normal(   # per-request
                    (ENC_LEN, cfg.d_model)).astype(np.float32)
            reqs.append(scheduler.Request(
                rid=rid, prompt=prompt, max_new_tokens=5,
                arrival_time=stagger * (rep * n_unique + i), **kw))
            rid += 1
    return reqs


def _assert_bit_exact(ref, out):
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


# ---------------------------------------------------------------------------
# pool units
# ---------------------------------------------------------------------------

def test_chain_keys_roll_over_exact_prefix():
    """Chunk k's key is a function of ALL tokens [0:(k+1)C): same prefix
    -> same keys; any earlier token change reroutes every later key."""
    pc = pfx.PrefixCache(8, chunk=4)
    a = pc.chain_keys(np.arange(10, dtype=np.int32))
    assert len(a) == 2                      # only fully-real chunks
    b = pc.chain_keys(np.arange(12, dtype=np.int32))
    assert a == b[:2] and len(b) == 3       # shared prefix shares keys
    mutated = np.arange(10, dtype=np.int32)
    mutated[1] = 99
    m = pc.chain_keys(mutated)
    assert m[0] != a[0] and m[1] != a[1]    # divergence cascades
    # keys are salted: two pools with different salts never share pages
    other = pfx.PrefixCache(8, chunk=4, salt="other")
    assert other.chain_keys(np.arange(10, dtype=np.int32))[0] != a[0]


def test_chain_disabled_without_chunk_or_const_leaves():
    assert pfx.PrefixCache(8).chain_ok is False
    assert pfx.PrefixCache(8).chain_keys(np.arange(8)) == []
    assert pfx.PrefixCache(8, chunk=4, chain_ok=False).chain_ok is False
    pc = pfx.PrefixCache(8, chunk=4, chain_ok=False)
    pc.insert_chain(b"k", [np.zeros(2)])    # silently refused
    assert pc.info()["pages_resident"] == 0


def test_terminal_key_covers_features():
    """encdec: same prompt + different encoder features must NOT share
    state (cross-KV depends on the features)."""
    pc = pfx.PrefixCache(8)
    prompt = np.arange(6, dtype=np.int32)
    r1 = scheduler.Request(rid=0, prompt=prompt, max_new_tokens=2,
                           features=np.ones((4, 8), np.float32))
    r2 = scheduler.Request(rid=1, prompt=prompt, max_new_tokens=2,
                           features=np.zeros((4, 8), np.float32))
    pc.insert_terminal(r1, [np.zeros(2)], tok0=7)
    assert pc.lookup(r1).terminal is not None
    assert pc.lookup(r2).terminal is None


def test_peek_does_not_mutate():
    pc = pfx.PrefixCache(8, chunk=4)
    r = scheduler.Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                          max_new_tokens=2)
    pc.insert_terminal(r, [np.zeros(2)], tok0=1)
    before = pc.info()
    assert pc.peek_cached_tokens(r) == 8
    after = pc.info()
    assert (after["hits"], after["misses"]) \
        == (before["hits"], before["misses"])


def test_lru_eviction_skips_pinned():
    pc = pfx.PrefixCache(2, chunk=4)
    keys = [bytes([i]) * 4 for i in range(3)]
    pc.insert_chain(keys[0], [np.zeros(1)])
    pc.insert_chain(keys[1], [np.zeros(1)])
    pinned = pc.pin([keys[0]])              # oldest entry is now pinned
    assert pinned == (keys[0],)
    pc.insert_chain(keys[2], [np.zeros(1)])
    info = pc.info()
    # LRU victim would be keys[0], but it is pinned -> keys[1] evicted
    assert info["pages_evicted"] == 1
    assert pc.pin([keys[0]]) == (keys[0],)
    assert pc.pin([keys[1]]) == ()          # gone
    # releasing makes it evictable again once over capacity
    pc.release(pinned)
    pc.release((keys[0],))
    pc.insert_chain(bytes([9]) * 4, [np.zeros(1)])
    assert pc.info()["pages_resident"] <= 2


def test_duplicate_insert_is_touch_not_growth():
    pc = pfx.PrefixCache(4, chunk=4)
    pc.insert_chain(b"a", [np.zeros(1)])
    pc.insert_chain(b"a", [np.ones(1)])     # dup: refreshed, not replaced
    info = pc.info()
    assert info["insertions"] == 1 and info["pages_resident"] == 1


# ---------------------------------------------------------------------------
# engine: chain sharing on chunked prefill (dense)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("silvia", ["off", "all"])
def test_chunked_warm_stream_matches_cold(setup, silvia):
    """The tentpole bar: zipfian shared-prefix traffic through a pooled
    engine is BIT-IDENTICAL to the cold-cache run -- including with the
    full SILVIA pass pipeline lowering the serve graphs."""
    cfg, params = setup("dense")
    reqs = lambda: _zipf_requests(cfg)  # noqa: E731
    cold = _engine(cfg, params, prefill_chunk=4, silvia_passes=silvia).run(
        reqs(), clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, prefill_chunk=4, silvia_passes=silvia,
                  prefix_cache=64)
    warm = eng.run(reqs(), clock=scheduler.FastForwardClock())
    _assert_bit_exact(cold, warm)
    info = eng.cache_info()["prefix_cache"]
    assert info["chain_ok"] is True
    assert info["hits"] > 0 and info["tokens_skipped"] > 0
    assert info["pages_resident"] > 0


def test_terminal_repeat_skips_all_prefill_dispatches(setup):
    """An exact-repeat prompt terminal-hits: its admission runs ZERO
    chunk/prefill dispatches (pages + first token come from the pool),
    and the generated stream is identical to the first serving."""
    cfg, params = setup("dense")
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64)
    first = eng.run(_repeat_requests(cfg, n_unique=2),
                    clock=scheduler.FastForwardClock())
    chunks_before = eng._site_counts["chunk"]
    again = [scheduler.Request(rid=100 + r.rid, prompt=r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens)
             for r in _repeat_requests(cfg, n_unique=2)]
    second = eng.run(again, clock=scheduler.FastForwardClock())
    assert eng._site_counts["chunk"] == chunks_before
    for r in _repeat_requests(cfg, n_unique=2):
        np.testing.assert_array_equal(first[r.rid], second[100 + r.rid])


# ---------------------------------------------------------------------------
# engine: terminal sharing, every family (full prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_terminal_repeat_bit_exact_all_families(setup, family):
    """Sequential-state families (SSM/hybrid/encdec) share at terminal
    granularity only; the repeated prompts must still stream bitwise
    what the cold engine streams, and must actually hit."""
    cfg, params = setup(family)
    reqs = lambda: _repeat_requests(cfg, repeats=2)  # noqa: E731
    cold = _engine(cfg, params).run(reqs(),
                                    clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, prefix_cache=64)
    warm = eng.run(reqs(), clock=scheduler.FastForwardClock())
    _assert_bit_exact(cold, warm)
    info = eng.cache_info()["prefix_cache"]
    assert info["hits"] > 0
    assert info["chain_ok"] is False        # no chunking -> no chains


# ---------------------------------------------------------------------------
# engine: admission token budget (fairness satellite)
# ---------------------------------------------------------------------------

def test_admit_token_budget_defers_and_stays_bit_exact(setup):
    cfg, params = setup("dense")
    reqs = lambda: _zipf_requests(cfg, n=8, rate=500.0)  # noqa: E731
    ref = _engine(cfg, params, prefill_chunk=4).run(
        reqs(), clock=scheduler.FastForwardClock())
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64,
                  admit_token_budget=8)
    out = eng.run(reqs(), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)             # deferral reorders nothing
    adm = eng.cache_info()["admission"]
    assert adm["token_budget"] == 8 and adm["deferrals"] > 0
    assert all(r.outcome == res.OK for r in eng.finished)


def test_budget_head_request_always_admitted(setup):
    """A prompt wider than the whole budget must not starve."""
    cfg, params = setup("dense")
    r = scheduler.Request(rid=0, prompt=np.arange(20) % cfg.vocab,
                          max_new_tokens=3)
    eng = _engine(cfg, params, prefill_chunk=4, admit_token_budget=4)
    out = eng.run([r], clock=scheduler.FastForwardClock())
    assert len(out[0]) == 3


# ---------------------------------------------------------------------------
# engine: chaos + recovery through prefix hits
# ---------------------------------------------------------------------------

def test_chaos_recovery_with_prefix_hits_bit_exact(setup):
    """Faults on chunk + segment sites while the pool is hot: recovery
    replays through admission (which may now HIT), and every stream must
    equal the fault-free cold-cache run."""
    cfg, params = setup("dense")
    reqs = lambda: _zipf_requests(cfg)  # noqa: E731
    ref = _engine(cfg, params, prefill_chunk=4).run(
        reqs(), clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("chunk:1", "segment:2"))
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64,
                  chaos=chaos)
    out = eng.run(reqs(), clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["faults_injected"] >= 2
    assert rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_chaos_recovery_terminal_path_bit_exact(setup):
    """Same bar for a sequential-state family on the terminal-only
    (full-prefill) path."""
    cfg, params = setup("ssm")
    reqs = lambda: _repeat_requests(cfg, repeats=2)  # noqa: E731
    ref = _engine(cfg, params).run(reqs(),
                                   clock=scheduler.FastForwardClock())
    chaos = res.ChaosSchedule(fail_at_sites=("prefill:1", "segment:2"))
    eng = _engine(cfg, params, prefix_cache=64, chaos=chaos)
    out = eng.run(reqs(), clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


# ---------------------------------------------------------------------------
# engine: mesh + elastic degrade
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_mesh
def test_sharded_warm_stream_matches_single_device_cold(setup):
    cfg, params = setup("dense")
    reqs = lambda: _zipf_requests(cfg)  # noqa: E731
    ref = _engine(cfg, params, prefill_chunk=4).run(
        reqs(), clock=scheduler.FastForwardClock())
    mesh = make_mesh((2, 1), ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64)
    out = eng.run(reqs(), clock=scheduler.FastForwardClock())
    _assert_bit_exact(ref, out)
    info = eng.cache_info()["prefix_cache"]
    assert info["hits"] > 0
    assert info["mesh_fingerprint"] is not None


@needs_mesh
def test_degrade_reshards_pooled_pages_bit_exact(setup):
    """Lose half the mesh mid-run with a hot pool: host-resident pages
    re-enter device state under the shrunken plan's specs, the pool
    records the re-mesh, and surviving streams stay bitwise equal to the
    fault-free single-device run."""
    cfg, params = setup("dense")
    reqs = lambda: _zipf_requests(cfg)  # noqa: E731
    ref = _engine(cfg, params, prefill_chunk=4).run(
        reqs(), clock=scheduler.FastForwardClock())
    inj = elastic.DeviceLossInjector.parse("lose@segment:1=1")
    mesh = make_mesh((2, 1), ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=64,
                      chaos=inj)
    out = eng.run(reqs(), clock=scheduler.FastForwardClock())
    assert eng.cache_info()["robustness"]["degraded"] >= 1
    info = eng.cache_info()["prefix_cache"]
    assert info["remeshes"] >= 1            # fingerprint rolled over
    _assert_bit_exact(ref, out)


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def test_cache_info_reports_pool_and_budget(setup):
    cfg, params = setup("dense")
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=32,
                  admit_token_budget=64)
    info = eng.cache_info()
    pc = info["prefix_cache"]
    for k in ("hits", "misses", "hit_rate", "tokens_skipped",
              "pages_resident", "pages_evicted", "pages_pinned",
              "max_pages", "remeshes", "mesh_fingerprint"):
        assert k in pc
    assert info["admission"] == {"token_budget": 64, "deferrals": 0}
    # prefix-less engines still report the admission block
    plain = _engine(cfg, params)
    assert "prefix_cache" not in plain.cache_info()
    assert plain.cache_info()["admission"]["token_budget"] is None
