"""Training infrastructure: optimizer, microbatching, data determinism,
checkpoint/restart, failure injection, compression, fault detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, configs
from repro.data import DataConfig, make_stream
from repro.distributed.fault import (Heartbeat,
                                     SimulatedFailure, StragglerDetector)
from repro.models import lm
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_grads, compressed_psum, decompress_grads,
                         global_norm, warmup_cosine)
from repro.training import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    _, state2, _ = adamw_update(params, {"w": jnp.ones((4, 4))}, state, cfg)
    assert state2["v"]["w"].dtype == jnp.bfloat16


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update(params, {"w": jnp.full((3,), 100.0)}, state, cfg)
    assert float(m["clip_scale"]) < 0.01


def test_schedule_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, warmup=10, total=100)) == pytest.approx(
        0.1, abs=1e-5)


def test_microbatch_equivalence():
    """mb=1 and mb=2 must produce identical updates (same total batch)."""
    cfg = configs.get_reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    toks = jax.random.randint(rng, (4, 17), 0, cfg.vocab)
    outs = []
    for mb in (1, 2):
        tcfg = TrainConfig(microbatches=mb, remat=False,
                           optimizer=AdamWConfig(lr=1e-3))
        opt = adamw_init(params, tcfg.optimizer)
        p2, _, m = make_train_step(cfg, tcfg)(params, opt,
                                              {"tokens": toks})
        outs.append((p2, float(m["loss"])))
    (pa, la), (pb, lb) = outs
    assert la == pytest.approx(lb, rel=1e-3)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), pa, pb)
    assert max(jax.tree_util.tree_leaves(diffs)) < 2e-2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=7)
    s1, s2 = make_stream(cfg), make_stream(cfg)
    np.testing.assert_array_equal(s1.batch_at(13), s2.batch_at(13))
    assert not np.array_equal(s1.batch_at(13), s1.batch_at(14))


def test_data_host_sharding():
    h0 = make_stream(DataConfig(16, 4, 100, seed=1, n_hosts=2, host_id=0))
    h1 = make_stream(DataConfig(16, 4, 100, seed=1, n_hosts=2, host_id=1))
    assert h0.batch_at(5).shape == (2, 17)
    assert not np.array_equal(h0.batch_at(5), h1.batch_at(5))


def test_mmap_stream(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10000, dtype=np.int32).tofile(path)
    s = make_stream(DataConfig(16, 2, 100, source="mmap", path=str(path)))
    b = s.batch_at(0)
    assert b.shape == (2, 17)
    # windows are contiguous slices of the file
    assert np.all(np.diff(b, axis=1) == 1)


def test_iterate_resume():
    s = make_stream(DataConfig(8, 2, 50, seed=3))
    it = s.iterate(start_step=5)
    np.testing.assert_array_equal(next(it), s.batch_at(5))
    np.testing.assert_array_equal(next(it), s.batch_at(6))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "step": jnp.int32(7)}}
    checkpoint.save_checkpoint(str(tmp_path), 42, tree)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    restored, step = checkpoint.restore_checkpoint(str(tmp_path), like)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_keep_last_k(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        checkpoint.save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 4
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    checkpoint.save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_000000099")   # no _COMMITTED marker
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_checkpoint_restores_quantized_tree(tmp_path):
    from repro.quant.qtensor import quantize_tree_for_serving
    w = {"blocks": {"mlp": {"wi": jnp.ones((2, 256, 256), jnp.bfloat16)}}}
    q = quantize_tree_for_serving(w, "w8a8")
    checkpoint.save_checkpoint(str(tmp_path), 5, q)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), q)
    restored, _ = checkpoint.restore_checkpoint(str(tmp_path), like)
    qt = restored["blocks"]["mlp"]["wi"]
    assert qt.fmt == "w8a8"
    np.testing.assert_array_equal(np.asarray(qt.q),
                                  np.asarray(q["blocks"]["mlp"]["wi"].q))


# ---------------------------------------------------------------------------
# failure injection + restart (end-to-end via the training driver)
# ---------------------------------------------------------------------------

def test_train_driver_restart_after_failures(tmp_path):
    import argparse

    from repro.launch import train as train_mod

    args = argparse.Namespace(
        arch="smollm-135m", reduced=True, steps=24, batch=2, seq=16,
        lr=1e-3, microbatches=1, mesh="1x1", seed=0,
        ckpt_dir=str(tmp_path), ckpt_every=8, log_every=8,
        simulate_failures="10,18", max_restarts=5, sim_hosts=2)
    out = train_mod.run(args)
    assert out["restores"] == 2          # both failures recovered
    assert np.isfinite(out["final_loss"])
    assert checkpoint.latest_step(str(tmp_path)) == 24


def test_restart_policy_gives_up():
    from repro.distributed.fault import RestartPolicy
    p = RestartPolicy(max_restarts=2)
    exc = SimulatedFailure("x")
    assert p.should_restart(exc)
    assert p.should_restart(exc)
    assert not p.should_restart(exc)


# ---------------------------------------------------------------------------
# straggler / heartbeat / compression
# ---------------------------------------------------------------------------

def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(n_hosts=4, threshold=1.5)
    for step in range(20):
        for h in range(4):
            det.report(step, h, 1.0 if h != 2 else 3.0)
    assert det.stragglers(20) == [2]


def test_heartbeat_dead_hosts():
    hb = Heartbeat(n_hosts=3, timeout_s=10.0)
    now = max(hb.last_seen.values())
    hb.beat(0, t=now + 15)
    assert hb.dead_hosts(now=now + 20) == [1, 2]


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)}
    acc = jnp.zeros((256,))
    err = None
    for _ in range(64):
        q, s, err = compress_grads(g_true, err)
        acc = acc + decompress_grads(q, s)["w"]
    # time-averaged compressed gradient converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 64),
                               np.asarray(g_true["w"]), atol=0.02)


def test_compressed_psum_under_shard_map():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}

    def f(gl):
        q, s, _ = compress_grads(gl)
        return compressed_psum(q, s, "data")

    out = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P())(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=0.05)
