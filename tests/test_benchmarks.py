"""Benchmark harness sanity: the paper-table metrics come out in the
published ballpark and the CNN study parity assertions hold."""
import pytest


def test_table1a_densities():
    from benchmarks import table1a
    rows = {r["name"]: r for r in table1a.run()}
    # paper: Ops/Unit -> ~3.3, ~70% DSP reduction on the add group
    assert rows["vadd"]["ops_per_unit_silvia"] >= 3.0
    assert rows["SNN"]["ops_per_unit_silvia"] >= 3.0
    assert rows["vadd"]["unit_reduction_pct"] >= 70
    assert rows["SNN"]["unit_reduction_pct"] >= 70


def test_table1b_densities():
    from benchmarks import table1b
    rows = {r["name"]: r for r in table1b.run()}
    assert rows["MVM"]["ops_per_unit_silvia"] == 2.0
    assert rows["MMM"]["ops_per_unit_silvia"] == 2.0
    assert rows["MMM-4b"]["ops_per_unit_silvia"] == 4.0
    assert rows["scal"]["ops_per_unit_silvia"] == 2.0
    assert rows["axpy"]["ops_per_unit_silvia"] == 2.0
    assert 1.0 < rows["GSM"]["ops_per_unit_silvia"] < 2.0  # partial (1.58)
    assert rows["GAT"]["ops_per_unit_silvia"] >= 1.9       # paper 1.97
    # group mean ~50% unit reduction (paper)
    mean_red = sum(r["unit_reduction_pct"] for r in rows.values()) / len(rows)
    assert mean_red >= 40


def test_table2_auto_matches_manual():
    from benchmarks import table2_cnn
    rows = table2_cnn.run()
    assert all(r["match"] for r in rows)
    names = {r["name"] for r in rows}
    assert names == {"ResNet8", "ResNet20", "CNV-8b", "MobileNet-4b"}


# ---------------------------------------------------------------------------
# scripts/bench_compare.py regression gate
# ---------------------------------------------------------------------------

def _bench_compare():
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).parent.parent / "scripts" / "bench_compare.py"
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _payload(tok_s, host=None):
    out = {"engine": {"agg_tok_s": tok_s}}
    if host is not None:
        out["host_class"] = host
    return out


@pytest.fixture
def bench_dirs(tmp_path):
    import json
    base = tmp_path / "baselines"
    cur = tmp_path / "results"
    base.mkdir(), cur.mkdir()

    def write(payloads):
        for d, p in zip((base, cur), payloads):
            (d / "serve_throughput_dense.json").write_text(json.dumps(p))
        return base, cur
    return write


def test_bench_compare_fails_on_regression(bench_dirs, capsys):
    bc = _bench_compare()
    host = {"backend": "cpu", "cpus": 8}
    base, cur = bench_dirs([_payload(100.0, host), _payload(50.0, host)])
    assert bc.compare(base, cur, 0.30) == 1
    assert "FAIL serve_throughput_dense" in capsys.readouterr().out


def test_bench_compare_skips_on_host_class_mismatch(bench_dirs, capsys):
    """A baseline recorded on a different host class is warned about and
    skipped -- the gate must bind to code, not runner hardware."""
    bc = _bench_compare()
    base, cur = bench_dirs([_payload(100.0, {"backend": "cpu", "cpus": 64}),
                            _payload(50.0, {"backend": "cpu", "cpus": 8})])
    assert bc.compare(base, cur, 0.30) == 0
    out = capsys.readouterr().out
    assert "host-class mismatch" in out
    assert "1 skipped" in out


def test_bench_compare_unstamped_baseline_still_compares(bench_dirs):
    """Pre-host-class baselines (no stamp) keep gating (back-compat)."""
    bc = _bench_compare()
    base, cur = bench_dirs([_payload(100.0),
                            _payload(50.0, {"backend": "cpu", "cpus": 8})])
    assert bc.compare(base, cur, 0.30) == 1
    base, cur = bench_dirs([_payload(100.0), _payload(95.0)])
    assert bc.compare(base, cur, 0.30) == 0


def test_write_bench_json_stamps_host_class(tmp_path, monkeypatch):
    import json
    from benchmarks import common
    monkeypatch.setenv("BENCH_DIR", str(tmp_path))
    common.write_bench_json({"engine": {"agg_tok_s": 1.0}}, "stamped")
    payload = json.loads((tmp_path / "stamped.json").read_text())
    assert payload["host_class"] == common.host_class()
    assert set(payload["host_class"]) == {
        "platform", "machine", "cpus", "backend", "device_kind"}
