"""Benchmark harness sanity: the paper-table metrics come out in the
published ballpark and the CNN study parity assertions hold."""
import pytest


def test_table1a_densities():
    from benchmarks import table1a
    rows = {r["name"]: r for r in table1a.run()}
    # paper: Ops/Unit -> ~3.3, ~70% DSP reduction on the add group
    assert rows["vadd"]["ops_per_unit_silvia"] >= 3.0
    assert rows["SNN"]["ops_per_unit_silvia"] >= 3.0
    assert rows["vadd"]["unit_reduction_pct"] >= 70
    assert rows["SNN"]["unit_reduction_pct"] >= 70


def test_table1b_densities():
    from benchmarks import table1b
    rows = {r["name"]: r for r in table1b.run()}
    assert rows["MVM"]["ops_per_unit_silvia"] == 2.0
    assert rows["MMM"]["ops_per_unit_silvia"] == 2.0
    assert rows["MMM-4b"]["ops_per_unit_silvia"] == 4.0
    assert rows["scal"]["ops_per_unit_silvia"] == 2.0
    assert rows["axpy"]["ops_per_unit_silvia"] == 2.0
    assert 1.0 < rows["GSM"]["ops_per_unit_silvia"] < 2.0  # partial (1.58)
    assert rows["GAT"]["ops_per_unit_silvia"] >= 1.9       # paper 1.97
    # group mean ~50% unit reduction (paper)
    mean_red = sum(r["unit_reduction_pct"] for r in rows.values()) / len(rows)
    assert mean_red >= 40


def test_table2_auto_matches_manual():
    from benchmarks import table2_cnn
    rows = table2_cnn.run()
    assert all(r["match"] for r in rows)
    names = {r["name"] for r in rows}
    assert names == {"ResNet8", "ResNet20", "CNV-8b", "MobileNet-4b"}
