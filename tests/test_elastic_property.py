"""Property-based device-loss testing: loss events share the counted
dispatch-site namespace (``segment:/prefill:/chunk:N``) with plain
faults, so a seeded schedule of EITHER kind replays identically -- and
for RANDOM loss schedules mixed with fault arms and deadline mixes,
every surviving stream stays byte-identical to the fault-free run
(DESIGN.md sec. 9's determinism contract, stated over the schedule
space instead of hand-picked sites).

Like tests/test_resilience_property.py, the reference invariant is
prefix-wise so it is timing-robust; the twin-run invariant (two engines
armed with IDENTICAL schedules) is exact -- same fired sites, same lost
devices, same tokens."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.distributed import elastic
from repro.distributed.fault import SimulatedFailure
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b"}
PLENS = (5, 12, 9, 16, 7)
GENS = (7, 5, 8, 4, 6)
_KINDS = sorted(res.ChaosSchedule.SITE_KINDS)


@pytest.fixture(scope="module")
def setups():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
        out[fam] = (cfg, params)
    return out


def _traffic(cfg, ttls):
    reqs = []
    for i, (pl, g) in enumerate(zip(PLENS, GENS)):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(31 + 10 * i), (pl,), 0, cfg.vocab))
        r = scheduler.Request(rid=i, prompt=prompt, max_new_tokens=g,
                              arrival_time=0.01 * i)
        if ttls[i] is not None:
            r.deadline = r.arrival_time + ttls[i]
        reqs.append(r)
    return reqs


def _injector(loss, faults):
    return elastic.DeviceLossInjector(
        fail_at_sites=tuple(f"{k}:{i}" for k, i in faults),
        lose_at_sites=tuple((f"{k}:{i}", n) for k, i, n in loss))


def _run(cfg, params, ttls, chaos):
    eng = ServeEngine(params, cfg, n_slots=3, max_cache_len=64,
                      segment_len=4, chaos=chaos)
    eng.run(_traffic(cfg, ttls), clock=scheduler.FastForwardClock())
    return eng


# fault-free reference streams, cached per (family, deadline-mix)
_REF_CACHE: dict = {}


def _reference(setups, fam, ttls):
    key = (fam, ttls)
    if key not in _REF_CACHE:
        cfg, params = setups[fam]
        _REF_CACHE[key] = _run(cfg, params, ttls, chaos=None)
    return _REF_CACHE[key]


# a loss schedule: (site-kind, dispatch-index, devices-to-lose) triples;
# indices beyond the run's dispatch count simply never fire
_LOSS = st.lists(
    st.tuples(st.sampled_from(_KINDS), st.integers(0, 7),
              st.integers(1, 4)),
    min_size=1, max_size=2, unique_by=lambda t: t[:2])

# plain fault arms riding along (possibly colliding with a loss site:
# loss wins there, which must itself replay deterministically)
_FAULTS = st.lists(
    st.tuples(st.sampled_from(_KINDS), st.integers(0, 7)),
    min_size=0, max_size=2, unique=True)

_TTL_MIXES = st.lists(st.sampled_from([None, 1e6, 0.0]),
                      min_size=len(PLENS), max_size=len(PLENS))


@given(loss=_LOSS, faults=_FAULTS, n_sites=st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_injector_tape_replays_identically(loss, faults, n_sites):
    """Walk the same counted-site tape with two fresh, identically-armed
    injectors: every DeviceLoss and every plain fault fires at the same
    site with the same device count, and every fired site lives in the
    shared kind:index namespace."""
    tape = [f"{k}:{i}" for i in range(n_sites) for k in _KINDS]
    logs = []
    for _ in range(2):
        inj = _injector(loss, faults)
        log = []
        for site in tape:
            try:
                inj.check_site(site)
                log.append((site, "ok", 0))
            except elastic.DeviceLoss as e:
                log.append((site, "lose", e.n_lost))
            except SimulatedFailure:
                log.append((site, "fail", 0))
        logs.append((log, dict(inj.lost_sites), frozenset(inj.failed)))
    assert logs[0] == logs[1]
    log, lost_sites, failed = logs[0]
    assert set(lost_sites) <= failed
    for site in failed:
        kind, _, idx = site.partition(":")
        assert kind in res.ChaosSchedule.SITE_KINDS and idx.isdigit()


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
@given(loss=_LOSS, faults=_FAULTS, ttls=_TTL_MIXES)
@settings(max_examples=4, deadline=None)
def test_streams_bit_identical_under_random_loss(setups, fam, loss,
                                                 faults, ttls):
    ttls = tuple(ttls)
    cfg, params = setups[fam]
    ref = _reference(setups, fam, ttls)
    eng = _run(cfg, params, ttls, _injector(loss, faults))
    twin = _run(cfg, params, ttls, _injector(loss, faults))

    rb = eng.cache_info()["robustness"]
    assert rb["replay_divergence"] == 0
    assert rb["faults_injected"] == len(eng._chaos.failed)
    assert rb["recoveries"] >= rb["faults_injected"]
    # loss accounting lives in the fault-site namespace
    assert set(eng._chaos.lost_sites) <= eng._chaos.failed

    # twin determinism: identical schedules fire identically and the
    # engines emit identical streams with identical outcomes
    assert eng._chaos.failed == twin._chaos.failed
    assert eng._chaos.lost_sites == twin._chaos.lost_sites
    a_res, b_res = eng.results(), twin.results()
    assert set(a_res) == set(b_res)
    for rid in a_res:
        np.testing.assert_array_equal(
            np.asarray(a_res[rid].tokens, np.int64),
            np.asarray(b_res[rid].tokens, np.int64))
        assert a_res[rid].outcome == b_res[rid].outcome

    # prefix-wise vs the fault-free reference (recovery adds wall-clock
    # steps, so a mid-flight deadline may lapse at a different boundary)
    got_res, ref_res = a_res, ref.results()
    assert set(ref_res) == set(got_res) == set(range(len(PLENS)))
    for rid in got_res:
        a = np.asarray(got_res[rid].tokens, np.int64)
        b = np.asarray(ref_res[rid].tokens, np.int64)
        n = min(len(a), len(b))
        np.testing.assert_array_equal(a[:n], b[:n])
        if got_res[rid].outcome == res.OK and ref_res[rid].outcome == res.OK:
            assert len(a) == len(b)
        if ttls[rid] == 0.0:
            assert got_res[rid].outcome == ref_res[rid].outcome \
                == res.EXPIRED
            assert len(a) == 0
