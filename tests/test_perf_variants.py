"""The §Perf levers must preserve semantics: grouped / shard_map MoE
dispatch, chunked attention, int8 KV cache, pure-DP sharding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import context
from repro.launch.mesh import make_mesh
from repro.models import lm, mlp


def _moe_cfg(cf=8.0, **kw):
    cfg = configs.get_reduced_config("granite-moe-1b-a400m")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf, **kw))


def test_grouped_dispatch_matches_global():
    rng = jax.random.PRNGKey(0)
    cfg = _moe_cfg()
    p = mlp.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
    y1, a1 = mlp.moe(p, x, cfg)
    y2, a2 = mlp.moe(p, x, _moe_cfg(dispatch="grouped", dispatch_groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert abs(float(a1 - a2)) < 1e-5


def test_shard_map_dispatch_matches_global():
    rng = jax.random.PRNGKey(1)
    cfg = _moe_cfg()
    p = mlp.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
    y1, a1 = mlp.moe(p, x, cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg_sm = _moe_cfg(dispatch="shard_map")
    with context.mesh_scope(mesh, ("data",), "model"):
        y2, a2 = jax.jit(lambda p, x: mlp.moe(p, x, cfg_sm))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert abs(float(a1 - a2)) < 1e-5


def test_shard_map_dispatch_differentiable():
    rng = jax.random.PRNGKey(2)
    cfg = _moe_cfg(dispatch="shard_map")
    p = mlp.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), jnp.float32)
    mesh = make_mesh((1, 1), ("data", "model"))
    with context.mesh_scope(mesh, ("data",), "model"):
        g = jax.jit(jax.grad(
            lambda p, x: jnp.sum(mlp.moe(p, x, cfg)[0] ** 2)))(p, x)
    total = sum(float(jnp.abs(l).sum())
                for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0


def test_chunked_attention_matches_full():
    cfg0 = dataclasses.replace(configs.get_reduced_config("yi-6b"),
                               dtype="float32")
    cfg1 = dataclasses.replace(cfg0, attn_q_chunk=16)
    rng = jax.random.PRNGKey(3)
    params = lm.init_params(rng, cfg0, max_seq=72)
    toks = jax.random.randint(rng, (2, 64), 0, cfg0.vocab)
    l0, _ = lm.forward(params, toks, cfg0, remat=False)
    l1, _ = lm.forward(params, toks, cfg1, remat=False)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_decode_accuracy():
    cfg = dataclasses.replace(configs.get_reduced_config("yi-6b"),
                              dtype="float32", serve_kv_dtype="int8")
    rng = jax.random.PRNGKey(4)
    S = 32
    params = lm.init_params(rng, cfg, max_seq=S * 2)
    toks = jax.random.randint(rng, (2, S + 1), 0, cfg.vocab)
    lg_full, _ = lm.forward(params, toks, cfg, remat=False)
    _, cache = lm.prefill(params, toks[:, :S], cfg, cache_len=S + 8)
    assert cache["attn"]["k"].dtype == jnp.int8 if "attn" in cache else True
    flat = jax.tree_util.tree_leaves(cache)
    assert any(l.dtype == jnp.int8 for l in flat)
    lg_dec, _ = lm.decode_step(params, toks[:, S:S + 1], cache,
                               jnp.full((2,), S, jnp.int32), cfg)
    rel = float(jnp.abs(lg_dec[:, 0] - lg_full[:, S]).max()
                / jnp.abs(lg_full).max())
    assert rel < 0.05, rel


def test_pure_dp_specs_replicate_tp():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_pspecs
    cfg = configs.get_reduced_config("smollm-135m")
    mesh = make_mesh((1, 1), ("data", "model"))
    params = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=64))
    specs = param_pspecs(params, mesh, cfg, mode="pure_dp")
    flat = jax.tree_util.tree_leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
    for s in flat:
        for entry in s:
            if entry is not None:
                axes = entry if isinstance(entry, tuple) else (entry,)
                assert "model" not in axes or len(axes) > 1, s
