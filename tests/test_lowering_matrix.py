"""Lowering parity matrix: every registered lowering of every packed op
must match the `ref` oracle bit-exactly, across dtypes / lane_bits /
shapes -- including the Pallas families, which run in interpret mode on
non-native hosts.  Plus end-to-end: forced-lowering engine serving stays
bit-identical to the static generate() path (incl. --silvia all)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref, registry

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# every lowering is exercised on this host: native ones resolve, foreign
# Pallas ones are forced (they fall back to interpret mode)
LOWERINGS = ("ref", "cpu-vector", "tpu-pallas", "gpu-pallas")
SHAPES = [(7,), (64,), (8, 33)]


def _assert_equal(got, want):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _simd_add_case(shape, lane_bits, sub, seed=0):
    rng = np.random.default_rng(seed)
    k = 32 // lane_bits
    dt = jnp.int8 if lane_bits == 8 else jnp.int16
    lo, hi = (-128, 128) if lane_bits == 8 else (-32768, 32768)
    xs = [jnp.asarray(rng.integers(lo, hi, shape), dt) for _ in range(k)]
    ys = [jnp.asarray(rng.integers(lo, hi, shape), dt) for _ in range(k)]
    return (xs, ys), {"lane_bits": lane_bits, "sub": sub}


def _muladd2_case(shape, n=4, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda lo, hi: [jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)
                         for _ in range(n)]
    return (mk(-8, 8), mk(-8, 8), mk(-128, 128)), {}


def _mul4_case(shape, seed=0):
    rng = np.random.default_rng(seed)
    a = [jnp.asarray(rng.integers(-8, 8, shape), jnp.int8) for _ in range(4)]
    b = jnp.asarray(rng.integers(-8, 8, shape), jnp.int8)
    return (a, b), {}


def _matmul_case(packed, mkn=(9, 96, 34), out_dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    m, k, n = mkn
    x_q = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    x_s = jnp.asarray(rng.random((m, 1)), jnp.float32)
    w_s = jnp.asarray(rng.random((1, n)), jnp.float32)
    if packed:
        w = ref.pack_w4(jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8))
    else:
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    return (x_q, w, x_s, w_s), {"out_dtype": out_dtype}


# ---------------------------------------------------------------------------
# the matrix: dispatch under each forced lowering == dispatch under ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lid", LOWERINGS)
@pytest.mark.parametrize("lane_bits,sub", [(8, False), (8, True),
                                           (16, False), (16, True)])
def test_simd_add_matrix(lid, lane_bits, sub):
    for shape in SHAPES:
        args, kw = _simd_add_case(shape, lane_bits, sub)
        want = ref.simd_add_ref(args[0], args[1], sub=sub,
                                lane_bits=lane_bits)
        with registry.force(simd_add=lid):
            _assert_equal(registry.dispatch("simd_add", *args, **kw), want)


@pytest.mark.parametrize("lid", LOWERINGS)
@pytest.mark.parametrize("n", [1, 4])
def test_muladd2_matrix(lid, n):
    for shape in SHAPES:
        args, kw = _muladd2_case(shape, n=n)
        want = ref.muladd2_ref(*args)
        with registry.force(muladd2=lid):
            _assert_equal(registry.dispatch("muladd2", *args, **kw), want)


@pytest.mark.parametrize("lid", LOWERINGS)
def test_mul4_matrix(lid):
    for shape in SHAPES:
        args, kw = _mul4_case(shape)
        want = ref.mul4_ref(*args)
        with registry.force(mul4=lid):
            _assert_equal(registry.dispatch("mul4", *args, **kw), want)


@pytest.mark.parametrize("lid", LOWERINGS)
@pytest.mark.parametrize("op,out_dtype", [
    ("quant_matmul", jnp.float32), ("quant_matmul", jnp.bfloat16),
    ("packed_w4_matmul", jnp.float32), ("packed_w4_matmul", jnp.bfloat16),
])
def test_matmul_matrix(lid, op, out_dtype):
    args, kw = _matmul_case(op == "packed_w4_matmul", out_dtype=out_dtype)
    oracle = ref.quant_matmul_ref if op == "quant_matmul" \
        else ref.packed_w4_matmul_ref
    want = oracle(*args, out_dtype)
    with registry.force(**{op: lid}):
        got = registry.dispatch(op, *args, **kw)
    assert got.dtype == jnp.dtype(out_dtype)
    _assert_equal(got, want)


def test_ops_compat_wrappers_match_oracle():
    """kernels.ops is kept as the historical API surface; its wrappers
    must stay exact pass-throughs to registry.dispatch."""
    from repro.kernels import ops

    with registry.force("ref"):
        args, kw = _simd_add_case((9,), 8, False)
        _assert_equal(ops.simd_add(*args, **kw),
                      ref.simd_add_ref(args[0], args[1], lane_bits=8))
        (a, b, c), _ = _muladd2_case((9,))
        _assert_equal(ops.muladd2(a, b, c), ref.muladd2_ref(a, b, c))
        (a4, b4), _ = _mul4_case((9,))
        _assert_equal(ops.mul4(a4, b4), ref.mul4_ref(a4, b4))
        qargs, _ = _matmul_case(False, mkn=(4, 32, 16))
        _assert_equal(ops.quant_matmul(*qargs),
                      ref.quant_matmul_ref(*qargs))
        pargs, _ = _matmul_case(True, mkn=(4, 32, 16))
        _assert_equal(ops.packed_w4_matmul(*pargs),
                      ref.packed_w4_matmul_ref(*pargs))


def test_matrix_covers_every_registered_lowering():
    """The LOWERINGS tuple above must not silently lag the registry."""
    for op in registry.ops():
        assert set(registry.lowering_ids(op)) == set(LOWERINGS), op


# ---------------------------------------------------------------------------
# hypothesis sweep (where installed): random shapes/values, every lowering
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(st.sampled_from(SHAPES + [(257,), (3, 5, 7)]),
           st.sampled_from([8, 16]), st.booleans(),
           st.sampled_from(LOWERINGS), st.integers(0, 2**31))
    def test_simd_add_matrix_property(shape, lane_bits, sub, lid, seed):
        args, kw = _simd_add_case(shape, lane_bits, sub, seed=seed)
        want = ref.simd_add_ref(args[0], args[1], sub=sub,
                                lane_bits=lane_bits)
        with registry.force(simd_add=lid):
            _assert_equal(registry.dispatch("simd_add", *args, **kw), want)

    @settings(max_examples=10, deadline=None)
    @given(st.sampled_from(SHAPES), st.sampled_from([1, 2, 4]),
           st.sampled_from(LOWERINGS), st.integers(0, 2**31))
    def test_muladd2_matrix_property(shape, n, lid, seed):
        args, kw = _muladd2_case(shape, n=n, seed=seed)
        want = ref.muladd2_ref(*args)
        with registry.force(muladd2=lid):
            _assert_equal(registry.dispatch("muladd2", *args, **kw), want)


# ---------------------------------------------------------------------------
# end to end: forced-lowering engine == static generate(), incl. SILVIA
# ---------------------------------------------------------------------------

def _quantize_all_blocks(params, fmt):
    """Quantize every stacked 3-D block weight, bypassing the size/width
    floors of quantize_tree_for_serving: the reduced test configs are below
    those floors, and these tests NEED the decode graph to actually contain
    registry-dispatched quantized matmuls."""
    from repro.quant.qtensor import quantize_weight

    def visit(leaf):
        if getattr(leaf, "ndim", 0) == 3 and leaf.dtype == jnp.bfloat16:
            return quantize_weight(leaf, fmt)
        return leaf

    return jax.tree_util.tree_map(visit, params)


@pytest.fixture(scope="module")
def serving_setup():
    from repro import configs
    from repro.models import lm
    from repro.quant.qtensor import QTensor

    cfg = configs.get_reduced_config("smollm-135m")
    params = _quantize_all_blocks(
        lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=48), "w8a8")
    n_q = sum(isinstance(l, QTensor) for l in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QTensor)))
    assert n_q > 0, "decode graph would contain no packed-op dispatches"
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab))
    return cfg, params, prompts


@pytest.fixture
def forced_env(monkeypatch):
    """Force a lowering through the real REPRO_LOWERING env path (with the
    explicit invalidate the satellite task mandates), and restore after."""
    def _force(spec):
        monkeypatch.setenv("REPRO_LOWERING", spec)
        registry.invalidate()

    yield _force
    monkeypatch.delenv("REPRO_LOWERING", raising=False)
    registry.invalidate()


@pytest.mark.parametrize("lid,silvia_passes",
                         [("ref", "all"), ("cpu-vector", "all"),
                          ("cpu-vector", "off")])
def test_engine_matches_static_under_forced_lowering(serving_setup,
                                                     forced_env, lid,
                                                     silvia_passes):
    from repro.launch import scheduler, serve
    from repro.launch.engine import ServeEngine

    cfg, params, prompts = serving_setup
    forced_env(f"*={lid}")
    static = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, gen=4, cache_len=16,
        silvia_passes=silvia_passes))
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=32,
                      segment_len=2, silvia_passes=silvia_passes)
    assert eng.cache_info()["lowerings"] == \
        {op: lid for op in registry.ops()}
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=4)
            for i in range(2)]
    out = eng.run(reqs)
    for i in range(2):
        np.testing.assert_array_equal(out[i], static[i])


def test_decode_graph_resolves_through_registry(serving_setup, forced_env):
    """Tracing the decode step must actually consult the registry (i.e.
    the graph contains packed-op dispatches): a bogus forced id fails at
    trace time, it cannot be silently ignored."""
    from repro.launch import serve

    cfg, params, prompts = serving_setup
    forced_env("quant_matmul=no-such-lowering")
    with pytest.raises(ValueError, match="registered"):
        serve.generate(params, jnp.asarray(prompts), cfg, gen=2,
                       cache_len=16)


def test_generate_identical_across_lowerings(serving_setup, forced_env):
    """Greedy tokens must be LOWERING-independent: the whole registry is
    bit-exact, so swapping the forced lowering cannot move one token."""
    from repro.launch import serve

    cfg, params, prompts = serving_setup
    outs = {}
    for lid in ("ref", "cpu-vector"):
        forced_env(f"*={lid}")
        outs[lid] = np.asarray(serve.generate(
            params, jnp.asarray(prompts), cfg, gen=4, cache_len=16))
    np.testing.assert_array_equal(outs["ref"], outs["cpu-vector"])
