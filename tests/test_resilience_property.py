"""Property-based chaos testing: for RANDOM fault schedules and deadline
mixes over ragged traffic, every surviving stream must be byte-identical
to the fault-free run's -- recovery-as-replay admits no drift anywhere in
the schedule space, not just at hand-picked sites (DESIGN.md sec. 8).

The invariant is stated prefix-wise so it is timing-robust: recovery adds
wall-clock steps, so a mid-flight deadline may lapse at a different
segment boundary than in the reference run -- but every token either run
DID emit for a request must match the other's at the same position.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.models import lm

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b"}
PLENS = (5, 12, 9, 16, 7)
GENS = (7, 5, 8, 4, 6)


@pytest.fixture(scope="module")
def setups():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
        out[fam] = (cfg, params)
    return out


def _traffic(cfg, ttls):
    reqs = []
    for i, (pl, g) in enumerate(zip(PLENS, GENS)):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(31 + 10 * i), (pl,), 0, cfg.vocab))
        r = scheduler.Request(rid=i, prompt=prompt, max_new_tokens=g,
                              arrival_time=0.01 * i)
        if ttls[i] is not None:
            r.deadline = r.arrival_time + ttls[i]
        reqs.append(r)
    return reqs


def _run(cfg, params, ttls, chaos):
    eng = ServeEngine(params, cfg, n_slots=3, max_cache_len=64,
                      segment_len=4, chaos=chaos)
    eng.run(_traffic(cfg, ttls), clock=scheduler.FastForwardClock())
    return eng


# fault-free reference streams, cached per (family, deadline-mix): the
# drawn fault schedule never changes the reference, only the chaos run
_REF_CACHE: dict = {}


def _reference(setups, fam, ttls):
    key = (fam, ttls)
    if key not in _REF_CACHE:
        cfg, params = setups[fam]
        _REF_CACHE[key] = _run(cfg, params, ttls, chaos=None)
    return _REF_CACHE[key]


# a fault schedule: up to 3 distinct (site-kind, dispatch-index) pairs --
# indices beyond the run's dispatch count simply never fire, which is
# itself part of the space worth exercising
_SCHEDULES = st.lists(
    st.tuples(st.sampled_from(sorted(res.ChaosSchedule.SITE_KINDS)),
              st.integers(0, 7)),
    min_size=1, max_size=3, unique=True)

# a deadline mix: per-request TTL of never / generous / already-lapsed --
# the lapsed ones exercise queued expiry interleaved with recovery
_TTL_MIXES = st.lists(st.sampled_from([None, 1e6, 0.0]),
                      min_size=len(PLENS), max_size=len(PLENS))


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
@given(sched=_SCHEDULES, ttls=_TTL_MIXES)
@settings(max_examples=6, deadline=None)
def test_surviving_streams_bit_identical(setups, fam, sched, ttls):
    ttls = tuple(ttls)
    cfg, params = setups[fam]
    ref = _reference(setups, fam, ttls)
    chaos = res.ChaosSchedule(
        fail_at_sites=tuple(f"{k}:{i}" for k, i in sched))
    eng = _run(cfg, params, ttls, chaos=chaos)

    rb = eng.cache_info()["robustness"]
    assert rb["replay_divergence"] == 0
    assert rb["faults_injected"] == len(chaos.failed)
    assert rb["recoveries"] >= rb["faults_injected"]

    ref_res, got_res = ref.results(), eng.results()
    assert set(ref_res) == set(got_res) == set(range(len(PLENS)))
    for rid in got_res:
        a = np.asarray(got_res[rid].tokens, np.int64)
        b = np.asarray(ref_res[rid].tokens, np.int64)
        n = min(len(a), len(b))
        np.testing.assert_array_equal(a[:n], b[:n])
        if got_res[rid].outcome == res.OK and ref_res[rid].outcome == res.OK:
            assert len(a) == len(b)
        # already-lapsed deadlines expire identically in both runs
        if ttls[rid] == 0.0:
            assert got_res[rid].outcome == ref_res[rid].outcome == res.EXPIRED
            assert len(a) == 0
