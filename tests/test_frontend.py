"""Async streaming front-end (launch/frontend.py): streamed tokens must
be byte-identical to the batch ServeEngine on the same trace for every
model family, score must reproduce the decode-path logprobs exactly, and
cancellation (explicit, client disconnect, stream backlog) must evict
the slot while keeping the partial tokens.

The engines here use the default ``chaos="env"``: under the CI chaos job
(REPRO_CHAOS set) the SAME equality assertions also prove that streaming
survives fault injection + bit-exact replay.  The mesh test activates
only with >=8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8).

pytest-asyncio is optional in this environment, so every async scenario
is driven through a plain ``asyncio.run()`` inside a sync test.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.launch import methods, scheduler
from repro.launch.engine import ServeEngine
from repro.launch.frontend import AsyncFrontend, serve_requests
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16
_SETUP_CACHE: dict = {}


def _setup(family):
    """(cfg, params) per family, cached across tests in this module."""
    if family not in _SETUP_CACHE:
        cfg = configs.get_reduced_config(FAMILY_ARCHS[family])
        params = quantize_tree_for_serving(
            lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80), "w8a8")
        _SETUP_CACHE[family] = (cfg, params)
    return _SETUP_CACHE[family]


def _make_engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    return ServeEngine(params, cfg, **kw)


def _traffic(cfg, n=8, seed=0):
    reqs = scheduler.method_traffic(
        seed=seed, n_requests=n, rate=200.0, prompt_lens=(4, 7, 12),
        gen_lens=(3, 6), vocab=cfg.vocab)
    feats = None
    if cfg.family == "encdec":
        frng = np.random.default_rng(seed + 1)
        # ragged encoder lengths, including one short enough (<=8) to
        # land in a smaller enc-length bucket than ENC_LEN
        feats = {r.rid: frng.standard_normal(
            (int(frng.integers(3, ENC_LEN + 1)) if r.rid else 5,
             cfg.d_model)).astype(np.float32) for r in reqs}
    return reqs, feats


def _batch_reference(cfg, params, reqs, feats, **engine_kw):
    """The bit-exactness oracle: the same trace through a plain engine
    step loop (no front-end, no streaming)."""
    eng = _make_engine(cfg, params, **engine_kw)
    clock = scheduler.FastForwardClock()
    for r in reqs:
        if feats:
            r.features = feats.get(r.rid)
        eng.submit(r)
    while len(eng.results()) < len(reqs):
        if not eng.step(clock):
            nxt = eng.next_arrival(clock.now())
            if nxt is not None:
                clock.wait_until(nxt)
    return {r.rid: eng.result(r.rid) for r in reqs}


async def _run_frontend(eng, reqs, feats, *, overlap=True):
    """Serve `reqs` through the front-end: every generate request is
    STREAMED (per-token receipt), score/embed awaited.  Returns
    ({rid: RequestResult}, {rid: streamed tokens})."""
    fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock(),
                       overlap=overlap)
    results, stream_toks = {}, {}
    async with fe:
        async def stream_one(req):
            toks = []
            async for t in fe.generate_stream(
                    req.prompt, req.max_new_tokens, rid=req.rid,
                    features=feats.get(req.rid) if feats else None):
                toks.append(t)
            stream_toks[req.rid] = toks

        plain = []
        coros = []
        for r in reqs:
            if r.method == "generate":
                coros.append(stream_one(r))
            else:
                if feats:
                    r.features = feats.get(r.rid)
                plain.append(r)

        async def call_plain():
            results.update(await serve_requests(fe, plain))

        await asyncio.gather(call_plain(), *coros)
    for r in reqs:
        if r.method == "generate":
            results[r.rid] = eng.result(r.rid)
    return results, stream_toks


def _assert_results_equal(ref, got, stream_toks=None):
    assert set(ref) == set(got)
    for rid, a in ref.items():
        b = got[rid]
        assert a is not None and b is not None, rid
        assert a.outcome == b.outcome == "ok", (rid, a.outcome, b.outcome)
        assert list(a.tokens) == list(b.tokens), rid
        if stream_toks is not None and rid in stream_toks:
            assert stream_toks[rid] == list(a.tokens), rid
        if a.logprobs is not None:
            assert b.logprobs is not None and \
                all(x == y for x, y in zip(a.logprobs, b.logprobs)), rid
        if a.embedding is not None:
            assert np.array_equal(a.embedding, b.embedding), rid


# ---------------------------------------------------------------------------
# streamed == batch, all four families (chaos rides in via chaos="env")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_stream_matches_batch(family):
    """Mixed generate/score/embed traffic streamed through the async
    front-end must be bit-identical to the plain batch engine."""
    cfg, params = _setup(family)
    reqs, feats = _traffic(cfg)
    ref = _batch_reference(cfg, params, *_traffic(cfg))
    eng = _make_engine(cfg, params)
    got, stream_toks = asyncio.run(_run_frontend(eng, reqs, feats))
    _assert_results_equal(ref, got, stream_toks)
    assert any(len(v) > 0 for v in stream_toks.values())


def test_stream_matches_batch_silvia_all():
    """The SILVIA pass pipeline under the front-end: streamed tokens
    still equal the (equally silvia'd) batch engine."""
    cfg, params = _setup("dense")
    reqs, feats = _traffic(cfg, seed=23)
    ref = _batch_reference(cfg, params, *_traffic(cfg, seed=23),
                           silvia_passes="all")
    eng = _make_engine(cfg, params, silvia_passes="all")
    got, stream_toks = asyncio.run(_run_frontend(eng, reqs, feats))
    _assert_results_equal(ref, got, stream_toks)


def test_stream_no_overlap_matches_batch():
    """overlap=False (sync two-stage loop) is the same bits too -- the
    pipeline is a latency optimisation, never a semantic one."""
    cfg, params = _setup("dense")
    reqs, feats = _traffic(cfg, seed=3)
    ref = _batch_reference(cfg, params, *_traffic(cfg, seed=3))
    eng = _make_engine(cfg, params)
    got, stream_toks = asyncio.run(
        _run_frontend(eng, reqs, feats, overlap=False))
    _assert_results_equal(ref, got, stream_toks)


def test_stream_prefix_warm_matches_cold():
    """Streaming through a WARM prefix cache (second serving of the same
    trace on one engine) returns the same tokens as the cold pass and
    actually hits the cache."""
    cfg, params = _setup("dense")
    eng = _make_engine(cfg, params, prefill_chunk=4, prefix_cache=64)
    cold, cold_toks = asyncio.run(
        _run_frontend(eng, _traffic(cfg, seed=5)[0], None))
    warm_reqs = _traffic(cfg, seed=5)[0]
    for r in warm_reqs:       # same prompts, fresh rids (one live engine)
        r.rid += 100
    warm, warm_toks = asyncio.run(_run_frontend(eng, warm_reqs, None))
    assert eng.cache_info()["prefix_cache"]["hits"] > 0
    for rid, toks in cold_toks.items():
        assert warm_toks[rid + 100] == toks, rid
    for rid, a in cold.items():
        assert list(warm[rid + 100].tokens) == list(a.tokens), rid


# ---------------------------------------------------------------------------
# score == decode path
# ---------------------------------------------------------------------------

def test_score_matches_decode_path():
    """Per-token completion logprobs from the serve path must equal a
    teacher-forced prefill + decode_step replay, float-for-float."""
    cfg, params = _setup("dense")
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab, 9).astype(np.int32)
    comp = rng.integers(1, cfg.vocab, 5).astype(np.int32)

    async def score():
        eng = _make_engine(cfg, params)
        fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock())
        async with fe:
            return await fe.score(prompt, comp)

    got = asyncio.run(score())

    logits, cache = lm.prefill(params, jnp.asarray(prompt[None]), cfg,
                               cache_len=32)
    ref = [methods.logprob_from_logits(
        np.asarray(logits, np.float32)[0, 0], int(comp[0]))]
    for i in range(len(comp) - 1):
        logits, cache = lm.decode_step(
            params, jnp.asarray([[comp[i]]], jnp.int32), cache,
            jnp.asarray([len(prompt) + i], jnp.int32), cfg)
        ref.append(methods.logprob_from_logits(
            np.asarray(logits, np.float32)[0, 0], int(comp[i + 1])))
    assert got == ref


# ---------------------------------------------------------------------------
# cancellation: explicit, disconnect, backlog
# ---------------------------------------------------------------------------

def test_cancellation_under_load():
    """Disconnecting one stream mid-flight cancels that request (partial
    tokens kept, slot freed) while concurrent requests still finish with
    reference-exact tokens."""
    cfg, params = _setup("dense")
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, 8).astype(np.int32)
               for _ in range(3)]

    async def go():
        eng = _make_engine(cfg, params)
        fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock())
        async with fe:
            agen = fe.generate_stream(prompts[0], 40, rid=0)
            partial = []
            async for t in agen:
                partial.append(t)
                if len(partial) == 3:
                    break
            await agen.aclose()       # client disconnect -> cancel
            survivors = await asyncio.gather(
                fe.generate(prompts[1], 6, rid=1),
                fe.generate(prompts[2], 6, rid=2))
            for _ in range(400):      # let the cancel land in the loop
                if eng.result(0) is not None:
                    break
                await asyncio.sleep(0.005)
        return eng, fe, partial, survivors

    eng, fe, partial, survivors = asyncio.run(go())
    cancelled = eng.result(0)
    assert cancelled is not None and cancelled.outcome == "cancelled"
    assert list(cancelled.tokens)[:3] == partial
    assert fe.stats["disconnect_cancels"] == 1
    assert eng.cache_info()["robustness"]["cancelled_inflight"] >= 1
    # survivors are unaffected: same bits as a solo batch run
    for i, r in enumerate(survivors, start=1):
        solo = _batch_reference(
            cfg, params,
            [methods.generate_request(0, prompts[i], 6)], None)[0]
        assert r.outcome == "ok" and list(r.tokens) == list(solo.tokens)


def test_stream_backlog_evicts_slow_client():
    """A client that stops draining its bounded stream queue is shed:
    the request is cancelled (not the server stalled)."""
    cfg, params = _setup("dense")
    rng = np.random.default_rng(13)

    async def go():
        eng = _make_engine(cfg, params)
        fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock(),
                           stream_queue=2)
        async with fe:
            agen = fe.generate_stream(
                rng.integers(1, cfg.vocab, 8).astype(np.int32), 40, rid=7)
            it = agen.__aiter__()
            await it.__anext__()      # first token, then stop draining
            for _ in range(400):
                if eng.result(7) is not None:
                    break
                await asyncio.sleep(0.005)
            await agen.aclose()
        return eng, fe

    eng, fe = asyncio.run(go())
    r = eng.result(7)
    assert r is not None and r.outcome == "cancelled"
    assert fe.stats["backlog_cancels"] >= 1


def test_validation_error_surfaces_at_await():
    cfg, params = _setup("dense")

    async def go():
        eng = _make_engine(cfg, params)
        fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock())
        async with fe:
            with pytest.raises(ValueError, match="max_cache_len"):
                await fe.generate(np.arange(1, 200, dtype=np.int32), 5)

    asyncio.run(go())


# ---------------------------------------------------------------------------
# ragged encoder lengths: zero-extension exactness across enc_len
# ---------------------------------------------------------------------------

def test_ragged_encdec_cross_enc_len_exact():
    """A short encoder feature served under a LARGER enc_len capacity
    must stream the same tokens as an engine whose capacity is the
    snug bucket -- enc-length bucketing pads with zeros that the masked
    cross-attention provably ignores."""
    cfg, params = _setup("encdec")
    rng = np.random.default_rng(17)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    feat = rng.standard_normal((5, cfg.d_model)).astype(np.float32)

    def stream(enc_len):
        async def go():
            eng = _make_engine(cfg, params, enc_len=enc_len)
            fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock())
            async with fe:
                toks = []
                async for t in fe.generate_stream(prompt, 6, rid=0,
                                                  features=feat):
                    toks.append(t)
            return toks

        return asyncio.run(go())

    assert stream(ENC_LEN) == stream(8)


# ---------------------------------------------------------------------------
# sharded mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="mesh streaming needs 8 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("mesh_shape", [(8, 1), (2, 4)])
def test_stream_matches_batch_sharded(mesh_shape):
    """Streaming through a sharded engine is the same bits as the
    unsharded batch engine."""
    cfg, params = _setup("dense")
    reqs, feats = _traffic(cfg, n=6, seed=19)
    ref = _batch_reference(cfg, params, *_traffic(cfg, n=6, seed=19))
    mesh = make_mesh(mesh_shape, ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        # the slot axis shards over the dp extent, so it must divide it
        eng = _make_engine(cfg, params, n_slots=mesh_shape[0])
        got, stream_toks = asyncio.run(_run_frontend(eng, reqs, feats))
    _assert_results_equal(ref, got, stream_toks)


# ---------------------------------------------------------------------------
# property: any traffic shape streams the batch engine's bits
# ---------------------------------------------------------------------------

def test_stream_property_random_traffic():
    """Random method mixes x random mid-stream disconnects: surviving
    streams byte-identical to the batch engine, disconnected ones end as
    a strict prefix (cancelled) or the full result (finished first --
    the disconnect raced completion)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = _setup("dense")

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(seed=st.integers(0, 10_000), n=st.integers(2, 6),
               cut=st.integers(0, 2))
    def prop(seed, n, cut):
        reqs, feats = _traffic(cfg, n=n, seed=seed)
        ref = _batch_reference(cfg, params, *_traffic(cfg, n=n, seed=seed))
        eng = _make_engine(cfg, params)
        gen_rids = [r.rid for r in reqs if r.method == "generate"]
        drop = set(gen_rids[:cut])

        async def go():
            fe = AsyncFrontend(eng, clock=scheduler.FastForwardClock())
            stream_toks = {}
            async with fe:
                async def stream_one(req):
                    agen = fe.generate_stream(req.prompt,
                                              req.max_new_tokens,
                                              rid=req.rid)
                    toks = []
                    async for t in agen:
                        toks.append(t)
                        if req.rid in drop:
                            break
                    await agen.aclose()
                    stream_toks[req.rid] = toks

                plain = [r for r in reqs if r.method != "generate"]
                await asyncio.gather(
                    serve_requests(fe, plain),
                    *(stream_one(r) for r in reqs
                      if r.method == "generate"))
                for _ in range(400):   # let raced cancels land
                    if all(eng.result(r.rid) is not None for r in reqs):
                        break
                    await asyncio.sleep(0.005)
            return stream_toks

        stream_toks = asyncio.run(go())
        for r in reqs:
            got, want = eng.result(r.rid), ref[r.rid]
            assert got is not None, r.rid
            if r.rid in drop:
                assert got.outcome in ("ok", "cancelled"), got.outcome
                assert list(want.tokens)[:len(got.tokens)] \
                    == list(got.tokens), r.rid
            else:
                assert got.outcome == "ok"
                assert list(got.tokens) == list(want.tokens), r.rid
                if r.method == "generate":
                    assert stream_toks[r.rid] == list(want.tokens), r.rid

    prop()
