"""Elastic degraded-mesh serving (distributed/elastic.py + the engine's
`_degrade` path): after losing devices the engine re-plans onto the
largest valid healthy sub-mesh, re-shards, and replays -- and every
surviving stream is BIT-IDENTICAL to the fault-free single-device run
(DESIGN.md sec. 9: rebind slots to fewer devices, same tokens).

Planner/injector/registry units run on any host; the engine matrix needs
the simulated 8-device mesh (CI tier1-elastic sets XLA_FLAGS=
--xla_force_host_platform_device_count=8)."""
import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import context as dctx
from repro.distributed import elastic
from repro.launch import resilience as res
from repro.launch import scheduler
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_mesh
from repro.models import lm, slot_state

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="elastic mesh tests need 8 simulated devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

FAMILY_ARCHS = {"dense": "smollm-135m", "ssm": "mamba2-2.7b",
                "hybrid": "jamba-v0.1-52b", "encdec": "whisper-small"}
ENC_LEN = 16


@pytest.fixture(scope="module")
def family_setup():
    out = {}
    for fam, arch in FAMILY_ARCHS.items():
        cfg = configs.get_reduced_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80)
        out[fam] = (cfg, params)
    return out


def _requests(cfg, n=6, seed=0):
    plens = (5, 12, 9, 16, 7, 11)[:n]
    gens = (8, 6, 9, 5, 10, 7)[:n]
    reqs = []
    for i, (pl, g) in enumerate(zip(plens, gens)):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed + 10 * i), (pl,), 0, cfg.vocab))
        kw = {}
        if cfg.family == "encdec":
            rng = np.random.default_rng(seed + i)
            kw["features"] = rng.standard_normal(
                (ENC_LEN, cfg.d_model)).astype(np.float32)
        reqs.append(scheduler.Request(rid=i, prompt=prompt,
                                      max_new_tokens=g, **kw))
    return reqs


def _engine(cfg, params, *, mesh_shape=None, n_slots=8, **kw):
    if cfg.family == "encdec":
        kw.setdefault("enc_len", ENC_LEN)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("segment_len", 4)
    if mesh_shape is None:
        return ServeEngine(params, cfg, n_slots=n_slots, **kw)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    with dctx.mesh_scope(mesh, ("data",), "model"):
        return ServeEngine(params, cfg, n_slots=n_slots, **kw)


def _assert_bit_exact(ref, out):
    assert set(ref) == set(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


# ---------------------------------------------------------------------------
# degraded-mesh planner units (pure shapes: run on any host)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("old,healthy,n_slots,want", [
    ((8, 1), 4, 8, (4, 1)),    # the ISSUE's 8x1 -> 4x1
    ((2, 4), 4, 8, (2, 2)),    # 2x4 -> 2x2 (keep dp, halve model)
    ((2, 4), 2, 8, (2, 1)),    # 2x4 -> 2x1
    ((8, 1), 7, 8, (4, 1)),    # dp must divide n_slots: 7 -> 4
    ((8, 1), 1, 8, (1, 1)),    # last device standing
    ((8, 1), 5, 6, (2, 1)),    # n_slots=6: dp in {1,2} only
    ((2, 4), 8, 8, (2, 4)),    # nothing lost -> unchanged
])
def test_plan_shape(old, healthy, n_slots, want):
    assert elastic.plan_shape(old, healthy, n_slots) == want


def test_plan_shape_prefers_active_tp():
    """With a config whose heads shard at m=2, shrinking 2x4 onto 4
    devices keeps TP active ((2,2)) instead of going data-only ((4,1))."""
    cfg = configs.get_reduced_config("mamba2-2.7b")
    assert slot_state.tp_plan(cfg, 2).active          # precondition
    assert elastic.plan_shape((2, 4), 4, 8, cfg) == (2, 2)
    assert 2 in slot_state.tp_viable_sizes(cfg, 4)


def test_plan_shape_no_healthy_raises():
    with pytest.raises(ValueError, match="no healthy"):
        elastic.plan_shape((8, 1), 0, 8)


@needs_mesh
def test_plan_degraded_mesh_builds_submesh():
    mesh = make_mesh((2, 4), ("data", "model"))
    reg = elastic.DeviceHealthRegistry(mesh.devices)
    reg.kill(4)
    new = elastic.plan_degraded_mesh(mesh, reg.healthy(),
                                     dp_axes=("data",),
                                     model_axis="model", n_slots=8)
    assert new.axis_names == mesh.axis_names
    assert new.shape["data"] == 2 and new.shape["model"] == 2
    # survivors only, taken in the old mesh's flattened order
    survivors = {int(d.id) for d in reg.healthy()}
    assert {int(d.id) for d in new.devices.flat} <= survivors


# ---------------------------------------------------------------------------
# health registry + loss injector units
# ---------------------------------------------------------------------------

def test_health_registry_kill_order_and_floor():
    devs = jax.devices()
    reg = elastic.DeviceHealthRegistry(devs)
    assert reg.n_healthy == len(devs)
    ids = reg.kill(len(devs) + 5)       # clamped: one always survives
    assert reg.n_healthy == 1
    assert len(ids) == len(devs) - 1
    # deterministic: the LAST devices die first, survivors keep order
    assert [int(d.id) for d in reg.healthy()] == [int(devs[0].id)]
    assert reg.dead_ids == tuple(int(d.id) for d in devs[1:])
    assert reg.kill(3) == []            # floor holds on repeat kills


def test_injector_parse():
    inj = elastic.DeviceLossInjector.parse(
        "lose@segment:1=4;rate=0.5,seed=3,max=2;lose_rate=0.25,"
        "lose_seed=7,lose_n=2,lose_max=1")
    assert inj.lose_at_sites == (("segment:1", 4),)
    assert inj.lose_rate == 0.25 and inj.lose_seed == 7
    assert inj.lose_n == 2 and inj.lose_max == 1
    # base ChaosSchedule arms pass through untouched
    assert inj.rate == 0.5 and inj.seed == 3 and inj.max_failures == 2
    assert elastic.DeviceLossInjector.parse("lose@chunk:0") \
        .lose_at_sites == (("chunk:0", 1),)


@pytest.mark.parametrize("bad", ["lose@warp:1", "lose@segment:x",
                                 "lose@segment:1=y", "lose_frobnicate=3"])
def test_injector_parse_rejects(bad):
    with pytest.raises(ValueError):
        elastic.DeviceLossInjector.parse(bad)


def test_injector_fires_once_and_caps():
    inj = elastic.DeviceLossInjector.parse("lose@segment:1=2;lose@chunk:0;"
                                           "lose_max=1")
    with pytest.raises(elastic.DeviceLoss) as ei:
        inj.check_site("segment:1")
    assert ei.value.n_lost == 2
    inj.check_site("segment:1")         # at-most-once per site
    inj.check_site("chunk:0")           # lose_max caps total loss events
    assert inj.lost_sites == {"segment:1": 2}


def test_injector_env_roundtrip(monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS", "lose@segment:2=3,rate=0.1,seed=5")
    inj = res.chaos_from_env()
    assert isinstance(inj, elastic.DeviceLossInjector)
    assert inj.lose_at_sites == (("segment:2", 3),)
    assert inj.rate == 0.1 and inj.seed == 5
    monkeypatch.setenv("REPRO_CHAOS", "rate=0.1,seed=5")
    assert not isinstance(res.chaos_from_env(),
                          elastic.DeviceLossInjector)


def test_loss_and_fault_sites_independent():
    """Deterministic accounting: the loss decision and the fault decision
    for a site are independent pure functions of (seed, site) -- arming
    loss does not move where plain faults fire, and two identical
    schedules fire identically (the property test broadens this)."""
    plain = res.ChaosSchedule(rate=0.3, seed=9)
    armed = elastic.DeviceLossInjector(rate=0.3, seed=9, lose_rate=0.2,
                                       lose_seed=4)
    sites = [f"segment:{i}" for i in range(40)]
    plain_fires = {s for s in sites if plain.should_fail(s)}
    armed_fires = {s for s in sites if armed.should_fail(s)}
    assert plain_fires == armed_fires
    twin = elastic.DeviceLossInjector(rate=0.3, seed=9, lose_rate=0.2,
                                      lose_seed=4)
    assert [armed.loss_at(s) for s in sites] \
        == [twin.loss_at(s) for s in sites]


# ---------------------------------------------------------------------------
# engine degrade: bit-exact surviving streams on the shrunken mesh
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("mesh_shape,want", [((8, 1), (4, 1)),
                                             ((2, 4), (2, 2))])
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_device_loss_bit_exact(family_setup, family, mesh_shape, want):
    """Lose half the mesh mid-decode: the engine re-shards onto the
    planned sub-mesh without operator intervention and every stream
    matches the fault-free single-device run bitwise."""
    cfg, params = family_setup[family]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = elastic.DeviceLossInjector.parse("lose@segment:1=4")
    eng = _engine(cfg, params, mesh_shape=mesh_shape, chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    info = eng.cache_info()
    rb = info["robustness"]
    assert rb["degraded"] == 1
    assert rb["faults_injected"] == 1 and rb["recoveries"] == 1
    assert rb["replay_divergence"] == 0
    assert rb["replayed_tokens"] > 0
    assert (info["mesh"]["shape"]["data"],
            info["mesh"]["shape"]["model"]) == want
    assert len(info["mesh"]["dead_devices"]) == 4
    assert info["mesh"]["reshard_s"] > 0
    assert info["resilience"]["chaos"]["lost_sites"] == {"segment:1": 4}
    assert all(r.outcome == res.OK for r in eng.finished)
    _assert_bit_exact(ref, out)


@needs_mesh
def test_device_loss_bit_exact_silvia_all(family_setup):
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, silvia_passes="all", chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = elastic.DeviceLossInjector.parse("lose@segment:2=4")
    eng = _engine(cfg, params, mesh_shape=(8, 1), silvia_passes="all",
                  chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["degraded"] == 1 and rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


@needs_mesh
def test_repeated_loss_shrinks_again(family_setup):
    """8x1 loses 4, then 2 more: two degrades, 8 -> 4 -> 2 data shards,
    still bit-exact."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = elastic.DeviceLossInjector.parse(
        "lose@segment:1=4;lose@segment:3=2")
    eng = _engine(cfg, params, mesh_shape=(8, 1), chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    info = eng.cache_info()
    assert info["robustness"]["degraded"] == 2
    assert info["robustness"]["replay_divergence"] == 0
    assert info["mesh"]["shape"]["data"] == 2
    assert len(info["mesh"]["dead_devices"]) == 6
    _assert_bit_exact(ref, out)


@needs_mesh
def test_deep_loss_2x4_to_2x1(family_setup):
    """The ISSUE's deep-shrink arm: 2x4 losing 6 devices lands on 2x1."""
    cfg, params = family_setup["ssm"]
    ref = _engine(cfg, params, chaos=None).run(
        _requests(cfg, n=4), clock=scheduler.FastForwardClock())
    chaos = elastic.DeviceLossInjector.parse("lose@segment:1=6")
    eng = _engine(cfg, params, mesh_shape=(2, 4), chaos=chaos)
    out = eng.run(_requests(cfg, n=4), clock=scheduler.FastForwardClock())
    info = eng.cache_info()
    assert (info["mesh"]["shape"]["data"],
            info["mesh"]["shape"]["model"]) == (2, 1)
    assert info["robustness"]["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


def test_unmeshed_engine_treats_loss_as_plain_fault(family_setup):
    """A single-device engine has no mesh to shrink: DeviceLoss recovers
    through the ordinary fault path (it IS a SimulatedFailure) and the
    streams still match."""
    cfg, params = family_setup["dense"]
    ref = _engine(cfg, params, n_slots=4, chaos=None).run(
        _requests(cfg), clock=scheduler.FastForwardClock())
    chaos = elastic.DeviceLossInjector.parse("lose@segment:1=4")
    eng = _engine(cfg, params, n_slots=4, chaos=chaos)
    out = eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    rb = eng.cache_info()["robustness"]
    assert rb["degraded"] == 0
    assert rb["faults_injected"] == 1 and rb["replay_divergence"] == 0
    _assert_bit_exact(ref, out)


@needs_mesh
def test_degrade_rebuckets_admission(family_setup):
    """Shrinking 8 -> 4 data shards lowers the batch-bucket floor with it
    (slot re-bucketing: post-degrade segments may run at bucket 4)."""
    cfg, params = family_setup["dense"]
    chaos = elastic.DeviceLossInjector.parse("lose@segment:1=4")
    eng = _engine(cfg, params, mesh_shape=(8, 1), chaos=chaos)
    assert eng.min_batch_bucket == 8
    eng.run(_requests(cfg), clock=scheduler.FastForwardClock())
    assert eng.min_batch_bucket == 4
    assert eng._adm_floor == 4
    assert min(eng.batch_buckets) == 4
    info = eng.cache_info()
    assert info["graphs"] <= info["graph_bound"]


# ---------------------------------------------------------------------------
# snapshot on mesh A, restore on mesh B (satellite: cross-mesh restore)
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_snapshot_2x4_restores_on_8x1_and_single(family_setup, family,
                                                 tmp_path):
    """Request snapshots are mesh-free: taken mid-flight on a 2x4 engine,
    they restore onto an 8x1 engine AND a single-device engine, and the
    merged streams match the uninterrupted single-device run bitwise."""
    cfg, params = family_setup[family]
    ref = _engine(cfg, params, n_slots=4, chaos=None).run(
        _requests(cfg, n=4), clock=scheduler.FastForwardClock())

    eng = _engine(cfg, params, mesh_shape=(2, 4), n_slots=4, chaos=None)
    clock = scheduler.FastForwardClock()
    for r in _requests(cfg, n=4):
        eng.submit(r)
    eng.step(clock)                       # partial progress on 2x4
    eng.snapshot(str(tmp_path), step=1)
    done_before = {r.rid: np.asarray(r.tokens, np.int32)
                   for r in eng.finished}

    from repro.checkpoint import ckpt
    meta, _ = ckpt.load_meta(str(tmp_path))
    assert meta["mesh"]["shape"] == {"data": 2, "model": 4}

    for shape in [(8, 1), None]:          # None = single device
        eng2 = _engine(cfg, params, mesh_shape=shape,
                       n_slots=8 if shape else 4, chaos=None)
        n = eng2.restore(str(tmp_path))
        assert n + len(done_before) == 4
        out = eng2.run(clock=scheduler.FastForwardClock())
        merged = dict(done_before)
        merged.update(out)
        _assert_bit_exact(ref, merged)
        assert eng2.cache_info()["robustness"]["replay_divergence"] == 0
