"""Continuous-batching serve engine: bucket rounding, slot
admission/eviction invariants, and bit-exact determinism against the
static `serve.generate()` path (with and without SILVIA passes)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import scheduler, serve
from repro.launch.engine import ServeEngine
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_reduced_config("smollm-135m")
    params = quantize_tree_for_serving(
        lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=80), "w8a8")
    return cfg, params


def _prompts(cfg, n, s, seed=0):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n, s),
                                         0, cfg.vocab))


# ---------------------------------------------------------------------------
# scheduler: buckets + queue
# ---------------------------------------------------------------------------

def test_bucket_pow2_rounding():
    assert scheduler.bucket_pow2(1) == 1
    assert scheduler.bucket_pow2(3) == 4
    assert scheduler.bucket_pow2(4) == 4
    assert scheduler.bucket_pow2(5, minimum=2) == 8
    assert scheduler.bucket_pow2(3, minimum=8) == 8
    # maximum is an inclusive cap, not necessarily a power of two
    assert scheduler.bucket_pow2(5, minimum=1, maximum=6) == 6
    with pytest.raises(ValueError):
        scheduler.bucket_pow2(7, maximum=6)
    with pytest.raises(ValueError):
        scheduler.bucket_pow2(-1)


def test_bucket_set_covers_range():
    assert scheduler.bucket_set(1, 8) == (1, 2, 4, 8)
    assert scheduler.bucket_set(32, 96) == (32, 64, 96)
    # every admissible size rounds into the set
    for n in range(1, 97):
        assert scheduler.bucket_pow2(n, minimum=32, maximum=96) in \
            scheduler.bucket_set(32, 96)


def test_queue_arrival_gating():
    reqs = [scheduler.Request(rid=i, prompt=[1, 2], max_new_tokens=2,
                              arrival_time=t)
            for i, t in enumerate([0.5, 0.0, 2.0])]
    q = scheduler.RequestQueue(reqs)
    assert [r.rid for r in q.pop_ready(0.0, limit=5)] == [1]
    assert q.next_arrival(0.0) == 0.5
    assert [r.rid for r in q.pop_ready(1.0, limit=5)] == [0]
    assert [r.rid for r in q.pop_ready(1.0, limit=5)] == []
    assert q.next_arrival(1.0) == 2.0
    assert [r.rid for r in q.pop_ready(2.5, limit=5)] == [2]
    assert q.next_arrival(2.5) is None and len(q) == 0


# ---------------------------------------------------------------------------
# determinism vs the static path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("silvia_passes", ["off", "all"])
def test_engine_matches_static_generate(setup, silvia_passes):
    """3 requests on 2 slots (forces eviction + re-admission) must produce
    bit-identical greedy tokens to one static generate() batch."""
    cfg, params = setup
    prompts = _prompts(cfg, 3, 12)
    static = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, gen=8, cache_len=32,
        silvia_passes=silvia_passes))
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=8)
            for i in range(3)]
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=4, silvia_passes=silvia_passes)
    out = eng.run(reqs)
    for i in range(3):
        np.testing.assert_array_equal(out[i], static[i])


def test_engine_mixed_lengths_match_per_request_static(setup):
    """Ragged prompt/gen mix: every request's tokens must equal a dedicated
    static run of just that request."""
    cfg, params = setup
    plens, gens = (5, 12, 9, 16), (3, 8, 1, 6)
    prompts = [_prompts(cfg, 1, s, seed=10 + i)[0]
               for i, s in enumerate(plens)]
    reqs = [scheduler.Request(rid=i, prompt=prompts[i], max_new_tokens=g)
            for i, g in enumerate(gens)]
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=4)
    out = eng.run(reqs)
    for i, g in enumerate(gens):
        static = np.asarray(serve.generate(
            params, jnp.asarray(prompts[i][None]), cfg, gen=g,
            cache_len=plens[i] + g))[0]
        np.testing.assert_array_equal(out[i], static)


def test_engine_matches_static_across_bucket_boundary(setup):
    """Regression: a still-active slot whose segment ends exactly on a
    cache-length bucket boundary (pos+segment_len == t_b) must keep
    advancing its position; an earlier clamp to t_b-1 made the next
    segment overwrite the last KV position and diverge from static."""
    cfg, params = setup
    prompts = _prompts(cfg, 1, 48, seed=5)
    static = np.asarray(serve.generate(
        params, jnp.asarray(prompts), cfg, gen=32, cache_len=80))
    eng = ServeEngine(params, cfg, n_slots=1, max_cache_len=128,
                      segment_len=16, min_len_bucket=32)
    out = eng.run([scheduler.Request(rid=0, prompt=prompts[0],
                                     max_new_tokens=32)])
    np.testing.assert_array_equal(out[0], static[0])


def test_chunked_prefill_matches_full(setup):
    """prefill_chunk pushes prompts through the decode path; tokens must
    still match the full-prefill engine (and hence the static path)."""
    cfg, params = setup
    prompts = _prompts(cfg, 3, 12, seed=3)
    reqs = lambda: [scheduler.Request(rid=i, prompt=prompts[i],
                                      max_new_tokens=6) for i in range(3)]
    full = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                       segment_len=4).run(reqs())
    chunked = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                          segment_len=4, prefill_chunk=4).run(reqs())
    for i in range(3):
        np.testing.assert_array_equal(chunked[i], full[i])


# ---------------------------------------------------------------------------
# slot admission / eviction invariants
# ---------------------------------------------------------------------------

def test_slot_admission_eviction_invariants(setup):
    cfg, params = setup
    gens = (2, 5, 1, 7, 3)
    reqs = [scheduler.Request(rid=i, prompt=_prompts(cfg, 1, 6, seed=i)[0],
                              max_new_tokens=g, arrival_time=0.0)
            for i, g in enumerate(gens)]
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=32,
                      segment_len=2, min_len_bucket=16)
    for r in reqs:
        eng.submit(r)
    clock = scheduler.FastForwardClock()
    for _ in range(64):
        progressed = eng.step(clock)
        # invariant: active flags and slot assignments agree, 1:1
        live = [r for r in eng._slot_req if r is not None]
        assert len(live) == eng.n_active == int(np.sum(eng._active))
        assert len({id(r) for r in live}) == len(live)
        for slot in range(eng.n_slots):
            if eng._active[slot]:
                assert eng._slot_req[slot] is not None
                assert 0 < eng._pos[slot] <= eng.max_cache_len
                assert eng._remaining[slot] > 0
            else:
                assert eng._slot_req[slot] is None
                assert eng._remaining[slot] == 0
        assert eng.n_active <= eng.n_slots
        if not progressed and not eng.n_queued and not eng.n_active:
            break
    assert len(eng.finished) == len(reqs)
    for r in eng.finished:
        assert len(r.tokens) == r.max_new_tokens
        assert r.finish_time is not None and r.first_token_time is not None
    # slots were reused: 5 requests through 2 slots
    assert max(eng.occupancy) <= 1.0


def test_engine_rejects_oversized_and_unregistered_family(setup):
    import dataclasses

    cfg, params = setup
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=32)
    with pytest.raises(ValueError):
        eng.submit(scheduler.Request(rid=0, prompt=np.zeros(30, np.int32),
                                     max_new_tokens=8))
    # a family with no registered slot-state impl fails with guidance
    # pointing at the registry, not a frozen family tuple
    alien = dataclasses.replace(cfg, family="rwkv")
    with pytest.raises(ValueError, match="slot_state.register"):
        ServeEngine(params, alien)
    # ssm IS served now, but its state is not prefill-chunkable
    ssm_cfg = configs.get_reduced_config("mamba2-2.7b")
    with pytest.raises(ValueError, match="chunkable"):
        ServeEngine(params, ssm_cfg, prefill_chunk=4)
    # features are encdec-only; encdec engines require enc_len
    with pytest.raises(ValueError, match="encdec"):
        eng.submit(scheduler.Request(rid=1, prompt=np.zeros(4, np.int32),
                                     max_new_tokens=2,
                                     features=np.zeros((4, cfg.d_model))))
    with pytest.raises(ValueError, match="enc_len"):
        ServeEngine(params, configs.get_reduced_config("whisper-small"))


def test_warmup_bounds_compiled_graphs(setup):
    """After warmup over the advertised traffic profile, serving that
    traffic must not add new graphs, and the census stays within the
    bucket-set bound."""
    cfg, params = setup
    plens, gens = (4, 8, 12), (2, 4, 8)
    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=4)
    eng.warmup(prompt_lens=plens)
    warmed = set(eng._graphs)
    assert len(warmed) <= eng.graph_bound()
    reqs = scheduler.synthetic_traffic(seed=1, n_requests=6, rate=100.0,
                                       prompt_lens=plens, gen_lens=gens,
                                       vocab=cfg.vocab)
    eng.run(reqs)
    assert eng._graphs == warmed, "traffic compiled outside the warmed grid"
    info = eng.cache_info()
    assert info["graphs"] <= info["graph_bound"]


# ---------------------------------------------------------------------------
# serve.py decode-bundle LRU
# ---------------------------------------------------------------------------

def test_lru_cache_bound_and_counters():
    c = serve.LRUCache(maxsize=2)
    built = []
    mk = lambda k: lambda: built.append(k) or k.upper()
    assert c.get_or_build("a", mk("a")) == "A"
    assert c.get_or_build("b", mk("b")) == "B"
    assert c.get_or_build("a", mk("a")) == "A"     # hit refreshes recency
    assert c.get_or_build("c", mk("c")) == "C"     # evicts b (LRU)
    assert c.get_or_build("b", mk("b")) == "B"     # rebuild after eviction
    assert built == ["a", "b", "c", "b"]
    info = c.info()
    assert info == {"hits": 1, "misses": 4, "evictions": 2, "size": 2,
                    "maxsize": 2}
    c.clear()
    assert c.info()["size"] == 0 and c.info()["misses"] == 0


def test_decode_cache_info_tracks_generate(setup):
    cfg, params = setup
    before = serve.decode_cache_info()
    prompts = jnp.asarray(_prompts(cfg, 2, 8))
    serve.generate(params, prompts, cfg, gen=2, cache_len=16)
    serve.generate(params, prompts, cfg, gen=2, cache_len=16)
    after = serve.decode_cache_info()
    assert after["hits"] > before["hits"]          # second call reuses bundle
    assert after["size"] <= after["maxsize"]
