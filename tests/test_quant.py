"""Quantization substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.quant.qtensor import (QTensor, qmatmul, quantize_tree_for_serving,
                                 quantize_weight)


def test_quantize_roundtrip_accuracy(rng):
    x = jnp.asarray(rng.normal(0, 1, (64, 32)), jnp.float32)
    q, s = quant.quantize(x, bits=8, axis=1)
    err = np.abs(np.asarray(quant.dequantize(q, s) - x)).max()
    assert err <= float(np.abs(np.asarray(x)).max()) / 127 + 1e-6


def test_int4_pack_unpack(rng):
    q4, _ = quant.quantize_int4(
        jnp.asarray(rng.normal(0, 1, (16, 32)), jnp.float32), axis=1)
    packed = quant.pack_int4(q4)
    assert packed.shape == (16, 16) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(quant.unpack_int4(packed)),
                                  np.asarray(q4))


@pytest.mark.parametrize("fmt,tol", [("bf16", 0.02), ("w8a8", 0.05),
                                     ("w4a8", 0.35)])
def test_quant_linear_accuracy(fmt, tol, rng):
    w = jnp.asarray(rng.normal(0, 0.1, (64, 48)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 64)), jnp.float32)
    p = quant.quantize_linear_params(w, fmt)
    y = quant.quant_linear(x, p)
    want = x @ w
    rel = float(jnp.abs(y.astype(jnp.float32) - want).max()
                / jnp.abs(want).max())
    assert rel < tol


def test_qtensor_stacked_scales(rng):
    """Stacked [L, K, N] weights keep per-(layer, out-channel) scales."""
    w = jnp.asarray(rng.normal(0, 1, (3, 32, 16)), jnp.float32)
    w = w * jnp.asarray([1.0, 10.0, 100.0])[:, None, None]  # layer spread
    qt = quantize_weight(w, "w8a8")
    assert qt.scale.shape == (3, 1, 16)
    deq = qt.q.astype(jnp.float32) * qt.scale
    rel = float(jnp.abs(deq - w).max() / jnp.abs(w).max())
    assert rel < 0.02


@pytest.mark.parametrize("fmt", ["w8a8", "w4a8"])
def test_qmatmul_2d_and_batched(fmt, rng):
    w = jnp.asarray(rng.normal(0, 0.1, (64, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (5, 64)), jnp.bfloat16)
    qt = quantize_weight(w, fmt)
    y = qmatmul(x, qt)
    want = x.astype(jnp.float32) @ w
    assert y.dtype == x.dtype
    rel = float(jnp.abs(y.astype(jnp.float32) - want).max()
                / jnp.abs(want).max())
    assert rel < (0.4 if fmt == "w4a8" else 0.08)
    # batched (experts)
    we = jnp.asarray(rng.normal(0, 0.1, (4, 64, 32)), jnp.float32)
    xe = jnp.asarray(rng.normal(0, 1, (4, 5, 64)), jnp.bfloat16)
    qe = quantize_weight(we, fmt)
    ye = qmatmul(xe, qe)
    wante = jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32), we)
    rel = float(jnp.abs(ye.astype(jnp.float32) - wante).max()
                / jnp.abs(wante).max())
    assert rel < (0.4 if fmt == "w4a8" else 0.08)


def test_quantize_tree_skips_and_converts(rng):
    tree = {
        "blocks": {
            "attn": {"wq": jnp.zeros((4, 512, 512), jnp.bfloat16)},
            "ln1": {"w": jnp.ones((4, 512), jnp.float32)},
        },
        "embed": jnp.zeros((1024, 512), jnp.bfloat16),
        "lm_head": jnp.zeros((512, 1024), jnp.bfloat16),
        "step": jnp.zeros((), jnp.int32),
    }
    out = quantize_tree_for_serving(tree, "w8a8")
    assert isinstance(out["blocks"]["attn"]["wq"], QTensor)
    assert isinstance(out["lm_head"], QTensor)
    assert not isinstance(out["embed"], QTensor)          # skip_keys
    assert not isinstance(out["blocks"]["ln1"]["w"], QTensor)  # 2D stacked
    assert out["step"].dtype == jnp.int32
    # bf16 passthrough
    same = quantize_tree_for_serving(tree, "bf16")
    assert same is tree


def test_w4a8_odd_last_dim_falls_back(rng):
    w = jnp.zeros((4, 256, 257), jnp.bfloat16)
    out = quantize_tree_for_serving({"blocks": {"mlp": {"wi": w}}}, "w4a8")
    qt = out["blocks"]["mlp"]["wi"]
    assert isinstance(qt, QTensor) and qt.fmt == "w8a8"   # odd N -> w8a8


def test_width_hint_survives_grad():
    def f(x):
        return (quant.quantize(x, bits=4)[0].astype(jnp.float32)).sum()

    g = jax.grad(lambda x: f(x) * 0.0 + (x * x).sum())(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(g), 2 * np.ones((4,)))
