"""End-to-end serving: quantized weights + SILVIA-packed decode must
produce token-for-token identical generations to the unpacked path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import generate
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


@pytest.mark.parametrize("quant", ["w8a8", "w4a8"])
def test_generate_silvia_equals_baseline(quant):
    cfg = configs.get_reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg, max_seq=64)
    params = quantize_tree_for_serving(params, quant)
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    base = generate(params, prompts, cfg, gen=8, cache_len=32,
                    silvia_passes="off")
    packed = generate(params, prompts, cfg, gen=8, cache_len=32,
                      silvia_passes="all")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(packed))


def test_generate_int8_kv_close():
    cfg = dataclasses.replace(configs.get_reduced_config("qwen1.5-0.5b"),
                              serve_kv_dtype="int8")
    cfg_ref = configs.get_reduced_config("qwen1.5-0.5b")
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(rng, cfg, max_seq=64)
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    toks_q = generate(params, prompts, cfg, gen=8, cache_len=32)
    toks_f = generate(params, prompts, cfg_ref, gen=8, cache_len=32)
    # int8 KV is lossy; token agreement should still be high on short gens
    agree = float(np.mean(np.asarray(toks_q) == np.asarray(toks_f)))
    assert agree >= 0.5, agree
