"""End-to-end serving: quantized weights + SILVIA-packed decode must
produce token-for-token identical generations to the unpacked path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import generate
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving


@pytest.mark.parametrize("quant", ["w8a8", "w4a8"])
def test_generate_silvia_equals_baseline(quant):
    cfg = configs.get_reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg, max_seq=64)
    params = quantize_tree_for_serving(params, quant)
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    base = generate(params, prompts, cfg, gen=8, cache_len=32,
                    silvia_passes="off")
    packed = generate(params, prompts, cfg, gen=8, cache_len=32,
                      silvia_passes="all")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(packed))


def test_generate_int8_kv_close():
    cfg = dataclasses.replace(configs.get_reduced_config("qwen1.5-0.5b"),
                              serve_kv_dtype="int8")
    cfg_ref = configs.get_reduced_config("qwen1.5-0.5b")
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(rng, cfg, max_seq=64)
    prompts = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    toks_q = generate(params, prompts, cfg, gen=8, cache_len=32)
    toks_f = generate(params, prompts, cfg_ref, gen=8, cache_len=32)
    # int8 KV is lossy; token agreement should still be high on short gens
    agree = float(np.mean(np.asarray(toks_q) == np.asarray(toks_f)))
    assert agree >= 0.5, agree


@pytest.mark.parametrize("quant", ["w8a8", "w4a8"])
def test_forced_quantization_dispatches_packed_matmuls(quant):
    """ROADMAP (found in PR 4): the production size floors exceed every
    reduced-config weight, so default `quantize_tree_for_serving` serves
    bf16 graphs with ZERO packed dispatches.  force=True must actually
    bind packed matmuls -- asserted via the registry dispatch census --
    and the engine must still match static generate() bit-for-bit on the
    quantized graph."""
    from repro.kernels import registry
    from repro.launch.engine import ServeEngine
    from repro.launch.scheduler import Request

    cfg = configs.get_reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    raw = lm.init_params(rng, cfg, max_seq=64)

    default = quantize_tree_for_serving(raw, quant)
    leaves = jax.tree_util.tree_leaves(
        default, is_leaf=lambda x: hasattr(x, "fmt"))
    assert not any(hasattr(l, "fmt") for l in leaves), \
        "reduced-config floors changed: update this test + the ROADMAP"

    params = quantize_tree_for_serving(raw, quant, force=True)
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: hasattr(x, "fmt"))
    assert any(hasattr(l, "fmt") for l in leaves)

    registry.reset_dispatch_counts()
    prompts = np.asarray(jax.random.randint(rng, (2, 12), 0, cfg.vocab))
    static = np.asarray(generate(params, jax.numpy.asarray(prompts), cfg,
                                 gen=6, cache_len=32))
    counts = registry.dispatch_counts()
    packed_op = "quant_matmul" if quant == "w8a8" else "packed_w4_matmul"
    assert counts[packed_op] > 0, counts

    eng = ServeEngine(params, cfg, n_slots=2, max_cache_len=64,
                      segment_len=4)
    out = eng.run([Request(rid=i, prompt=prompts[i], max_new_tokens=6)
                   for i in range(2)])
    for i in range(2):
        np.testing.assert_array_equal(out[i], static[i])
