"""Distribution substrate: sharding rules, HLO analyzer, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import (cache_pspecs, param_pspecs,
                                        sanitize_spec, to_shardings)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.quant.qtensor import QTensor, quantize_tree_for_serving


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_sanitize_spec_divisibility():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    s = sanitize_spec(P("model", "data"), (49155, 1024), FakeMesh())
    assert s == P(None, "data")          # odd vocab falls back
    s = sanitize_spec(P("model", "data"), (4096, 1024), FakeMesh())
    assert s == P("model", "data")
    s = sanitize_spec(P(("data", "model"), None), (64, 8), FakeMesh())
    assert s == P()                      # 64 % 256 != 0 -> fully dropped


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_specs_rank_match(arch):
    """Every spec must have rank <= leaf rank and valid axis names."""
    cfg = configs.get_reduced_config(arch)
    mesh = _mesh11()
    params = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=64))
    specs = param_pspecs(params, mesh, cfg)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
    # shardings must construct without error
    to_shardings(specs, mesh)


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-2.7b", "jamba-v0.1-52b",
                                  "whisper-small"])
def test_cache_specs_rank_match(arch):
    cfg = configs.get_reduced_config(arch)
    mesh = _mesh11()
    s_enc = 32 if cfg.family == "encdec" else None
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 4, 32, s_enc=s_enc))
    for seq_shard in (False, True):
        specs = cache_pspecs(cache, mesh, cfg, seq_shard=seq_shard)
        flat_c = jax.tree_util.tree_leaves(cache)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(flat_c, flat_s):
            assert len(spec) <= leaf.ndim


def test_quantized_param_specs():
    """QTensor q/scale leaves get consistent, rank-correct specs."""
    cfg = configs.get_reduced_config("yi-6b")
    mesh = _mesh11()
    params = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=64))
    qparams = jax.eval_shape(
        lambda p: quantize_tree_for_serving(p, "w8a8"), params)
    specs = param_pspecs(qparams, mesh, cfg)
    flat_p = jax.tree_util.tree_leaves(qparams)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim


def test_sharded_train_step_runs_on_1x1():
    """End-to-end: jit with explicit shardings executes on the tiny mesh."""
    from repro.optim import adamw_init
    from repro.training import TrainConfig, make_train_step

    cfg = configs.get_reduced_config("smollm-135m")
    mesh = _mesh11()
    tcfg = TrainConfig(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    opt = adamw_init(params, tcfg.optimizer)
    with mesh:
        pspecs = param_pspecs(params, mesh, cfg)
        params = jax.device_put(params, to_shardings(pspecs, mesh))
        step = jax.jit(make_train_step(cfg, tcfg),
                       in_shardings=(to_shardings(pspecs, mesh),
                                     to_shardings(param_pspecs(opt, mesh,
                                                               cfg), mesh),
                                     None))
        toks = jnp.zeros((2, 17), jnp.int32)
        p2, o2, m = step(params, opt, {"tokens": toks})
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trips():
    L, B, D = 6, 4, 32

    def fn(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    compiled = jax.jit(fn).lower(w, x).compile()
    res = analyze_hlo(compiled.as_text())
    analytic = L * 2 * B * D * D
    assert res.n_while == 1
    assert res.trip_counts == [L]
    assert res.dot_flops == pytest.approx(analytic, rel=0.05)


def test_hlo_analyzer_straightline_dots():
    def fn(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    compiled = jax.jit(fn).lower(a, b).compile()
    res = analyze_hlo(compiled.as_text())
    assert res.dot_flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)
    assert res.coll_bytes == 0


def test_elastic_remesh_roundtrip():
    from repro.distributed.fault import elastic_remesh
    tree = {"blocks": {"attn": {"wq": jnp.ones((2, 64, 64))}}}
    mesh = _mesh11()
    out = elastic_remesh(tree, mesh)
    np.testing.assert_array_equal(np.asarray(out["blocks"]["attn"]["wq"]),
                                  np.asarray(tree["blocks"]["attn"]["wq"]))
