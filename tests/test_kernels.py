"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and value ranges (hypothesis drives the sweep)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import (mul4, muladd2, packed_matmul, quant_matmul, ref,
                           simd_add)

shapes_st = st.sampled_from([(5,), (64,), (257,), (8, 33), (3, 5, 7),
                             (1024,), (33, 130)])


# ---------------------------------------------------------------------------
# simd_add (SWAR four8 / two16)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(shapes_st, st.booleans(), st.sampled_from([8, 16]),
       st.integers(0, 2**31))
def test_simd_add_sweep(shape, sub, lane_bits, seed):
    rng = np.random.default_rng(seed)
    k = 32 // lane_bits
    dt = jnp.int8 if lane_bits == 8 else jnp.int16
    lo, hi = (-128, 128) if lane_bits == 8 else (-32768, 32768)
    xs = [jnp.asarray(rng.integers(lo, hi, shape), dt) for _ in range(k)]
    ys = [jnp.asarray(rng.integers(lo, hi, shape), dt) for _ in range(k)]
    got = simd_add.simd_add(xs, ys, lane_bits=lane_bits, sub=sub,
                            interpret=True)
    want = ref.simd_add_ref(xs, ys, sub=sub, lane_bits=lane_bits)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_simd_add_partial_lanes(rng):
    xs = [jnp.asarray(rng.integers(-128, 128, (40,)), jnp.int8)
          for _ in range(2)]
    ys = [jnp.asarray(rng.integers(-128, 128, (40,)), jnp.int8)
          for _ in range(2)]
    got = simd_add.simd_add(xs, ys, lane_bits=8, interpret=True)
    want = ref.simd_add_ref(xs, ys, lane_bits=8)
    assert len(got) == 2
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_swar_wraps_like_int8(rng):
    """Lane overflow must wrap exactly like int8 two's complement."""
    x = jnp.asarray([127, -128, 100, -100], jnp.int8)
    y = jnp.asarray([1, -1, 100, -100], jnp.int8)
    got = simd_add.simd_add([x] * 4, [y] * 4, lane_bits=8, interpret=True)
    want = x + y  # jnp int8 add wraps
    for g in got:
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(want.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# muladd2 (factor-2 shared-operand MAD, wp486-on-i32)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.sampled_from([(1, (-128, 128)), (4, (-8, 8)), (31, (-8, 8)),
                        (2, (-16, 16))]),
       shapes_st, st.integers(0, 2**31))
def test_muladd2_sweep(chain_cfg, shape, seed):
    n, (lo, hi) = chain_cfg
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(lo, hi, (n,) + shape), jnp.int8)
    b = jnp.asarray(rng.integers(lo, hi, (n,) + shape), jnp.int8)
    c = jnp.asarray(rng.integers(-128, 128, (n,) + shape), jnp.int8)
    pa, pb = muladd2.muladd2(a, b, c, interpret=True)
    wa, wb = ref.muladd2_ref(list(a), list(b), list(c))
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(wb))


def test_muladd2_extreme_values():
    """Lane-boundary cases: +-127 products with sign borrows."""
    vals = [-128, -127, -1, 0, 1, 126, 127]
    a = jnp.asarray([vals], jnp.int8).reshape(1, -1)
    b = -a
    c = jnp.full_like(a, -128)
    pa, pb = muladd2.muladd2(a, b, c, interpret=True)
    wa, wb = ref.muladd2_ref(list(a), list(b), list(c))
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(wb))


# ---------------------------------------------------------------------------
# mul4 (factor-4 4-bit; paper Fig. 3 split + TPU full-lane variant)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.sampled_from([((-8, 8), (-8, 8), True), ((0, 16), (-8, 8), True),
                        ((-8, 8), (0, 16), True), ((0, 16), (0, 16), False)]),
       shapes_st, st.booleans(), st.integers(0, 2**31))
def test_mul4_sweep(ranges, shape, use_split, seed):
    (alo, ahi), (blo, bhi), signed = ranges
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(alo, ahi, (4,) + shape), jnp.int8)
    b = jnp.asarray(rng.integers(blo, bhi, shape), jnp.int8)
    fn = mul4.mul4_split if use_split else mul4.mul4_full32
    got = fn(a, b, interpret=True, signed=signed)
    want = ref.mul4_ref(list(a), b)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_mul4_split_equals_full32(rng):
    """Paper-faithful split variant == TPU-native variant (Eq. 4)."""
    a = jnp.asarray(rng.integers(-8, 8, (4, 100)), jnp.int8)
    b = jnp.asarray(rng.integers(-8, 8, (100,)), jnp.int8)
    g1 = mul4.mul4_split(a, b, interpret=True)
    g2 = mul4.mul4_full32(a, b, interpret=True)
    for x, y in zip(g1, g2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# quantized matmuls
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(8, 128, 128), (65, 130, 62), (1, 512, 256),
                        (130, 257, 66)]),
       st.integers(0, 2**31))
def test_quant_matmul_sweep(mkn, seed):
    m, k, n = mkn
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.random((m, 1)), jnp.float32)
    ws = jnp.asarray(rng.random((1, n)), jnp.float32)
    got = quant_matmul.quant_matmul(xq, wq, xs, ws, interpret=True,
                                    block=(32, 128, 128))
    want = ref.quant_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(8, 128, 128), (65, 130, 62), (1, 512, 256)]),
       st.integers(0, 2**31))
def test_packed_w4_matmul_sweep(mkn, seed):
    m, k, n = mkn
    n -= n % 2
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w4 = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
    wp = ref.pack_w4(w4)
    xs = jnp.asarray(rng.random((m, 1)), jnp.float32)
    ws = jnp.asarray(rng.random((1, n)), jnp.float32)
    got = packed_matmul.packed_w4_matmul(xq, wp, xs, ws, interpret=True,
                                         block=(32, 128, 128))
    want = (jnp.dot(xq.astype(jnp.int32), w4.astype(jnp.int32))
            .astype(jnp.float32) * xs * ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    # oracle consistency too
    np.testing.assert_allclose(np.asarray(ref.packed_w4_matmul_ref(
        xq, wp, xs, ws)), np.asarray(want), rtol=1e-5)


def test_pack_w4_roundtrip(rng):
    w4 = jnp.asarray(rng.integers(-8, 8, (16, 32)), jnp.int8)
    wp = ref.pack_w4(w4)
    assert wp.shape == (16, 16)
    lo = (wp.astype(jnp.int32) & 0xF) - 8
    hi = wp.astype(jnp.int32) >> 4
    back = jnp.stack([lo, hi], axis=-1).reshape(16, 32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w4))
