"""Property-based tests: SILVIA must preserve semantics on ARBITRARY
straight-line narrow-integer programs, and packing must never reduce the
operation density.

The generator builds random programs over int8 tensors: each step either
multiplies two live values (widened, candidates for muladd), adds two live
int8 values (candidates for SILVIAAdd), adds two widened values (tree
builders), or reuses a shared operand -- covering the paper's candidate
patterns plus plenty of non-candidates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import core as silvia
from repro.core import opcount

N = 8  # vector length for all generated tensors


def build_program(opcodes):
    """opcodes: list of (op, i, j) with indices into the live-value list."""

    def fn(a, b, c):
        live8 = [a, b, c]          # int8 values
        live32 = []                # widened values
        f = lambda x: x.astype(jnp.int32)
        for op, i, j in opcodes:
            if op == 0:            # shared-operand mul
                live32.append(f(live8[i % len(live8)]) * f(c))
            elif op == 1:          # mul of two int8
                live32.append(f(live8[i % len(live8)])
                              * f(live8[j % len(live8)]))
            elif op == 2:          # int8 add (SILVIAAdd candidate)
                live8.append(live8[i % len(live8)]
                             + live8[j % len(live8)])
            elif op == 3 and len(live32) >= 2:   # tree add
                live32.append(live32[i % len(live32)]
                              + live32[j % len(live32)])
            elif op == 4:          # int8 sub
                live8.append(live8[i % len(live8)]
                             - live8[j % len(live8)])
        outs = tuple(live32[-4:]) + tuple(live8[-4:])
        return outs

    return fn


opcode_st = st.tuples(st.integers(0, 4), st.integers(0, 7),
                      st.integers(0, 7))


@settings(max_examples=30, deadline=None)
@given(st.lists(opcode_st, min_size=2, max_size=12), st.integers(0, 2**31))
def test_random_programs_preserve_semantics(opcodes, seed):
    rng = np.random.default_rng(seed)
    fn = build_program(opcodes)
    args = [jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int8)
            for _ in range(3)]
    want = fn(*args)
    opt = silvia.optimize(fn, silvia.DEFAULT_PASSES)
    got = opt(*args)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=20, deadline=None)
@given(st.lists(opcode_st, min_size=2, max_size=12), st.integers(0, 2**31))
def test_density_never_decreases(opcodes, seed):
    rng = np.random.default_rng(seed)
    fn = build_program(opcodes)
    args = [jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int8)
            for _ in range(3)]
    before = opcount.count_ops(jax.make_jaxpr(fn)(*args))
    after = opcount.count_ops(
        silvia.optimized_jaxpr(fn, *args, passes=silvia.DEFAULT_PASSES))
    if before.mul_units:
        assert after.mul_density >= before.mul_density - 1e-9
    if before.add_units and after.add_units:
        assert after.add_density >= before.add_density - 1e-9
    # logical op counts are conserved or reduced only by DCE of dead code
    assert after.mul_ops <= before.mul_ops
    # every packed unit must carry > 1 op on average for its category
    if after.packed_units:
        assert after.packed_units <= before.mul_units + before.add_units


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2**31))
def test_chain_split_matches_reference(n_leaves, seed):
    """Random-length MAD trees: Eq. 2 splitting must stay exact."""
    rng = np.random.default_rng(seed)

    def trees(a, b, c):
        f = lambda x: x.astype(jnp.int32)
        pa = f(a[0]) * f(c[0])
        pb = f(b[0]) * f(c[0])
        for i in range(1, n_leaves):
            pa = pa + f(a[i]) * f(c[i])
            pb = pb + f(b[i]) * f(c[i])
        return pa, pb

    mk = lambda: tuple(jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int8)
                       for _ in range(n_leaves))
    args = [mk(), mk(), mk()]
    opt = silvia.optimize(trees, [silvia.PassConfig(op="muladd")])
    for g, w in zip(opt(*args), trees(*args)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
