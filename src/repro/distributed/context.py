"""Ambient distribution context.

Model code is mesh-agnostic; the launcher can install a mesh + axis roles
here to unlock explicitly-collective code paths (shard_map MoE dispatch).
Tracing-time only: the context must be active while jit/lower traces.
"""
from __future__ import annotations

import contextlib
from typing import Optional

_MESH = None
_DP_AXES: tuple = ()
_MODEL_AXIS: Optional[str] = None


@contextlib.contextmanager
def mesh_scope(mesh, dp_axes: tuple, model_axis: str):
    global _MESH, _DP_AXES, _MODEL_AXIS
    prev = (_MESH, _DP_AXES, _MODEL_AXIS)
    _MESH, _DP_AXES, _MODEL_AXIS = mesh, tuple(dp_axes), model_axis
    try:
        yield
    finally:
        _MESH, _DP_AXES, _MODEL_AXIS = prev


def current():
    """Returns (mesh, dp_axes, model_axis) or None."""
    if _MESH is None:
        return None
    return _MESH, _DP_AXES, _MODEL_AXIS
