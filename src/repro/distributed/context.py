"""Ambient distribution context.

Model code is mesh-agnostic; the launcher can install a mesh + axis roles
here to unlock explicitly-collective code paths (shard_map MoE dispatch,
and the serve engine's sharded segment fn).  Tracing-time only: the
context must be active while jit/lower traces.

Two scopes live here:

* `mesh_scope(mesh, dp_axes, model_axis)` -- the launcher-level roles.
  Train reads it for the shard_map MoE dispatch; `ServeEngine` reads it at
  CONSTRUCTION to build its sharded decode/prefill bundles (the scope only
  needs to be active while the engine is constructed -- the engine
  captures the mesh and re-enters its own tracing scopes lazily).
* `tp_scope(axis, size, attn, ssm)` -- serve-time tensor parallelism,
  entered INSIDE the engine's shard_map body while it traces.  Attention
  and SSM mixers read it (`tp_current()`) to compute only their local
  heads and all_gather before the merged projections; the per-family
  flags say which mixers actually shard (head counts must divide the
  axis; see models/slot_state.py tp_plan).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

_MESH = None
_DP_AXES: tuple = ()
_MODEL_AXIS: Optional[str] = None


@contextlib.contextmanager
def mesh_scope(mesh, dp_axes: tuple, model_axis: str):
    global _MESH, _DP_AXES, _MODEL_AXIS
    prev = (_MESH, _DP_AXES, _MODEL_AXIS)
    _MESH, _DP_AXES, _MODEL_AXIS = mesh, tuple(dp_axes), model_axis
    try:
        yield
    finally:
        _MESH, _DP_AXES, _MODEL_AXIS = prev


def current():
    """Returns (mesh, dp_axes, model_axis) or None."""
    if _MESH is None:
        return None
    return _MESH, _DP_AXES, _MODEL_AXIS


@dataclasses.dataclass(frozen=True)
class TPContext:
    """Serve-time tensor parallelism over one mesh axis (inside shard_map).

    axis:  mesh axis name the head/state dims are sharded over.
    size:  number of shards on that axis.
    attn:  attention mixers compute local heads (wq/wk/wv column slices,
           all_gather of head outputs before wo) -- requires
           n_heads % size == 0 and n_kv % size == 0.
    ssm:   SSD mixers keep their [B, H, P, N] state local and all_gather
           the per-head outputs before the gated norm -- requires
           ssm_heads % size == 0 (projections/conv stay replicated).
    """
    axis: str
    size: int
    attn: bool = False
    ssm: bool = False


_TP: Optional[TPContext] = None


@contextlib.contextmanager
def tp_scope(axis: str, size: int, *, attn: bool = False, ssm: bool = False):
    global _TP
    prev = _TP
    _TP = TPContext(axis, size, attn, ssm) if (attn or ssm) and size > 1 \
        else None
    try:
        yield
    finally:
        _TP = prev


def tp_current() -> Optional[TPContext]:
    return _TP
