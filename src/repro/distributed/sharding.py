"""Sharding rules: param/batch/cache PartitionSpecs for every family.

Strategy (GSPMD; baseline for the roofline table -- hillclimbed variants
live in launch/dryrun.py options):

* **FSDP** over ("pod","data"): every large weight's *input* (d_model-like)
  dimension is fully sharded; XLA all-gathers weights per layer under scan.
* **TP** over "model": attention heads / FFN hidden / vocab are sharded;
  row-parallel outputs (wo / out_proj / mlp down) contract over the sharded
  dimension, producing the Megatron-style psum per block.
* **EP** over "model": MoE expert dim is block-assigned to model shards;
  GSPMD inserts the all-to-all-equivalent resharding around expert compute.
* **SP**: long-context decode shards the KV cache / SSD chunk stream over
  "data" (sequence dimension) since batch=1 cannot use it.

Rules are by leaf-path suffix + rank, so the same table serves plain arrays
and QTensor leaves (…/wq.q, …/wq.scale) and arbitrary leading stack axes
(layers, super-blocks, experts).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, fsdp_axes

# suffix -> (spec for last two dims of the weight)
# "col": [K, N] -> (FSDP, model)   (column/head/ffn-up parallel)
# "row": [K, N] -> (model, FSDP)   (row parallel: contract dim sharded)
_COL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "lm_head", "router"}
_ROW = {"wo", "out_proj"}
_EXPERT_STACKED = {"wi", "wg", "wo"}   # under a "moe" parent: [E, K, N]


def _last2(path):
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    keys = [k for k in keys if isinstance(k, str)]
    return keys


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop sharding on dimensions the mesh axes don't divide (e.g. odd
    vocab sizes, head counts smaller than the model axis)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim_size, axis in zip(shape, dims):
        if axis is not None and dim_size % _axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(params, mesh, cfg=None, mode: str = "2d"):
    """Pytree of PartitionSpecs matching `params` (arrays or QTensors).

    mode="2d" (default): FSDP over (pod, data) + TP/EP over model.
    mode="pure_dp": no tensor parallelism -- weights fully sharded over ALL
    mesh axes on their input dim, batch over all axes.  The right choice for
    models whose head counts don't divide the model axis (e.g. smollm's 9
    heads vs model=16, where TP replicates attention compute)."""
    if mode == "pure_dp":
        all_axes = tuple(mesh.axis_names)
        fs = all_axes if len(all_axes) > 1 else all_axes[0]
        tp = None
    else:
        fsdp = fsdp_axes(mesh)
        fs = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
        tp = "model"

    def spec_for(path, leaf) -> P:
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        keys = _last2(path)
        name = next((k for k in reversed(keys)
                     if k not in ("q", "scale")), "")
        in_moe = "moe" in keys
        lead = leaf.ndim - 2
        if name == "embed":
            return P(tp, fs)
        if name == "pos_embed" or name == "enc_pos":
            return P(None, None)
        if leaf.ndim == 1:
            return P(None)
        if name == "conv_w":
            return P(*([None] * lead), None, tp)
        if in_moe and name in _EXPERT_STACKED:
            # [..., E, K, N]: experts over model (EP), K or N over FSDP
            lead_e = leaf.ndim - 3
            if name == "wo":
                return P(*([None] * lead_e), tp, None, fs)
            return P(*([None] * lead_e), tp, fs, None)
        if name == "scale" or keys and keys[-1] == "scale":
            # QTensor scale [..., 1, N]: follow the weight's N sharding
            base = next((k for k in reversed(keys) if k not in ("scale",)), "")
            if base in _ROW:
                return P(*([None] * lead), None, fs if tp is None else None)
            return P(*([None] * lead), None, tp)
        if name in _ROW:
            return P(*([None] * lead), tp, fs)
        if name in _COL:
            if name == "router":
                return P(*([None] * lead), fs, None)
            return P(*([None] * lead), fs, tp)
        # unknown leaves (stacked norms, biases, A_log, ...): replicate
        return P(*([None] * leaf.ndim))

    def wrapped(path, leaf):
        s = spec_for(path, leaf)
        if hasattr(leaf, "shape"):
            return sanitize_spec(s, leaf.shape, mesh)
        return s

    return jax.tree_util.tree_map_with_path(wrapped, params)


def batch_pspec(mesh, kind: str = "train", mode: str = "2d") -> Any:
    """PartitionSpec factory for input batches (batch dim over DP axes)."""
    dp = tuple(mesh.axis_names) if mode == "pure_dp" else dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_for(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        return P(dp, *([None] * (leaf.ndim - 1)))

    return spec_for


def cache_pspecs(cache, mesh, cfg, *, seq_shard: bool = False,
                 mode: str = "2d", seq_axis=None):
    """Specs for decode caches.  Layout: leaves are [L(stack), B, S, ...] for
    attention KV, [L, B, H, P, N] for SSD state.  seq_shard=True (long_500k,
    batch=1) puts the sequence dim on "data" instead of the batch dim.
    seq_axis (e.g. "model"): ALSO shard the KV sequence dim over that axis
    -- decode batches smaller than the chip count otherwise replicate the
    cache across the model axis (the dominant HBM term)."""
    dp = tuple(mesh.axis_names) if mode == "pure_dp" else dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_for(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim <= 1:
            return P()
        keys = _last2(path)
        if keys and keys[-1] in ("k_s", "v_s") and leaf.ndim >= 3:
            # [L, B, S, KV] per-position scales: follow the kv sharding
            if seq_shard:
                return P(None, None, dp, None)
            return P(None, dp, seq_axis, None)
        is_kv = any(k in ("k", "v") for k in keys[-1:])
        if is_kv and leaf.ndim >= 4:
            # [L, B, S, KV, D]
            if seq_shard:
                return P(None, None, dp, *([None] * (leaf.ndim - 3)))
            return P(None, dp, seq_axis, *([None] * (leaf.ndim - 3)))
        if keys and keys[-1] == "ssm" and leaf.ndim >= 4:
            # [L(, M), B, H, P, N]: heads over model
            lead = leaf.ndim - 4
            if seq_shard:
                return P(*([None] * lead), None, "model", None, None)
            return P(*([None] * lead), dp, "model", None, None)
        if keys and keys[-1] == "conv":
            lead = leaf.ndim - 3
            if seq_shard:
                return P(*([None] * lead), None, None, "model")
            return P(*([None] * lead), dp, None, "model")
        return P(*([None] * leaf.ndim))

    def wrapped(path, leaf):
        s = spec_for(path, leaf)
        if hasattr(leaf, "shape"):
            return sanitize_spec(s, leaf.shape, mesh)
        return s

    return jax.tree_util.tree_map_with_path(wrapped, cache)


def to_shardings(specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# serve-engine slot state + shard_map helpers
# ---------------------------------------------------------------------------

def dp_spec_entry(dp_axes):
    """The PartitionSpec entry for a dim sharded over the dp axes."""
    dp = tuple(dp_axes)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def slot_state_pspecs(treedef, slot_axes, tp_axes, dp_axes,
                      model_axis=None):
    """PartitionSpecs for the engine's slot-state pytree, from the probed
    per-leaf axis descriptors alone (tree_flatten order): slot axis over
    the dp axes, tp axis (models/slot_state.py tp_axes_for) over
    `model_axis`; entries of None leave the leaf replicated over model.
    Divisibility is the engine's invariant (scheduler.validate_slot_
    sharding + the tp_plan head checks), so no shape sanitizing here."""
    dp = dp_spec_entry(dp_axes)
    specs = []
    for ba, ta in zip(slot_axes, tp_axes):
        n = 1 + max(ba, ta if ta is not None else 0)
        dims = [None] * n
        dims[ba] = dp
        if ta is not None and model_axis is not None:
            dims[ta] = model_axis
        specs.append(P(*dims))
    return jax.tree_util.tree_unflatten(treedef, specs)


def gather_sharded(tree, specs):
    """Inside a shard_map body: all_gather every sharded dim of `tree`
    back to the full (replicated) value.

    This is the explicit FSDP/ZeRO-3 gather of the serve path: weights
    live sharded in HBM under the `param_pspecs` suffix rules and are
    reconstructed ONCE per segment dispatch.  Gathering is pure data
    movement -- the reconstructed leaf is bitwise the original -- which is
    what keeps the sharded engine exact where a GSPMD-partitioned
    contraction (partial dots + float psum) would not be.

    A dim sharded over a tuple of axes P(("a","b")) is laid out a-major,
    so gathering the minor axis first rebuilds each a-block contiguously,
    then the major gather rebuilds the dim."""
    flat_t, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    if len(flat_t) != len(flat_s):
        raise ValueError(
            f"gather_sharded: {len(flat_t)} leaves vs {len(flat_s)} specs")

    def gather(leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            for ax in reversed(tuple(axes)):
                leaf = jax.lax.all_gather(leaf, ax, axis=dim, tiled=True)
        return leaf

    return jax.tree_util.tree_unflatten(
        treedef, [gather(l, s) for l, s in zip(flat_t, flat_s)])
