"""Fault tolerance for long-running multi-pod jobs.

Mechanisms (all exercised by tests on CPU via the simulation hooks):

* **Heartbeats / straggler detection** -- every host reports per-step wall
  time; `StragglerDetector` flags hosts whose rolling median exceeds the
  fleet median by `threshold`x.  At scale the controller uses this to
  hot-swap stragglers (evict + replace from spare pool); here the policy
  object records decisions so tests can assert them.
* **Failure simulation + restart policy** -- `FailureInjector` raises
  `SimulatedFailure` on chosen steps; the training driver catches ANY
  exception, restores the last committed checkpoint and continues, proving
  checkpoint/restart end to end.
* **Elastic scaling** -- `elastic_remesh` re-shards a param/opt pytree onto
  a new mesh (different device count / topology), using the same sharding
  rules; the driver calls it when the device pool changes between restarts.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax

from repro.distributed.sharding import param_pspecs, to_shardings


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    failed: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.failed:
            self.failed.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


class StragglerDetector:
    """Rolling per-host step-time tracking with median-ratio flagging."""

    def __init__(self, n_hosts: int, window: int = 16,
                 threshold: float = 1.5):
        self.times = [collections.deque(maxlen=window)
                      for _ in range(n_hosts)]
        self.threshold = threshold
        self.flagged: list[tuple[int, int]] = []   # (step, host)

    def report(self, step: int, host: int, dt: float):
        self.times[host].append(dt)

    def stragglers(self, step: int) -> list[int]:
        medians = [statistics.median(t) if t else 0.0 for t in self.times]
        fleet = statistics.median([m for m in medians if m > 0] or [0.0])
        out = []
        if fleet <= 0:
            return out
        for h, m in enumerate(medians):
            if m > self.threshold * fleet:
                out.append(h)
                self.flagged.append((step, h))
        return out


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 0.0
    restarts: int = 0

    def should_restart(self, exc: Exception) -> bool:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return False
        if self.backoff_s:
            time.sleep(self.backoff_s)
        return True


def elastic_remesh(tree: Any, new_mesh, cfg=None):
    """Re-shard a pytree onto a different mesh (elastic scale up/down).

    Works from host-replicated or differently-sharded arrays; sharding rules
    are re-derived for the new mesh so axis sizes re-validate (divisibility
    fallbacks may change when the mesh changes)."""
    specs = param_pspecs(tree, new_mesh, cfg)
    return jax.device_put(tree, to_shardings(specs, new_mesh))


class Heartbeat:
    """Host liveness: controller-side view of last-seen times."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.last_seen = {h: time.time() for h in range(n_hosts)}
        self.timeout_s = timeout_s

    def beat(self, host: int, t: Optional[float] = None):
        self.last_seen[host] = t if t is not None else time.time()

    def dead_hosts(self, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]
