"""Fault tolerance for long-running multi-pod jobs.

Mechanisms (all exercised by tests on CPU via the simulation hooks):

* **Heartbeats / straggler detection** -- every host reports per-step wall
  time; `StragglerDetector` flags hosts whose rolling median exceeds the
  fleet median by `threshold`x.  At scale the controller uses this to
  hot-swap stragglers (evict + replace from spare pool); here the policy
  object records decisions so tests can assert them.
* **Failure simulation + restart policy** -- `FailureInjector` raises
  `SimulatedFailure` on chosen steps; the training driver catches ANY
  exception, restores the last committed checkpoint and continues, proving
  checkpoint/restart end to end.
* **Elastic scaling** -- `elastic_remesh` re-shards a param/opt pytree onto
  a new mesh (different device count / topology), using the same sharding
  rules; the driver calls it when the device pool changes between restarts.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import statistics
import time
from typing import Any, Optional

import jax

from repro.distributed.sharding import param_pspecs, to_shardings


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class FailureInjector:
    """Raises `SimulatedFailure` at chosen points, each at most once.

    Training drives it by step number (`check`); serving drives it by
    dispatch SITE -- ``kind:index`` strings over the engine's per-kind
    dispatch counters, e.g. ``segment:3`` / ``prefill:0`` / ``chunk:7``
    (`check_site`; `launch/resilience.ChaosSchedule` extends this with a
    deterministic rate-based schedule parsed from $REPRO_CHAOS)."""
    fail_at_steps: tuple = ()
    failed: set = dataclasses.field(default_factory=set)
    fail_at_sites: tuple = ()

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.failed:
            self.failed.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")

    def check_site(self, site: str):
        if site in self.fail_at_sites and site not in self.failed:
            self.failed.add(site)
            raise SimulatedFailure(f"injected serving fault at {site}")


class StragglerDetector:
    """Rolling per-host step-time tracking with median-ratio flagging."""

    def __init__(self, n_hosts: int, window: int = 16,
                 threshold: float = 1.5):
        self.times = [collections.deque(maxlen=window)
                      for _ in range(n_hosts)]
        self.threshold = threshold
        self.flagged: list[tuple[int, int]] = []   # (step, host)

    def report(self, step: int, host: int, dt: float):
        self.times[host].append(dt)

    def stragglers(self, step: int) -> list[int]:
        medians = [statistics.median(t) if t else 0.0 for t in self.times]
        fleet = statistics.median([m for m in medians if m > 0] or [0.0])
        out = []
        if fleet <= 0:
            return out
        for h, m in enumerate(medians):
            if m > self.threshold * fleet:
                out.append(h)
                self.flagged.append((step, h))
        return out


@dataclasses.dataclass
class RestartPolicy:
    """Restart budget with exponential backoff and deterministic jitter.

    `restarts` counts restarts actually GRANTED (a refusal does not burn
    an attempt); `streak` counts consecutive failures since the last
    `reset()`, driving the backoff: min(backoff_s * 2**streak,
    max_backoff_s), scaled by a jitter factor in [1, 1+jitter) derived
    from a stable hash of (seed, streak) -- reproducible across runs,
    unlike random jitter, yet de-synchronized across differently-seeded
    hosts.  Call `reset()` after a success so a long-lived job's next
    incident starts from the base backoff again."""
    max_restarts: int = 10
    backoff_s: float = 0.0
    max_backoff_s: float = 30.0
    jitter: float = 0.1
    seed: int = 0
    restarts: int = 0
    streak: int = 0

    def next_backoff(self) -> float:
        """Backoff for the current streak (0.0 when backoff_s is 0)."""
        if not self.backoff_s:
            return 0.0
        base = min(self.backoff_s * (2.0 ** self.streak), self.max_backoff_s)
        h = hashlib.sha256(f"{self.seed}|{self.streak}".encode()).digest()
        frac = int.from_bytes(h[:8], "big") / 2.0 ** 64
        return base * (1.0 + self.jitter * frac)

    def should_restart(self, exc: Exception) -> bool:
        if self.restarts >= self.max_restarts:
            return False
        delay = self.next_backoff()
        self.restarts += 1
        self.streak += 1
        if delay:
            time.sleep(delay)
        return True

    def reset(self) -> None:
        """Record a success: the next failure backs off from the base."""
        self.streak = 0


def elastic_remesh(tree: Any, new_mesh, cfg=None):
    """Re-shard a pytree onto a different mesh (elastic scale up/down).

    Works from host-replicated or differently-sharded arrays; sharding rules
    are re-derived for the new mesh so axis sizes re-validate (divisibility
    fallbacks may change when the mesh changes)."""
    specs = param_pspecs(tree, new_mesh, cfg)
    return jax.device_put(tree, to_shardings(specs, new_mesh))


class Heartbeat:
    """Host liveness: controller-side view of last-seen times."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.last_seen = {h: time.time() for h in range(n_hosts)}
        self.timeout_s = timeout_s

    def beat(self, host: int, t: Optional[float] = None):
        self.last_seen[host] = t if t is not None else time.time()

    def dead_hosts(self, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]
