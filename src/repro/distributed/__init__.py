"""Distribution substrate: sharding rules, fault tolerance, elasticity."""
from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        param_pspecs, to_shardings)

__all__ = ["batch_pspec", "cache_pspecs", "param_pspecs", "to_shardings"]
