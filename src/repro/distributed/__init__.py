"""Distribution substrate: sharding rules, fault tolerance, elasticity,
and the serve-engine mesh/TP helpers (slot-state specs, exact gathers)."""
from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        dp_spec_entry, gather_sharded,
                                        param_pspecs, slot_state_pspecs,
                                        to_shardings)

__all__ = ["batch_pspec", "cache_pspecs", "dp_spec_entry", "gather_sharded",
           "param_pspecs", "slot_state_pspecs", "to_shardings"]
