"""Elastic degraded-mesh serving: survive device loss, keep the tokens.

SILVIA's packing passes rebind ops onto fewer DSPs without changing a
single output bit; this module carries that invariant one level up the
stack: when a serving mesh loses devices, the engine rebinds its slots
onto the largest valid HEALTHY sub-mesh and replays in-flight requests
bit-exactly (DESIGN.md sec. 9).  Three pieces live here:

* **`DeviceHealthRegistry`** -- the controller-side view of which mesh
  devices are alive.  Simulated loss marks devices dead (the container
  has no real failing chips); at scale the registry would be fed by
  `distributed.fault.Heartbeat` timeouts.
* **`DeviceLossInjector`** -- a `launch.resilience.ChaosSchedule` whose
  schedule can also KILL devices: loss events consume the SAME counted
  dispatch-site namespace as plain faults (``segment:/prefill:/chunk:N``),
  so a seeded schedule replays identically across runs -- the loss
  decision for a site is a pure function of (seed, site), exactly like
  the fault decision, and firing one never shifts the other's sites.
  `$REPRO_CHAOS` grows ``lose@site[=N]`` / ``lose_rate=``... arms
  (`parse`), so CI can run whole suites under device loss.
* **the degraded-mesh planner** (`plan_degraded_mesh`) -- maps a mesh
  with dead devices to the largest valid healthy sub-mesh, honouring the
  engine's constraints: the data extent must be a power of two dividing
  `n_slots` (`launch.scheduler.validate_slot_sharding`'s dp floor) and
  the model extent must divide the original model extent, preferring
  extents where the config's tensor-parallel plan stays ACTIVE
  (`models.slot_state.tp_plan`'s head-divisibility) -- shrinking never
  silently turns TP into replication when a TP-capable extent fits.

`ServeEngine` wires these together (launch/engine.py `_degrade`): on a
`DeviceLoss` it re-enters `context.mesh_scope` on the planned sub-mesh,
rebuilds its compiled bundles (the mesh fingerprint already keys the
decode-bundle LRU), re-shards weights via `fault.elastic_remesh`
(`sharding.param_pspecs` on the new mesh), and replays every in-flight
request through the recovery path -- surviving streams bit-identical to
the fault-free run, `replay_divergence == 0`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault import SimulatedFailure
from repro.launch.resilience import ChaosSchedule, _hash_frac


class DeviceLoss(SimulatedFailure):
    """Injected loss of `n_lost` mesh devices at a dispatch site.

    Subclasses `SimulatedFailure` so every existing recovery path (the
    engine's `_recover`, the training driver's restart loop) already
    catches it; mesh-aware engines additionally re-plan their mesh."""

    def __init__(self, site: str, n_lost: int):
        super().__init__(
            f"injected loss of {n_lost} device(s) at {site}")
        self.site = site
        self.n_lost = int(n_lost)


class DeviceHealthRegistry:
    """Alive/dead bookkeeping for one mesh's devices.

    Deterministic by construction: `kill(n)` marks the LAST n healthy
    devices dead (stable order = the mesh's flattened device order), so
    a seeded chaos run reproduces the same degraded topology every time.
    At least one device always survives -- the simulated controller has
    to run somewhere."""

    def __init__(self, devices: Sequence):
        self._devices = list(np.asarray(devices).flat)
        self._dead: List[int] = []      # device ids, kill order

    def kill(self, n: int) -> List[int]:
        """Mark up to `n` more devices dead; returns the ids killed now."""
        healthy = self.healthy()
        n = max(0, min(int(n), len(healthy) - 1))
        victims = healthy[len(healthy) - n:]
        ids = [int(d.id) for d in victims]
        self._dead.extend(ids)
        return ids

    def healthy(self) -> list:
        dead = set(self._dead)
        return [d for d in self._devices if int(d.id) not in dead]

    @property
    def dead_ids(self) -> Tuple[int, ...]:
        return tuple(self._dead)

    @property
    def n_healthy(self) -> int:
        return len(self._devices) - len(self._dead)


@dataclasses.dataclass
class DeviceLossInjector(ChaosSchedule):
    """ChaosSchedule that can also kill counted devices.

    `lose_at_sites` maps dispatch sites (``kind:index``, the engine's
    `_guarded` counters) to a device count; `lose_rate`/`lose_seed` draw
    additional loss events deterministically per site (`lose_n` devices
    each, at most `lose_max` events).  Loss is checked BEFORE the plain
    fault check on the same site string, and both decisions are pure
    functions of the site, so arming one schedule never perturbs where
    the other fires -- the deterministic-accounting contract the replay
    tests assert.
    """
    lose_at_sites: Tuple[Tuple[str, int], ...] = ()
    lose_rate: float = 0.0
    lose_seed: int = 0
    lose_n: int = 1
    lose_max: Optional[int] = None
    lost_sites: dict = dataclasses.field(default_factory=dict)

    def loss_at(self, site: str) -> int:
        """Devices to kill at `site` (0 = no loss event here)."""
        for s, n in self.lose_at_sites:
            if s == site:
                return n
        if self.lose_rate > 0 and \
                _hash_frac(self.lose_seed, f"lose|{site}") < self.lose_rate:
            return self.lose_n
        return 0

    def check_site(self, site: str) -> None:
        if site not in self.failed:
            capped = self.lose_max is not None \
                and len(self.lost_sites) >= self.lose_max
            n = 0 if capped else self.loss_at(site)
            if n > 0:
                self.failed.add(site)       # at-most-once, like faults
                self.lost_sites[site] = n
                raise DeviceLoss(site, n)
        super().check_site(site)

    @classmethod
    def parse(cls, spec: str) -> "DeviceLossInjector":
        """Extend the $REPRO_CHAOS grammar with device-loss arms::

            REPRO_CHAOS='lose@segment:1=4'            # kill 4 at a site
            REPRO_CHAOS='lose_rate=0.02,lose_seed=7'  # seeded loss draws
            REPRO_CHAOS='rate=0.05,seed=3;lose@chunk:2;lose_max=1'

        Tokens starting with ``lose`` are consumed here; everything else
        keeps the base `ChaosSchedule.parse` meaning."""
        lose_sites: List[Tuple[str, int]] = []
        lose_rate, lose_seed, lose_n, lose_max = 0.0, 0, 1, None
        rest: List[str] = []
        for tok in (t.strip() for part in spec.split(";")
                    for t in part.split(",")):
            if not tok:
                continue
            if tok.startswith("lose@"):
                body = tok[len("lose@"):]
                site, _, cnt = body.partition("=")
                kind, _, idx = site.partition(":")
                if kind not in cls.SITE_KINDS or not idx.isdigit() \
                        or (cnt and not cnt.isdigit()):
                    raise ValueError(
                        f"REPRO_CHAOS: bad device-loss site {tok!r} "
                        f"(want lose@kind:index or lose@kind:index=N)")
                lose_sites.append((site, int(cnt) if cnt else 1))
            elif tok.startswith("lose_") and "=" in tok:
                k, v = tok.split("=", 1)
                if k == "lose_rate":
                    lose_rate = float(v)
                elif k == "lose_seed":
                    lose_seed = int(v)
                elif k == "lose_n":
                    lose_n = int(v)
                elif k == "lose_max":
                    lose_max = int(v)
                else:
                    raise ValueError(
                        f"REPRO_CHAOS: unknown device-loss key {k!r} "
                        f"(want lose_rate/lose_seed/lose_n/lose_max)")
            else:
                rest.append(tok)
        base = ChaosSchedule.parse(",".join(rest)) if rest \
            else ChaosSchedule()
        return cls(fail_at_sites=base.fail_at_sites, rate=base.rate,
                   seed=base.seed, max_failures=base.max_failures,
                   lose_at_sites=tuple(lose_sites), lose_rate=lose_rate,
                   lose_seed=lose_seed, lose_n=lose_n, lose_max=lose_max)

    @property
    def arms_loss(self) -> bool:
        return bool(self.lose_at_sites) or self.lose_rate > 0


# ---------------------------------------------------------------------------
# degraded-mesh planning
# ---------------------------------------------------------------------------

def plan_shape(old_shape: Tuple[int, int], n_healthy: int, n_slots: int,
               cfg=None) -> Tuple[int, int]:
    """The (data, model) extents of the largest valid sub-mesh.

    Constraints: data is a power of two dividing `n_slots` (the engine's
    slot axis must split evenly -- scheduler.validate_slot_sharding);
    model divides the ORIGINAL model extent, so every head count that
    divided before still divides (slot_state.tp_plan degrades to
    replication otherwise, never errors).  Preference order: most devices
    used, then data extent closest to the original (keep request packing
    wide), then -- with a config -- a model extent whose TP plan stays
    ACTIVE, then the larger model extent."""
    from repro.launch.scheduler import largest_valid_dp

    d0, m0 = old_shape
    if n_healthy < 1:
        raise ValueError("plan_shape: no healthy devices left")
    tp_active: frozenset = frozenset()
    if cfg is not None:
        from repro.models import slot_state
        tp_active = frozenset(slot_state.tp_viable_sizes(cfg, m0))

    best = None
    m = m0
    while m >= 1:
        if m0 % m == 0:
            d = largest_valid_dp(n_slots, n_healthy // m)
            if d * m <= n_healthy:
                score = (d * m,                      # use the most devices
                         -abs(d - d0),               # keep dp near original
                         1 if m in tp_active else 0,
                         m)
                if best is None or score > best[0]:
                    best = (score, (d, m))
        m -= 1
    assert best is not None    # m=1, d=1 always fits when n_healthy >= 1
    return best[1]


def plan_degraded_mesh(old_mesh, healthy: Sequence, *, dp_axes: tuple,
                       model_axis: str, n_slots: int, cfg=None):
    """Build the degraded Mesh over the first (d x m) healthy devices.

    The new mesh keeps the old axis NAMES (the shard_map in_specs refer
    to them); when the old mesh had several dp axes (pod, data), the
    planned data extent lands on the FIRST and the rest collapse to 1.
    Healthy devices are taken in the old mesh's flattened order, so the
    plan is deterministic given the same loss sequence."""
    import jax

    d0 = 1
    for a in dp_axes:
        d0 *= old_mesh.shape[a]
    m0 = old_mesh.shape[model_axis] if model_axis in old_mesh.axis_names \
        else 1
    d, m = plan_shape((d0, m0), len(healthy), n_slots, cfg)
    shape = []
    first_dp = dp_axes[0] if dp_axes else None
    for name in old_mesh.axis_names:
        if name == first_dp:
            shape.append(d)
        elif name == model_axis:
            shape.append(m)
        else:
            shape.append(1)
    devs = np.asarray(healthy[:d * m]).reshape(tuple(shape))
    return jax.sharding.Mesh(devs, old_mesh.axis_names)
