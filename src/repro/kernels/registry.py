"""Lowering registry: pluggable per-backend kernel lowerings for packed ops.

This is the paper's sec. 3.3/3.4 binding step made first-class.  SILVIA keeps
its transformation pass target-agnostic by emitting calls to *placeholder
functions* that a technology library later binds to concrete DSP48E2 RTL
modules; our packed primitives (`core/prims.py`) are the placeholders, and
this registry is the technology library.  Each packed op registers named
lowerings:

    op               lowerings (id: where it runs)
    ---------------  -----------------------------------------------------
    simd_add         tpu-pallas / gpu-pallas / cpu-vector / ref
    muladd2          tpu-pallas / gpu-pallas / cpu-vector / ref
    mul4             tpu-pallas / gpu-pallas / cpu-vector / ref
    quant_matmul     tpu-pallas / gpu-pallas / cpu-vector / ref
    packed_w4_matmul tpu-pallas / gpu-pallas / cpu-vector / ref

Every lowering carries a **capability predicate** (backend / dtype /
lane_bits support), a **priority** (highest legal one wins) and a stable
string id.  `ref` (the pure-jnp oracle, `kernels/ref.py`) is always legal
and lowest-priority: resolution can never fail.

Resolution is computed once and cached per (op, backend, attrs): the env is
read lazily on first resolve, NOT per call (the old `_use_pallas()` re-read
`REPRO_FORCE_PALLAS` on every trace).  Overrides:

* ``REPRO_LOWERING=<op>=<id>,...`` forces specific ops; ``*=<id>`` forces
  every op (e.g. ``REPRO_LOWERING='*=ref'`` runs the whole suite on the
  oracle).  Forcing bypasses capability predicates -- a Pallas lowering
  forced onto a non-native backend runs in interpret mode.
* ``REPRO_FORCE_PALLAS`` is kept as a deprecated alias: truthy maps to
  ``*=tpu-pallas``, falsy to ``*=ref``.
* ``with registry.force("ref"): ...`` / ``force(simd_add="cpu-vector")``
  scopes an override to a block (tests).  Contexts nest; inner wins.
* ``registry.invalidate()`` drops the cached resolutions AND the cached env
  parse -- call it after mutating the env vars in-process.

`fingerprint()` summarizes the active resolution; the serve-path bundle
caches fold it into their keys so a forced-lowering change can never be
served a stale compiled graph.

Ops are dispatched with `dispatch(op, *args, **kwargs)`: a shared per-op
**adapter** canonicalizes operands first (broadcast / stack / astype -- the
prep that used to be duplicated inside `kernels/ops.py`'s Pallas branches),
so every lowering sees the same canonical operand layout:

    simd_add          xs, ys: k-tuples broadcast to one shape, lane dtype
    muladd2           a, b, c: stacked (n, ...) int8
    mul4              a: stacked (4, ...) int8; b: (...) int8
    quant_matmul      x_q [M,K] int8, w_q [K,N] int8, scales f32
    packed_w4_matmul  x_q [M,K] int8, w_packed [K,N//2] int8, scales f32
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: the packed ops served by the registry (the paper's placeholder functions)
OPS = ("simd_add", "muladd2", "mul4", "quant_matmul", "packed_w4_matmul")

#: which lowering family runs NATIVELY on each JAX backend -- the single
#: source for this binding (kernels/lowerings.py predicates, autotune's
#: interpret-mode defaults, and benchmarks all derive from it)
NATIVE_LOWERING = {"cpu": "cpu-vector", "tpu": "tpu-pallas",
                   "gpu": "gpu-pallas"}


def native_lowering(backend: Optional[str] = None) -> Optional[str]:
    """The lowering id native to `backend` (default: the current one);
    None for backends with no native family (ref still serves them)."""
    return NATIVE_LOWERING.get(backend or jax.default_backend())


def native_backend(lid: str) -> Optional[str]:
    """Inverse of native_lowering: the backend a Pallas/vector family runs
    natively on; None for backend-agnostic lowerings (ref)."""
    for backend, native in NATIVE_LOWERING.items():
        if native == lid:
            return backend
    return None


@dataclasses.dataclass(frozen=True)
class Env:
    """What a capability predicate may inspect: the JAX backend plus the
    call-site resolution attrs (lane_bits, chain length, out dtype...)."""
    backend: str
    attrs: Tuple[Tuple[str, object], ...] = ()

    def attr(self, key: str, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default


@dataclasses.dataclass(frozen=True)
class Lowering:
    op: str
    lid: str                               # stable id, e.g. "tpu-pallas"
    fn: Callable                           # takes CANONICAL operands
    priority: int                          # highest legal one wins
    predicate: Optional[Callable] = None   # predicate(Env) -> bool
    description: str = ""

    def legal(self, env: Env) -> bool:
        return self.predicate is None or bool(self.predicate(env))


_TABLE: Dict[str, Dict[str, Lowering]] = {op: {} for op in OPS}
_resolve_cache: Dict[tuple, Lowering] = {}
_tls = threading.local()                           # per-thread force stack:
_env_forced: Optional[Dict[str, str]] = None       # two engines pinned to
_loaded = False                                    # different censuses may
                                                   # serve from two threads


def _force_stack() -> List[Dict[str, str]]:
    stack = getattr(_tls, "force_stack", None)
    if stack is None:
        stack = _tls.force_stack = []
    return stack


def register(op: str, lid: str, *, priority: int,
             predicate: Optional[Callable] = None, description: str = ""):
    """Decorator: register `fn` as lowering `lid` of packed op `op`."""
    if op not in _TABLE:
        raise KeyError(f"unknown packed op {op!r} (known: {OPS})")

    def deco(fn):
        if lid in _TABLE[op]:
            raise ValueError(f"lowering {op}:{lid} registered twice")
        _TABLE[op][lid] = Lowering(op, lid, fn, priority, predicate,
                                   description)
        _resolve_cache.clear()
        return fn

    return deco


def _ensure_loaded() -> None:
    """Populate the table on first use (the lowering modules import the
    kernel modules, which import autotune -- keep that out of import time
    of this module)."""
    global _loaded
    if not _loaded:
        try:
            from repro.kernels import lowerings as _  # noqa: F401 (registers)
        except BaseException:
            # a partial registration must not linger: drop it so the retry
            # re-raises the ROOT-CAUSE import error instead of a misleading
            # "registered twice" / "no legal lowering"
            for table in _TABLE.values():
                table.clear()
            _resolve_cache.clear()
            raise
        _loaded = True


def ops() -> tuple:
    return OPS


def lowerings(op: str) -> Tuple[Lowering, ...]:
    """All registered lowerings of `op`, highest priority first."""
    _ensure_loaded()
    return tuple(sorted(_TABLE[op].values(),
                        key=lambda l: (-l.priority, l.lid)))


def lowering_ids(op: str) -> Tuple[str, ...]:
    return tuple(l.lid for l in lowerings(op))


# ---------------------------------------------------------------------------
# forced overrides: env vars (parsed once) + the force() context stack
# ---------------------------------------------------------------------------

def _parse_env() -> Dict[str, str]:
    spec = os.environ.get("REPRO_LOWERING")
    if spec is not None and not spec.strip():
        spec = None   # blank (e.g. an empty CI yaml env entry) == unset,
    if spec is not None:  # so the deprecated alias below still applies
        forced: Dict[str, str] = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                raise ValueError(
                    f"REPRO_LOWERING entry {item!r} is not <op>=<id> "
                    f"(ops: {', '.join(OPS)} or '*')")
            op, lid = (s.strip() for s in item.split("=", 1))
            if op != "*" and op not in OPS:
                raise ValueError(
                    f"REPRO_LOWERING names unknown op {op!r} "
                    f"(known: {', '.join(OPS)} or '*')")
            forced[op] = lid
        return forced
    legacy = os.environ.get("REPRO_FORCE_PALLAS")
    if legacy is not None:
        warnings.warn(
            "REPRO_FORCE_PALLAS is deprecated; use REPRO_LOWERING="
            "'*=tpu-pallas' (or '*=ref') instead", DeprecationWarning,
            stacklevel=2)
        return {"*": "ref" if legacy in ("0", "false", "") else "tpu-pallas"}
    return {}


def _forced_id(op: str) -> Optional[str]:
    """Forced lowering id for `op`, innermost force() layer first (a layer's
    op-specific entry and its wildcard are equal-rank: a nested
    force("ref") overrides an outer force(op="...")), then the env map
    (parsed once, cached)."""
    global _env_forced
    if _env_forced is None:
        _env_forced = _parse_env()
    for layer in reversed(_force_stack()):
        lid = layer.get(op, layer.get("*"))
        if lid is not None:
            return lid
    return _env_forced.get(op, _env_forced.get("*"))


@contextlib.contextmanager
def force(default: Optional[str] = None, **by_op: str):
    """Force lowering selection inside a block (tests / benchmarks).

        with registry.force("ref"): ...                 # every op
        with registry.force(simd_add="cpu-vector"): ... # one op

    Forcing bypasses capability predicates; unknown ids raise at resolve
    time.  Contexts nest (inner wins per op)."""
    layer: Dict[str, str] = {}
    if default is not None:
        layer["*"] = default
    for op, lid in by_op.items():
        if op not in OPS:
            raise KeyError(f"unknown packed op {op!r} (known: {OPS})")
        layer[op] = lid
    stack = _force_stack()
    stack.append(layer)
    try:
        yield
    finally:
        stack.pop()


def invalidate() -> None:
    """Drop cached resolutions, the cached env parse AND the stored
    lowering-timings cache.  Call after mutating REPRO_LOWERING /
    REPRO_FORCE_PALLAS / REPRO_LOWERING_TIMINGS in-process (resolution is
    otherwise computed once, not re-read per trace)."""
    global _env_forced
    _env_forced = None
    _resolve_cache.clear()
    from repro.kernels import timings
    timings.invalidate()


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

def resolve(op: str, **attrs) -> Lowering:
    """The lowering that will serve `op` under the current (backend, env,
    force stack), given the call-site attrs.  Cached; never fails while a
    predicate-free lowering (ref) is registered."""
    _ensure_loaded()
    if op not in _TABLE:
        raise KeyError(f"unknown packed op {op!r} (known: {OPS})")
    backend = jax.default_backend()
    # the force-stack CONTENTS are part of the key (not cleared on
    # enter/exit): repeated equal force() contexts -- e.g. the serve-path
    # bundle pinning around every dispatch -- hit the cache, and the
    # unforced base state's entries survive any number of force blocks
    stack_key = tuple(tuple(sorted(l.items())) for l in _force_stack())
    key = (op, backend, tuple(sorted(attrs.items())), stack_key)
    hit = _resolve_cache.get(key)
    if hit is not None:
        return hit
    lid = _forced_id(op)
    if lid is not None:
        low = _TABLE[op].get(lid)
        if low is None:
            raise ValueError(
                f"forced lowering {op}={lid!r} is not registered "
                f"(registered: {', '.join(sorted(_TABLE[op]))})")
    else:
        low = _stored_default(op, backend)
        if low is None:
            env = Env(backend, key[2])
            low = next((l for l in lowerings(op) if l.legal(env)), None)
            if low is None:  # unreachable while ref is registered
                raise RuntimeError(f"no legal lowering for {op} on "
                                   f"{backend}")
    _resolve_cache[key] = low
    return low


def _stored_default(op: str, backend: str) -> Optional[Lowering]:
    """Measured per-op auto-default (kernels/timings.py): on backends
    with no native Pallas family (CPU), the stored fastest lowering from
    a `benchmarks/lowering_matrix.py --record` run on THIS host wins over
    the guessed priorities; no record -> None (priorities decide, i.e.
    `ref` stays the CPU default).  Backends with native Pallas kernels
    keep their priority ordering -- a stored CPU-side timing must never
    shadow a real accelerator kernel."""
    if backend != "cpu":
        return None
    from repro.kernels import timings
    lid = timings.stored_best(op, backend)
    if lid is None:
        return None
    low = _TABLE[op].get(lid)
    if low is None:
        return None   # stale record for an unregistered lowering
    # a Pallas family recorded on CPU would run in interpret mode --
    # never an auto-default, only reachable by forcing
    if native_backend(lid) not in (None, backend):
        return None
    return low


def active_lowerings() -> Dict[str, str]:
    """Census {op: lowering id} under the current resolution -- surfaced
    by engine/serve `cache_info()` and the benchmark BENCH JSON rows.

    Resolved with DEFAULT attrs: the census (and everything derived from
    it -- `fingerprint()`, the serve-path bundle pinning) is one id per
    op.  A predicate that gates on call-site attrs (e.g. rejects
    lane_bits=16) only steers per-call AUTO-selection; it cannot split one
    op across two ids within a pinned serving bundle.  Register such a
    case as two ops (or make the lowering handle the attr internally)
    rather than relying on attr-dependent predicates under pinning."""
    return {op: resolve(op).lid for op in OPS}


def census_str() -> str:
    """The active census as one printable line (CLI / example output)."""
    return ", ".join(f"{op}={lid}"
                     for op, lid in sorted(active_lowerings().items()))


def fingerprint() -> tuple:
    """Stable summary of the active resolution (default attrs, see
    `active_lowerings`), for compiled-graph cache keys (launch/serve.py
    decode bundles): two runs with different forced lowerings must never
    share a compiled executable."""
    return tuple(sorted(active_lowerings().items()))


# ---------------------------------------------------------------------------
# per-op canonicalization adapters (shared by every lowering)
# ---------------------------------------------------------------------------

def _adapt_simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False):
    shape = jnp.broadcast_shapes(*[x.shape for x in (*xs, *ys)])
    dt = jnp.int8 if lane_bits == 8 else jnp.int16
    xs = tuple(jnp.broadcast_to(x, shape).astype(dt) for x in xs)
    ys = tuple(jnp.broadcast_to(y, shape).astype(dt) for y in ys)
    return ((xs, ys), {"lane_bits": lane_bits, "sub": sub},
            {"lane_bits": lane_bits})


def _adapt_muladd2(a, b, c):
    shape = jnp.broadcast_shapes(*[x.shape for x in (*a, *b, *c)])
    st = lambda seq: jnp.stack([jnp.broadcast_to(x, shape).astype(jnp.int8)
                                for x in seq])
    return ((st(a), st(b), st(c)), {}, {"n": len(a)})


def _adapt_mul4(a, b):
    shape = jnp.broadcast_shapes(*[x.shape for x in a], b.shape)
    a4 = jnp.stack([jnp.broadcast_to(x, shape).astype(jnp.int8) for x in a])
    return ((a4, jnp.broadcast_to(b, shape).astype(jnp.int8)), {}, {})


def _adapt_quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32):
    return ((x_q, w_q, x_scale, w_scale), {"out_dtype": out_dtype},
            {"out_dtype": np.dtype(out_dtype).name})


def _adapt_packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                            out_dtype=jnp.float32):
    return ((x_q, w_packed, x_scale, w_scale), {"out_dtype": out_dtype},
            {"out_dtype": np.dtype(out_dtype).name})


_ADAPTERS = {
    "simd_add": _adapt_simd_add,
    "muladd2": _adapt_muladd2,
    "mul4": _adapt_mul4,
    "quant_matmul": _adapt_quant_matmul,
    "packed_w4_matmul": _adapt_packed_w4_matmul,
}


#: trace-time packed-op dispatch census {op: count}.  Counts TRACES, not
#: executions (a jitted graph dispatches once per compilation) -- enough
#: to assert that a "quantized" serve path actually binds packed matmuls
#: instead of silently serving bf16 graphs (the reduced-config
#: quantization no-op this census was added to catch).
_DISPATCH_COUNTS: Dict[str, int] = {op: 0 for op in OPS}


def dispatch_counts() -> Dict[str, int]:
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    for op in OPS:
        _DISPATCH_COUNTS[op] = 0


def dispatch(op: str, *args, **kwargs):
    """Canonicalize operands through the op's adapter, resolve the active
    lowering, run it.  The single entry point every packed-op call site
    (core/prims.py, quant layers) binds through."""
    _DISPATCH_COUNTS[op] += 1
    cargs, ckwargs, attrs = _ADAPTERS[op](*args, **kwargs)
    return resolve(op, **attrs).fn(*cargs, **ckwargs)
