"""Factor-2 shared-operand MAD Pallas kernel -- SILVIAMuladd's packed unit.

Paper (sec. 2.2, Fu et al. wp486): one DSP computes p_a = sum a_i*c_i and
p_b = sum b_i*c_i by placing a in the upper multiplier port bits:
(a * 2^18 + b) * c.  TPU adaptation: same trick in an int32 lane with a
16-bit low lane:

    P   = sum_i (a_i * 2^16 + b_i) * c_i          (ONE i32 multiply per i,
                                                   instead of two)
    p_b = sign_extend_16(P mod 2^16)              (exact while |p_b| < 2^15,
    p_a = (P - p_b) >> 16                          guaranteed by Eq. 2)

Chain length N obeys the re-derived Eq. 2 bound (core/bounds.py):
N(m=8,n=8,L=16)=1, N(m=4,n=8,L=16)=31 -- the w4a8 serving configuration gets
genuine in-lane accumulation, mirroring the paper's 7-deep DSP cascades.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


def _muladd2_kernel(a_ref, b_ref, c_ref, pa_ref, pb_ref):
    # blocks: (n, bm, bn) int8 -> (bm, bn) int32
    p_a, p_b = common.madd2_reduce(a_ref[...].astype(jnp.int32),
                                   b_ref[...].astype(jnp.int32),
                                   c_ref[...].astype(jnp.int32))
    pa_ref[...] = p_a
    pb_ref[...] = p_b


def muladd2(a, b, c, *, block=None, interpret: bool | None = None):
    """a, b, c: (n, ...) int8 stacks (n = chain length within the Eq. 2
    bound).  Returns (p_a, p_b) int32 of shape (...).

    The caller (core pass / ops.py) is responsible for n <= Eq. 2 bound;
    violating it overflows the low lane exactly as it would on the DSP.
    block=None resolves through kernels/autotune.py (keyed on chain length
    and the padded 2-D layout)."""
    interpret = common.interpret_default() if interpret is None else interpret
    assert a.shape == b.shape == c.shape and a.ndim >= 1
    n = a.shape[0]
    inner = a.shape[1:]
    a2, shape, cnt = common.pad_to_2d(a.reshape(n, -1)[0], common.TILE_8)
    rows, cols = a2.shape
    if block is None:
        block = autotune.resolve("muladd2", n, rows, cols,
                                 lowering="tpu-pallas", interpret=interpret)

    def prep(x):
        flat = x.reshape(n, -1)
        pad = rows * cols - flat.shape[1]
        return jnp.pad(flat, ((0, 0), (0, pad))).reshape(n, rows, cols)

    bm = max(common.TILE_8[0], min(block[0], rows) // common.TILE_8[0] * common.TILE_8[0])
    bn = max(common.TILE_8[1], min(block[1], cols) // common.TILE_8[1] * common.TILE_8[1])
    rows = common.cdiv(rows, bm) * bm
    cols = common.cdiv(cols, bn) * bn
    a3, b3, c3 = prep(a), prep(b), prep(c)
    grid = (rows // bm, cols // bn)
    spec_in = pl.BlockSpec((n, bm, bn), lambda i, j: (0, i, j))
    spec_out = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    p_a, p_b = pl.pallas_call(
        _muladd2_kernel,
        out_shape=[jax.ShapeDtypeStruct((rows, cols), jnp.int32)] * 2,
        grid=grid,
        in_specs=[spec_in, spec_in, spec_in],
        out_specs=[spec_out, spec_out],
        interpret=interpret,
    )(a3, b3, c3)
    return (common.unpad_from_2d(p_a, inner, cnt),
            common.unpad_from_2d(p_b, inner, cnt))
