"""Block-size autotuner for the packed Pallas matmul kernels.

The paper's flow bakes its packing decisions in at synthesis time; the TPU
serving analogue of that "pay once" philosophy is an AutoDSE-style search
over the kernel tile sizes with a *persistent on-disk cache*: the first time
a (kernel, M, K, N, backend) shape signature is seen with tuning enabled,
every candidate block is timed and the winner is written to a JSON cache;
every later process start reads the cache and pays nothing.

    from repro.kernels import autotune
    autotune.enable(True)                  # or REPRO_AUTOTUNE=1
    block = autotune.resolve("quant_matmul", m, k, n)

Kernels call `resolve()` when invoked with `block=None`; with tuning
disabled and no cache entry it falls through to the kernel's static default,
so the tuner is strictly opt-in.

Cache location: $REPRO_AUTOTUNE_CACHE, else ~/.cache/repro/autotune.json.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = (256, 256, 512)

# Candidate (bm, bn, bk) tiles: all keep x/w/acc blocks within a small slice
# of the ~16 MiB VMEM budget (see quant_matmul.py header for the arithmetic).
CANDIDATE_BLOCKS = (
    (128, 128, 256),
    (128, 256, 512),
    (256, 128, 512),
    (256, 256, 256),
    (256, 256, 512),
    (256, 512, 512),
    (512, 256, 512),
)

_enabled = os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0", "false")
_cache: dict | None = None


def enable(on: bool = True) -> None:
    global _enabled
    _enabled = bool(on)


def enabled() -> bool:
    return _enabled


def cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


def _load() -> dict:
    global _cache
    if _cache is None:
        try:
            _cache = json.loads(cache_path().read_text())
        except (OSError, ValueError):
            _cache = {}
    return _cache


def _save() -> None:
    global _cache
    path = cache_path()
    try:
        # merge-on-save: another process may have tuned other shapes since
        # we loaded; our in-process entries win only on key collision
        try:
            on_disk = json.loads(path.read_text())
        except (OSError, ValueError):
            on_disk = {}
        _cache = {**on_disk, **_cache}
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(_cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: tuning still works in-process


def _key(kind: str, m: int, k: int, n: int) -> str:
    return f"{kind}:{m}x{k}x{n}:{jax.default_backend()}"


def lookup(kind: str, m: int, k: int, n: int) -> tuple | None:
    ent = _load().get(_key(kind, m, k, n))
    if ent is None:
        return None
    return tuple(ent["block"])


def resolve(kind: str, m: int, k: int, n: int) -> tuple:
    """Best known block for this shape: cache hit > (tune now if enabled)
    > static default."""
    hit = lookup(kind, m, k, n)
    if hit is not None:
        return hit
    if _enabled:
        return tune(kind, m, k, n)
    return DEFAULT_BLOCK


def _time_call(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def tune(kind: str, m: int, k: int, n: int,
         candidates=CANDIDATE_BLOCKS, iters: int = 3) -> tuple:
    """Time every candidate block on synthetic int8 operands, persist and
    return the winner.  Runs real kernel invocations, so only call at
    set-up time (resolve() does, once per shape signature)."""
    from repro.kernels import packed_matmul, quant_matmul  # lazy: no cycle

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    if kind == "packed_w4_matmul":
        w = jnp.asarray(rng.integers(-128, 128, (k, n // 2)), jnp.int8)
        def run(blk):
            return packed_matmul.packed_w4_matmul_acc(x, w, block=blk)
    elif kind == "quant_matmul":
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        def run(blk):
            return quant_matmul.quant_matmul_acc(x, w, block=blk)
    else:
        raise ValueError(f"unknown autotune kind: {kind}")

    best_blk, best_us = DEFAULT_BLOCK, float("inf")
    results = {}
    for blk in candidates:
        try:
            us = _time_call(jax.jit(run, static_argnums=0), blk, iters=iters)
        except Exception:
            continue  # candidate illegal on this backend/shape
        results[str(blk)] = round(us, 1)
        if us < best_us:
            best_blk, best_us = blk, us
    if not results:
        # every candidate failed: don't poison the persistent cache (a hit
        # would suppress retries forever) -- fall back without recording
        return DEFAULT_BLOCK
    cache = _load()
    cache[_key(kind, m, k, n)] = {
        "block": list(best_blk), "us": round(best_us, 1),
        "candidates": results,
    }
    _save()
    return best_blk
