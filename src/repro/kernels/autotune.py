"""Block-size autotuner for the packed Pallas kernels.

The paper's flow bakes its packing decisions in at synthesis time; the TPU
serving analogue of that "pay once" philosophy is an AutoDSE-style search
over the kernel tile sizes with a *persistent on-disk cache*: the first time
a (kernel, shape..., backend) signature is seen with tuning enabled, every
candidate block is timed and the winner is written to a JSON cache; every
later process start reads the cache and pays nothing.

    from repro.kernels import autotune
    autotune.enable(True)                  # or REPRO_AUTOTUNE=1
    block = autotune.resolve("quant_matmul", m, k, n)   # Mosaic (default
    block = autotune.resolve("simd_add", rows, cols,    # lowering id is
                             lowering="gpu-pallas",     # "tpu-pallas")
                             interpret=False)

Kernels call `resolve()` when invoked with `block=None`; with tuning
disabled and no cache entry it falls through to the kernel's static default,
so the tuner is strictly opt-in.

Covered kinds: the GEMMs ("quant_matmul", "packed_w4_matmul"; 3-D
(bm, bn, bk) blocks keyed on M/K/N) and the SWAR units ("simd_add",
"mul4", "muladd2"; 2-D (bm, bn) blocks keyed on their padded 2-D layout,
plus the chain length for muladd2).

Cache keys (v2) include the **lowering id** ("tpu-pallas" / "gpu-pallas" --
the registry families that own tunable Pallas kernels) and the **execution
mode** ("native" / "interp") on top of kind/shape/backend.  v1 keyed on
`jax.default_backend()` alone, so interpret-mode CPU tuning results could
shadow real TPU timings for the same shapes; v2 entries can never collide
across lowerings or modes, and stale v1 entries are simply never read.

Cache location: $REPRO_AUTOTUNE_CACHE, else ~/.cache/repro/autotune.json.
On-disk format and failure handling live in kernels/diskcache.py: a
schema-versioned, checksummed envelope written atomically under a file
lock -- a corrupt/truncated/foreign-version cache file warns and
recomputes, it can never crash an engine.
"""
from __future__ import annotations

import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import diskcache

CACHE_VERSION = 2   # bumped: v2 keys fold in (lowering id, interpret mode)

DEFAULT_BLOCK = (256, 256, 512)

# Candidate (bm, bn, bk) tiles: all keep x/w/acc blocks within a small slice
# of the ~16 MiB VMEM budget (see quant_matmul.py header for the arithmetic).
CANDIDATE_BLOCKS = (
    (128, 128, 256),
    (128, 256, 512),
    (256, 128, 512),
    (256, 256, 256),
    (256, 256, 512),
    (256, 512, 512),
    (512, 256, 512),
)

# (bm, bn) tiles for the elementwise SWAR kernels.  pad_to_2d flattens to
# (rows, 128) -- one vreg-width column -- so only bm varies; bn is pinned
# at 128 (a larger bn would be clamped to cols inside the kernels anyway).
DEFAULT_BLOCK_2D = (256, 128)
CANDIDATE_BLOCKS_2D = (
    (32, 128),
    (64, 128),
    (128, 128),
    (256, 128),
    (512, 128),
    (1024, 128),
)

# kind -> (default block, candidate list); the SWAR kinds use 2-D blocks
KIND_SPECS = {
    "quant_matmul": (DEFAULT_BLOCK, CANDIDATE_BLOCKS),
    "packed_w4_matmul": (DEFAULT_BLOCK, CANDIDATE_BLOCKS),
    "simd_add": (DEFAULT_BLOCK_2D, CANDIDATE_BLOCKS_2D),
    "mul4": (DEFAULT_BLOCK_2D, CANDIDATE_BLOCKS_2D),
    "mul4_split": (DEFAULT_BLOCK_2D, CANDIDATE_BLOCKS_2D),
    "muladd2": (DEFAULT_BLOCK_2D, CANDIDATE_BLOCKS_2D),
}


def default_block(kind: str) -> tuple:
    return KIND_SPECS[kind][0]

_enabled = os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0", "false")
_cache: dict | None = None


def enable(on: bool = True) -> None:
    global _enabled
    _enabled = bool(on)


def enabled() -> bool:
    return _enabled


def cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


def _load() -> dict:
    global _cache
    if _cache is None:
        _cache = diskcache.load(cache_path(), CACHE_VERSION)
    return _cache


def _save() -> None:
    global _cache
    path = cache_path()
    # lock the read-merge-write cycle: another process may have tuned
    # other shapes since we loaded; our in-process entries win only on
    # key collision.  diskcache handles atomicity and read-only FS
    # (tuning still works in-process when store() fails)
    with diskcache.locked(path):
        on_disk = diskcache.load(path, CACHE_VERSION)
        _cache = {**on_disk, **(_cache or {})}
        diskcache.store(path, CACHE_VERSION, _cache)


def _interpret_default(lowering: str) -> bool:
    """A Pallas lowering tunes in the same mode it runs in (the shared
    common.interpret_default_for rule, so cache-key mode and kernel
    defaults can never disagree)."""
    from repro.kernels import common
    return common.interpret_default_for(lowering)


def _key(kind: str, *dims: int, lowering: str = "tpu-pallas",
         interpret: bool | None = None) -> str:
    if interpret is None:
        interpret = _interpret_default(lowering)
    mode = "interp" if interpret else "native"
    return (f"v{CACHE_VERSION}:{kind}:{'x'.join(map(str, dims))}:"
            f"{jax.default_backend()}:{lowering}:{mode}")


def lookup(kind: str, *dims: int, lowering: str = "tpu-pallas",
           interpret: bool | None = None) -> tuple | None:
    ent = _load().get(_key(kind, *dims, lowering=lowering,
                           interpret=interpret))
    if ent is None:
        return None
    return tuple(ent["block"])


def resolve(kind: str, *dims: int, lowering: str = "tpu-pallas",
            interpret: bool | None = None) -> tuple:
    """Best known block for this (shape, lowering, mode): cache hit >
    (tune now if enabled) > the kind's static default."""
    hit = lookup(kind, *dims, lowering=lowering, interpret=interpret)
    if hit is not None:
        return hit
    if _enabled:
        return tune(kind, *dims, lowering=lowering, interpret=interpret)
    return default_block(kind)


def _time_call(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _tune_runner(kind: str, dims: tuple, lowering: str, interpret: bool):
    """Synthetic-operand closure for one (kind, lowering): run(blk) ->
    kernel output, invoked in the same mode the cache key records."""
    # lazy imports: the kernels import this module for resolve()
    from repro.kernels import (gpu_pallas, mul4, muladd2, packed_matmul,
                               quant_matmul, simd_add)

    gpu = lowering == "gpu-pallas"
    rng = np.random.default_rng(0)
    if kind in ("quant_matmul", "packed_w4_matmul"):
        m, k, n = dims
        x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        if kind == "packed_w4_matmul":
            w = jnp.asarray(rng.integers(-128, 128, (k, n // 2)), jnp.int8)
            fn = gpu_pallas.packed_w4_matmul_acc if gpu else \
                packed_matmul.packed_w4_matmul_acc
            return lambda blk: fn(x, w, block=blk, interpret=interpret)
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        fn = gpu_pallas.quant_matmul_acc if gpu else \
            quant_matmul.quant_matmul_acc
        return lambda blk: fn(x, w, block=blk, interpret=interpret)
    if kind == "simd_add":
        rows, cols = dims
        x = jnp.asarray(rng.integers(0, 1 << 32, (rows, cols),
                                     dtype=np.uint32))
        y = jnp.asarray(rng.integers(0, 1 << 32, (rows, cols),
                                     dtype=np.uint32))
        fn = gpu_pallas.simd_add_packed if gpu else simd_add.simd_add_packed
        return lambda blk: fn(x, y, block=blk, interpret=interpret)
    if kind in ("mul4", "mul4_split"):
        rows, cols = dims
        a = jnp.asarray(rng.integers(-8, 8, (4, rows, cols)), jnp.int8)
        b = jnp.asarray(rng.integers(-8, 8, (rows, cols)), jnp.int8)
        if kind == "mul4_split":
            if gpu:
                # no gpu-pallas mul4_split kernel exists; timing the Mosaic
                # one here would persist a mislabeled gpu-pallas cache entry
                raise ValueError("mul4_split has no gpu-pallas kernel")
            return lambda blk: mul4.mul4_split(a, b, block=blk,
                                               interpret=interpret)
        fn = gpu_pallas.mul4 if gpu else mul4.mul4_full32
        return lambda blk: fn(a, b, block=blk, interpret=interpret)
    if kind == "muladd2":
        nc, rows, cols = dims
        a = jnp.asarray(rng.integers(-8, 8, (nc, rows, cols)), jnp.int8)
        b = jnp.asarray(rng.integers(-8, 8, (nc, rows, cols)), jnp.int8)
        c = jnp.asarray(rng.integers(-128, 128, (nc, rows, cols)), jnp.int8)
        fn = gpu_pallas.muladd2 if gpu else muladd2.muladd2
        return lambda blk: fn(a, b, c, block=blk, interpret=interpret)
    raise ValueError(f"unknown autotune kind: {kind}")


def tune(kind: str, *dims: int, candidates=None, iters: int = 3,
         lowering: str = "tpu-pallas", interpret: bool | None = None) -> tuple:
    """Time every candidate block on synthetic operands, persist and
    return the winner.  Runs real kernel invocations, so only call at
    set-up time (resolve() does, once per shape signature)."""
    if lowering not in ("tpu-pallas", "gpu-pallas"):
        # only the Pallas families have tunable blocks; timing anything
        # else here would persist a mislabeled entry to the shared cache
        raise ValueError(f"no tunable kernels for lowering {lowering!r} "
                         "(tunable: tpu-pallas, gpu-pallas)")
    if candidates is None:
        candidates = KIND_SPECS[kind][1]
    if interpret is None:
        interpret = _interpret_default(lowering)
    run = _tune_runner(kind, dims, lowering, interpret)

    best_blk, best_us = default_block(kind), float("inf")
    results = {}
    for blk in candidates:
        try:
            us = _time_call(jax.jit(run, static_argnums=0), blk, iters=iters)
        except Exception:
            continue  # candidate illegal on this backend/shape
        results[str(blk)] = round(us, 1)
        if us < best_us:
            best_blk, best_us = blk, us
    if not results:
        # every candidate failed: don't poison the persistent cache (a hit
        # would suppress retries forever) -- fall back without recording
        return default_block(kind)
    cache = _load()
    cache[_key(kind, *dims, lowering=lowering, interpret=interpret)] = {
        "block": list(best_blk), "us": round(best_us, 1),
        "candidates": results,
    }
    _save()
    return best_blk
