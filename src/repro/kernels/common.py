"""Shared utilities for the packed Pallas TPU kernels.

TPU tiling notes (the hardware this code targets; validated on CPU via
interpret mode):

* VPU lanes are 32-bit; the native vreg tile is (8, 128) for 32-bit types
  and (32, 128) for 8-bit types.  Every kernel here tiles VMEM blocks as
  multiples of those shapes so Mosaic lays registers out without relayouts.
* SWAR packing across *logical lanes* (k narrow ops in one i32 word) is the
  TPU analogue of the paper's DSP packing: one i32 VPU op carries k narrow
  operations.  Packing is free when operands are stored pre-packed (weights,
  packed offline at quantization time -- like FPGA routing, which costs
  nothing at runtime); activations pay a pack/unpack cost the tests account
  for separately.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Minimal TPU tile shapes per element width.
TILE_32 = (8, 128)
TILE_8 = (32, 128)


def pad_to_2d(x, tile):
    """Flatten x to 2D and pad each dim to a tile multiple.
    Returns (padded, orig_shape, (rows, cols))."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = tile[1]
    rows = -(-n // cols)
    rows_p = -(-rows // tile[0]) * tile[0]
    pad = rows_p * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_p, cols), shape, n


def unpad_from_2d(y, shape, n):
    return y.reshape(-1)[:n].reshape(shape)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.cache
def interpret_default() -> bool:
    """Pallas kernels run in interpret mode everywhere but real TPUs."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# SWAR lane packing helpers (jnp level; used by kernels and offline packers)
# ---------------------------------------------------------------------------

def lane_mask_high(lane_bits: int) -> int:
    """MSB-per-lane mask, e.g. 0x80808080 for 8-bit lanes in a u32 word."""
    m = 0
    for off in range(0, 32, lane_bits):
        m |= 1 << (off + lane_bits - 1)
    return m


def pack_lanes(xs, lane_bits: int):
    """Pack len(xs) == 32//lane_bits narrow int tensors into one uint32 SWAR
    word tensor (bit-concatenation of two's-complement lanes)."""
    n_lanes = 32 // lane_bits
    assert len(xs) == n_lanes
    lane_max = (1 << lane_bits) - 1
    w = jnp.zeros(jnp.broadcast_shapes(*[x.shape for x in xs]), jnp.uint32)
    for i, x in enumerate(xs):
        u = x.astype(jnp.int32).astype(jnp.uint32) & jnp.uint32(lane_max)
        w = w | (u << jnp.uint32(i * lane_bits))
    return w


def unpack_lanes(w, lane_bits: int):
    """Inverse of pack_lanes: returns list of int32 tensors (sign-extended)."""
    n_lanes = 32 // lane_bits
    lane_max = jnp.uint32((1 << lane_bits) - 1)
    sign = 1 << (lane_bits - 1)
    outs = []
    for i in range(n_lanes):
        u = (w >> jnp.uint32(i * lane_bits)) & lane_max
        s = u.astype(jnp.int32)
        s = ((s ^ sign) - sign)  # sign extend lane
        outs.append(s)
    return outs
