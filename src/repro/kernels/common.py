"""Shared utilities for the packed Pallas TPU kernels.

TPU tiling notes (the hardware this code targets; validated on CPU via
interpret mode):

* VPU lanes are 32-bit; the native vreg tile is (8, 128) for 32-bit types
  and (32, 128) for 8-bit types.  Every kernel here tiles VMEM blocks as
  multiples of those shapes so Mosaic lays registers out without relayouts.
* SWAR packing across *logical lanes* (k narrow ops in one i32 word) is the
  TPU analogue of the paper's DSP packing: one i32 VPU op carries k narrow
  operations.  Packing is free when operands are stored pre-packed (weights,
  packed offline at quantization time -- like FPGA routing, which costs
  nothing at runtime); activations pay a pack/unpack cost the tests account
  for separately.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Minimal TPU tile shapes per element width.
TILE_32 = (8, 128)
TILE_8 = (32, 128)


def pad_to_2d(x, tile):
    """Flatten x to 2D and pad each dim to a tile multiple.
    Returns (padded, orig_shape, (rows, cols))."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = tile[1]
    rows = -(-n // cols)
    rows_p = -(-rows // tile[0]) * tile[0]
    pad = rows_p * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_p, cols), shape, n


def unpad_from_2d(y, shape, n):
    return y.reshape(-1)[:n].reshape(shape)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.cache
def interpret_default_for(lid: str) -> bool:
    """A Pallas family runs natively only on its own backend (the
    registry.NATIVE_LOWERING binding); everywhere else it runs in
    interpret mode.  One helper so kernel defaults and the autotune cache
    keys can never disagree."""
    from repro.kernels import registry   # import cycle: registry is light
    return jax.default_backend() != registry.native_backend(lid)


def interpret_default() -> bool:
    """Mosaic (tpu-pallas) kernels interpret everywhere but real TPUs."""
    return interpret_default_for("tpu-pallas")


# ---------------------------------------------------------------------------
# SWAR lane packing helpers (jnp level; used by kernels and offline packers)
# ---------------------------------------------------------------------------

def lane_mask_high(lane_bits: int) -> int:
    """MSB-per-lane mask, e.g. 0x80808080 for 8-bit lanes in a u32 word."""
    m = 0
    for off in range(0, 32, lane_bits):
        m |= 1 << (off + lane_bits - 1)
    return m


# The packed-arithmetic identities every lowering family shares (plain jnp:
# legal inside Pallas kernel bodies AND at the XLA level for cpu_vector.py).
# kernels/ref.py deliberately does NOT use these -- the oracle stays an
# independent statement of the semantics these identities must reproduce.

def swar_add_sub(x, y, lane_bits: int, sub: bool = False):
    """Carry-kill SWAR add/sub on uint32 words: one 32-bit op computes
    32//lane_bits independent lane results (paper sec. 2.1 rescaled)."""
    h = jnp.uint32(lane_mask_high(lane_bits))
    nh = jnp.uint32(~lane_mask_high(lane_bits) & 0xFFFFFFFF)
    if sub:
        return ((x | h) - (y & nh)) ^ ((x ^ ~y) & h)
    return ((x & nh) + (y & nh)) ^ ((x ^ y) & h)


def extract_lane8(p, signed: bool = True):
    """Pop the low 8-bit lane of packed products: returns (lane, rest).

    Signed products use sign-extension (borrow correction per paper
    sec. 2.3: "adding the MSB of a product p_i to the next product" is
    algebraically the `(p - lane) >> 8` step); unsigned extract directly."""
    if signed:
        lane = ((p & 0xFF) ^ 0x80) - 0x80
    else:
        lane = p & 0xFF
    return lane, (p - lane) >> 8


def madd2_reduce(a32, b32, c32):
    """wp486 packed-operand MAD on stacked int32 (n, ...) operands:
    P = sum_i (a_i*2^16 + b_i)*c_i, then exact lane extraction -> (p_a,
    p_b).  ONE multiply per chain element; exact while |p_b| < 2^15 (the
    Eq. 2 bound the SILVIA legality check enforces)."""
    p = jnp.sum(((a32 << 16) + b32) * c32, axis=0)
    p_b = ((p & 0xFFFF) ^ 0x8000) - 0x8000      # sign-extend low lane
    p_a = (p - p_b) >> 16                        # exact: P - p_b == p_a*2^16
    return p_a, p_b


def mul4_reduce(a32, b32):
    """Factor-4 full-32-bit-lane multiply on signed int32 operands:
    ONE multiply computes four 4-bit products (paper Eq. 3 on the wide
    container), recovered by sequential lane extraction with sign
    borrows.  Exact: |sum_i a_i*2^(8i)| * |b| < 2^31 for 4-bit values."""
    w = a32[0] + (a32[1] << 8) + (a32[2] << 16) + (a32[3] << 24)
    p = w * b32
    p0, r = extract_lane8(p)
    p1, r = extract_lane8(r)
    p2, p3 = extract_lane8(r)
    return [p0, p1, p2, p3]


def unpack_w4_words(wp):
    """Packed int4 words [..., N//2] int8 -> [..., N] int8 weights
    (interleaved columns; inverse of ref.pack_w4's
    word = (w_even + 8) | (w_odd << 4)).  3 cheap VPU ops per word."""
    w32 = wp.astype(jnp.int32)
    w_even = (w32 & 0xF) - 8          # de-bias low nibble -> [-8, 7]
    w_odd = w32 >> 4                  # arithmetic shift -> [-8, 7]
    inter = jnp.stack([w_even, w_odd], axis=-1)
    return inter.reshape(*wp.shape[:-1], 2 * wp.shape[-1]).astype(jnp.int8)


def pack_lanes(xs, lane_bits: int):
    """Pack len(xs) == 32//lane_bits narrow int tensors into one uint32 SWAR
    word tensor (bit-concatenation of two's-complement lanes)."""
    n_lanes = 32 // lane_bits
    assert len(xs) == n_lanes
    lane_max = (1 << lane_bits) - 1
    w = jnp.zeros(jnp.broadcast_shapes(*[x.shape for x in xs]), jnp.uint32)
    for i, x in enumerate(xs):
        u = x.astype(jnp.int32).astype(jnp.uint32) & jnp.uint32(lane_max)
        w = w | (u << jnp.uint32(i * lane_bits))
    return w


def unpack_lanes(w, lane_bits: int):
    """Inverse of pack_lanes: returns list of int32 tensors (sign-extended)."""
    n_lanes = 32 // lane_bits
    lane_max = jnp.uint32((1 << lane_bits) - 1)
    sign = 1 << (lane_bits - 1)
    outs = []
    for i in range(n_lanes):
        u = (w >> jnp.uint32(i * lane_bits)) & lane_max
        s = u.astype(jnp.int32)
        s = ((s ^ sign) - sign)  # sign extend lane
        outs.append(s)
    return outs


def simd_add_lanes(packed_fn, xs, ys, lane_bits: int):
    """Shared unpacked-operand wrapper for every simd_add lowering: pack k
    narrow tensors into SWAR words (zero lanes pad a partially-filled unit,
    paper sec. 3.2), apply `packed_fn(xw, yw)`, unpack the first k lanes."""
    n_lanes = 32 // lane_bits
    k = len(xs)
    assert len(ys) == k <= n_lanes
    zero = jnp.zeros_like(xs[0])
    xw = pack_lanes(list(xs) + [zero] * (n_lanes - k), lane_bits)
    yw = pack_lanes(list(ys) + [zero] * (n_lanes - k), lane_bits)
    return unpack_lanes(packed_fn(xw, yw), lane_bits)[:k]
