"""Persistent per-op lowering timings: measured CPU auto-defaults.

PR 4 left the CPU auto-default on the `ref` oracle because per-op winners
flipped with shape and host noise across dev-host runs of
benchmarks/lowering_matrix.py.  This module is the AutoDSE-style answer
(measure, persist, then decide): `benchmarks/lowering_matrix.py --record`
persists its per-(op, lowering) timings here, and `registry.resolve()`
consults the stored winner as the per-op auto-default on backends with no
native Pallas family (CPU).  No record -> `ref` remains the fallback, so
behaviour is bit-for-bit the PR-4 default until a host has actually
measured itself.

Schema (entries object, merged on save like kernels/autotune.py):

    {"v1:<backend>:<op>": {"<lowering id>": {"us": float, "shape": str,
                                             "iters": int}}}

Entries keep the BEST (minimum) us per lowering id across recordings.
Cache location: $REPRO_LOWERING_TIMINGS, else
~/.cache/repro/lowering_timings.json.  The entries ride inside
kernels/diskcache.py's checksummed schema-versioned envelope (atomic
locked writes; damaged files warn-and-recompute, never raise).
"""
from __future__ import annotations

import os
import pathlib
from typing import Dict, Optional

from repro.kernels import diskcache

CACHE_VERSION = 1

_cache: Optional[dict] = None


def cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_LOWERING_TIMINGS")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "lowering_timings.json"


def _key(backend: str, op: str) -> str:
    return f"v{CACHE_VERSION}:{backend}:{op}"


def _load() -> dict:
    global _cache
    if _cache is None:
        _cache = diskcache.load(cache_path(), CACHE_VERSION)
    return _cache


def invalidate() -> None:
    """Drop the in-process cache (re-read the file on next lookup).
    `registry.invalidate()` calls this so env-var mutation in tests picks
    up a fresh timings file."""
    global _cache
    _cache = None


def _save() -> None:
    global _cache
    path = cache_path()
    # locked read-merge-write, keeping the faster record on collision;
    # diskcache handles atomicity and read-only FS (recording still
    # works in-process when store() fails)
    with diskcache.locked(path):
        on_disk = diskcache.load(path, CACHE_VERSION)
        merged = dict(on_disk)
        for key, by_lid in (_cache or {}).items():
            slot = dict(merged.get(key, {}))
            for lid, ent in by_lid.items():
                old = slot.get(lid)
                if old is None or ent["us"] < old["us"]:
                    slot[lid] = ent
            merged[key] = slot
        _cache = merged
        diskcache.store(path, CACHE_VERSION, merged)


def record(backend: str, op: str, lid: str, us: float, *,
           shape: str = "", iters: int = 0) -> None:
    """Persist one measurement (keeps the minimum us per lowering); a
    slower-than-stored timing changes nothing and skips the rewrite."""
    cache = _load()
    slot = cache.setdefault(_key(backend, op), {})
    old = slot.get(lid)
    if old is not None and us >= old["us"]:
        return
    slot[lid] = {"us": round(float(us), 2), "shape": shape,
                 "iters": int(iters)}
    _save()


def stored_best(op: str, backend: str) -> Optional[str]:
    """Lowering id with the fastest stored timing for (op, backend), or
    None when this host has never recorded one."""
    by_lid: Dict[str, dict] = _load().get(_key(backend, op), {})
    if not by_lid:
        return None
    return min(by_lid.items(), key=lambda kv: kv[1]["us"])[0]
