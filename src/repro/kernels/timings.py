"""Persistent per-op lowering timings: measured CPU auto-defaults.

PR 4 left the CPU auto-default on the `ref` oracle because per-op winners
flipped with shape and host noise across dev-host runs of
benchmarks/lowering_matrix.py.  This module is the AutoDSE-style answer
(measure, persist, then decide): `benchmarks/lowering_matrix.py --record`
persists its per-(op, lowering) timings here, and `registry.resolve()`
consults the stored winner as the per-op auto-default on backends with no
native Pallas family (CPU).  No record -> `ref` remains the fallback, so
behaviour is bit-for-bit the PR-4 default until a host has actually
measured itself.

Schema (one JSON object, merged on save like kernels/autotune.py):

    {"v1:<backend>:<op>": {"<lowering id>": {"us": float, "shape": str,
                                             "iters": int}}}

Entries keep the BEST (minimum) us per lowering id across recordings.
Cache location: $REPRO_LOWERING_TIMINGS, else
~/.cache/repro/lowering_timings.json.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Dict, Optional

CACHE_VERSION = 1

_cache: Optional[dict] = None


def cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_LOWERING_TIMINGS")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "lowering_timings.json"


def _key(backend: str, op: str) -> str:
    return f"v{CACHE_VERSION}:{backend}:{op}"


def _load() -> dict:
    global _cache
    if _cache is None:
        try:
            _cache = json.loads(cache_path().read_text())
        except (OSError, ValueError):
            _cache = {}
    return _cache


def invalidate() -> None:
    """Drop the in-process cache (re-read the file on next lookup).
    `registry.invalidate()` calls this so env-var mutation in tests picks
    up a fresh timings file."""
    global _cache
    _cache = None


def _save() -> None:
    global _cache
    path = cache_path()
    try:
        try:
            on_disk = json.loads(path.read_text())
        except (OSError, ValueError):
            on_disk = {}
        # merge-on-save, keeping the faster record on collision
        merged = dict(on_disk)
        for key, by_lid in (_cache or {}).items():
            slot = dict(merged.get(key, {}))
            for lid, ent in by_lid.items():
                old = slot.get(lid)
                if old is None or ent["us"] < old["us"]:
                    slot[lid] = ent
            merged[key] = slot
        _cache = merged
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: recording still works in-process


def record(backend: str, op: str, lid: str, us: float, *,
           shape: str = "", iters: int = 0) -> None:
    """Persist one measurement (keeps the minimum us per lowering); a
    slower-than-stored timing changes nothing and skips the rewrite."""
    cache = _load()
    slot = cache.setdefault(_key(backend, op), {})
    old = slot.get(lid)
    if old is not None and us >= old["us"]:
        return
    slot[lid] = {"us": round(float(us), 2), "shape": shape,
                 "iters": int(iters)}
    _save()


def stored_best(op: str, backend: str) -> Optional[str]:
    """Lowering id with the fastest stored timing for (op, backend), or
    None when this host has never recorded one."""
    by_lid: Dict[str, dict] = _load().get(_key(backend, op), {})
    if not by_lid:
        return None
    return min(by_lid.items(), key=lambda kv: kv[1]["us"])[0]
