"""Pure-jnp reference oracles for every packed kernel.

These define the *semantics* each packed operation must honour.  The Pallas
kernels (simd_add.py / muladd2.py / mul4.py / packed_matmul.py) are validated
against these references in interpret mode, shape/dtype-swept by the tests.

All references compute in int32 (the "exact" result); the packed kernels
compute the same values through SWAR bit manipulation inside int32 lanes.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def _i32(x):
    return x.astype(jnp.int32) if hasattr(x, "astype") else jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# SILVIAAdd: SWAR SIMD additions / subtractions
# ---------------------------------------------------------------------------

def simd_add_ref(xs: Sequence, ys: Sequence, *, sub: bool = False,
                 lane_bits: int = 8):
    """k independent lane-wise adds (or subs), each exact in its own lane.

    Semantics contract: result_i == (x_i +/- y_i) wrapped to `lane_bits`
    two's complement.  The SILVIA legality check only packs candidates whose
    results cannot exceed the lane (or whose original dtype already wraps at
    the lane width), so wrapping here matches the original program.
    """
    outs = []
    lo = -(2 ** (lane_bits - 1))
    span = 2 ** lane_bits
    for x, y in zip(xs, ys):
        r = _i32(x) - _i32(y) if sub else _i32(x) + _i32(y)
        # two's-complement wrap to lane_bits
        r = ((r - lo) % span) + lo
        outs.append(r)
    return outs


# ---------------------------------------------------------------------------
# SILVIAMuladd factor-2: two shared-operand MAD chains per unit (wp486)
# ---------------------------------------------------------------------------

def muladd2_ref(a: Sequence, b: Sequence, c: Sequence):
    """(p_a, p_b) = (sum_i a_i * c_i, sum_i b_i * c_i)  -- paper Eq. 1.

    a, b, c are length-N sequences of equally-shaped integer tensors (N is
    the chain length; legality guarantees N <= Eq.2 bound for the lane
    configuration).  Scalars broadcast.
    """
    assert len(a) == len(b) == len(c) and len(a) >= 1
    p_a = sum(_i32(ai) * _i32(ci) for ai, ci in zip(a, c))
    p_b = sum(_i32(bi) * _i32(ci) for bi, ci in zip(b, c))
    return p_a, p_b


# ---------------------------------------------------------------------------
# SILVIAMuladd factor-4: four 4-bit multiplications by one shared factor
# ---------------------------------------------------------------------------

def mul4_ref(a: Sequence, b):
    """p_i = a_i * b for i in 0..3 -- paper Eq. 3.

    a_i are 4-bit (signed or unsigned) values, b is a shared 4-bit factor.
    """
    assert len(a) == 4
    bb = _i32(b)
    return [_i32(ai) * bb for ai in a]


# ---------------------------------------------------------------------------
# Packed quantized matmuls (serving path)
# ---------------------------------------------------------------------------

def quant_matmul_ref(x_q, w_q, x_scale, w_scale, out_dtype=jnp.float32):
    """w8a8 matmul oracle: dequantized result of int8 x int8 -> int32 GEMM.

    x_q: [M, K] int8, w_q: [K, N] int8
    x_scale: [M, 1] or scalar, w_scale: [1, N] or scalar (float32)
    """
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def packed_w4_matmul_ref(x_q, w_packed, x_scale, w_scale,
                         out_dtype=jnp.float32):
    """w4a8 matmul oracle with two int4 weights packed per int8 word.

    w_packed: [K, N//2] int8 storing (w_even + 16 * w_odd) where w_even is
    biased to unsigned 4-bit (w_even_u = w_even + 8) so the word stays in
    int8 range; columns 2j / 2j+1 of the logical [K, N] int4 weight matrix.

    The oracle unpacks and performs the exact int32 GEMM.
    """
    lo_u = (w_packed.astype(jnp.int32) & 0xF)            # unsigned 4-bit + bias
    w_even = lo_u - 8                                     # de-bias -> signed
    w_odd = w_packed.astype(jnp.int32) >> 4               # arithmetic shift
    k, n_half = w_packed.shape
    w = jnp.stack([w_even, w_odd], axis=-1).reshape(k, 2 * n_half)
    acc = jnp.dot(x_q.astype(jnp.int32), w, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def pack_w4(w_int4):
    """Pack a [K, N] int4-valued (stored int8, range [-8, 7]) weight matrix
    into [K, N//2] int8 words: word = (w_even + 8) | (w_odd << 4)."""
    assert w_int4.shape[-1] % 2 == 0
    w = w_int4.astype(jnp.int32)
    w_even = w[..., 0::2] + 8          # [0, 15]
    w_odd = w[..., 1::2]               # [-8, 7]
    word = (w_odd * 16) + w_even       # in [-128, 127]
    return word.astype(jnp.int8)
