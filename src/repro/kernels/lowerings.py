"""Registration of every concrete lowering with kernels/registry.py.

One place to read the whole technology library (the paper's placeholder ->
RTL-module binding table):

    id          backend predicate   what runs
    ----------  ------------------  -------------------------------------
    tpu-pallas  backend == "tpu"    Mosaic kernels (simd_add.py, ...)
    gpu-pallas  backend == "gpu"    Triton-Pallas kernels (gpu_pallas.py)
    cpu-vector  backend == "cpu"    vectorized jnp SWAR (cpu_vector.py)
    ref         always legal        scalar-per-lane oracle (ref.py)

Priorities order native Pallas kernels above everything; on CPU the
oracle stays the auto-default (see the _CPU_VECTOR note below).  Forcing
(REPRO_LOWERING / registry.force) bypasses the predicates AND the
priorities, so every family remains runnable anywhere (Pallas via
interpret mode).

Imported lazily by registry._ensure_loaded() -- do not import this module
directly at package-import time (the kernel modules pull in autotune).
"""
from __future__ import annotations

from repro.kernels import cpu_vector, gpu_pallas, ref
from repro.kernels import mul4 as _mul4
from repro.kernels import muladd2 as _muladd2
from repro.kernels import packed_matmul as _pmm
from repro.kernels import quant_matmul as _qmm
from repro.kernels import simd_add as _simd_add
from repro.kernels.registry import NATIVE_LOWERING, register

# predicates derive from the shared backend<->family binding, so renaming
# a family or adding a backend happens in registry.NATIVE_LOWERING alone
_native = lambda lid: (lambda env: NATIVE_LOWERING.get(env.backend) == lid)
_TPU = _native("tpu-pallas")
_GPU = _native("gpu-pallas")
_CPU = _native("cpu-vector")

# cpu-vector sits BELOW ref (-10 < 0): repeated runs of
# benchmarks/lowering_matrix.py show per-op winners flipping with shape
# and host noise (cpu-vector wins some smoke shapes, loses serving-scale
# ones), so auto-selection on CPU conservatively stays on the oracle --
# identical to pre-registry behavior -- until stored per-host measurements
# justify flipping a priority.  cpu-vector remains fully reachable by
# forcing (REPRO_LOWERING / registry.force); the CI cpu-vector row runs
# the whole suite on it.  See ROADMAP "Multi-backend lowering (rest)".
_CPU_VECTOR = -10


# -- simd_add ---------------------------------------------------------------

register("simd_add", "tpu-pallas", priority=30, predicate=_TPU,
         description="Mosaic SWAR carry-kill kernel (vreg-tiled)")(
    lambda xs, ys, *, lane_bits=8, sub=False:
        _simd_add.simd_add(xs, ys, lane_bits=lane_bits, sub=sub))

register("simd_add", "gpu-pallas", priority=30, predicate=_GPU,
         description="Triton SWAR carry-kill kernel (flat row blocks)")(
    lambda xs, ys, *, lane_bits=8, sub=False:
        gpu_pallas.simd_add(xs, ys, lane_bits=lane_bits, sub=sub))

register("simd_add", "cpu-vector", priority=_CPU_VECTOR, predicate=_CPU,
         description="jnp SWAR words, one vector op per u32 word")(
    lambda xs, ys, *, lane_bits=8, sub=False:
        cpu_vector.simd_add(xs, ys, lane_bits=lane_bits, sub=sub))

register("simd_add", "ref", priority=0,
         description="scalar-per-lane oracle")(
    lambda xs, ys, *, lane_bits=8, sub=False:
        ref.simd_add_ref(xs, ys, sub=sub, lane_bits=lane_bits))


# -- muladd2 ----------------------------------------------------------------

register("muladd2", "tpu-pallas", priority=30, predicate=_TPU,
         description="Mosaic wp486 packed-operand MAD kernel")(
    _muladd2.muladd2)

register("muladd2", "gpu-pallas", priority=30, predicate=_GPU,
         description="Triton wp486 packed-operand MAD kernel")(
    gpu_pallas.muladd2)

register("muladd2", "cpu-vector", priority=_CPU_VECTOR, predicate=_CPU,
         description="jnp packed-operand MAD, one multiply per chain elem")(
    cpu_vector.muladd2)

register("muladd2", "ref", priority=0,
         description="exact int32 oracle")(
    lambda a, b, c: ref.muladd2_ref(list(a), list(b), list(c)))


# -- mul4 -------------------------------------------------------------------

register("mul4", "tpu-pallas", priority=30, predicate=_TPU,
         description="Mosaic full-32-bit-lane factor-4 kernel")(
    _mul4.mul4_full32)

register("mul4", "gpu-pallas", priority=30, predicate=_GPU,
         description="Triton full-32-bit-lane factor-4 kernel")(
    gpu_pallas.mul4)

register("mul4", "cpu-vector", priority=_CPU_VECTOR, predicate=_CPU,
         description="jnp full-lane layout, one multiply for 4 products")(
    cpu_vector.mul4)

register("mul4", "ref", priority=0,
         description="exact int32 oracle")(
    lambda a, b: ref.mul4_ref(list(a), b))


# -- quant_matmul -----------------------------------------------------------

register("quant_matmul", "tpu-pallas", priority=30, predicate=_TPU,
         description="Mosaic blocked int8 MXU GEMM (sequential K grid)")(
    lambda x_q, w_q, x_s, w_s, *, out_dtype:
        _qmm.quant_matmul(x_q, w_q, x_s, w_s, out_dtype=out_dtype))

register("quant_matmul", "gpu-pallas", priority=30, predicate=_GPU,
         description="Triton int8 GEMM (parallel MN grid, in-kernel K)")(
    lambda x_q, w_q, x_s, w_s, *, out_dtype:
        gpu_pallas.quant_matmul(x_q, w_q, x_s, w_s, out_dtype=out_dtype))

register("quant_matmul", "cpu-vector", priority=_CPU_VECTOR, predicate=_CPU,
         description="narrow-dtype dot_general GEMM")(
    lambda x_q, w_q, x_s, w_s, *, out_dtype:
        cpu_vector.quant_matmul(x_q, w_q, x_s, w_s, out_dtype=out_dtype))

register("quant_matmul", "ref", priority=0,
         description="int32-widened GEMM oracle")(
    lambda x_q, w_q, x_s, w_s, *, out_dtype:
        ref.quant_matmul_ref(x_q, w_q, x_s, w_s, out_dtype))


# -- packed_w4_matmul -------------------------------------------------------

register("packed_w4_matmul", "tpu-pallas", priority=30, predicate=_TPU,
         description="Mosaic w4a8 GEMM, nibble unpack in VMEM")(
    lambda x_q, w_p, x_s, w_s, *, out_dtype:
        _pmm.packed_w4_matmul(x_q, w_p, x_s, w_s, out_dtype=out_dtype))

register("packed_w4_matmul", "gpu-pallas", priority=30, predicate=_GPU,
         description="Triton w4a8 GEMM, nibble unpack in the kernel")(
    lambda x_q, w_p, x_s, w_s, *, out_dtype:
        gpu_pallas.packed_w4_matmul(x_q, w_p, x_s, w_s,
                                    out_dtype=out_dtype))

register("packed_w4_matmul", "cpu-vector", priority=_CPU_VECTOR,
         predicate=_CPU,
         description="vectorized nibble unpack + narrow-dtype GEMM")(
    lambda x_q, w_p, x_s, w_s, *, out_dtype:
        cpu_vector.packed_w4_matmul(x_q, w_p, x_s, w_s,
                                    out_dtype=out_dtype))

register("packed_w4_matmul", "ref", priority=0,
         description="unpack-to-int32 GEMM oracle")(
    lambda x_q, w_p, x_s, w_s, *, out_dtype:
        ref.packed_w4_matmul_ref(x_q, w_p, x_s, w_s, out_dtype))
