"""Packed-arithmetic kernels -- the "custom RTL modules" of the SILVIA flow
(paper sec. 3.3/3.4), adapted to each backend's memory/compute hierarchy.

registry        lowering registry: per-op, per-backend capability-gated
                lowerings (the paper's placeholder -> technology binding)
lowerings       the binding table itself (registers everything below)
simd_add        SWAR four8/two16 add/sub         (paper sec. 2.1, SILVIAAdd)
muladd2         factor-2 shared-operand MAD      (paper sec. 2.2, wp486)
mul4            factor-4 4-bit multiplications   (paper sec. 2.3, incl. the
                                                  paper's novel unsigned form)
quant_matmul    w8a8 MXU GEMM                    (serving baseline)
packed_matmul   w4a8 packed-weight MXU GEMM      (the packing insight applied
                                                  to the HBM-bound fast path)
gpu_pallas      Triton-Pallas variants of the SWAR + matmul kernels
cpu_vector      vectorized jnp lowerings (SWAR at jnp level; forced via
                                          REPRO_LOWERING, CI-exercised)
ref             scalar-per-lane oracles for all of the above (always-legal
                fallback lowering)
autotune        block-size search + on-disk cache, keyed by lowering id
ops             thin compatibility wrappers over registry.dispatch
"""
from repro.kernels import (autotune, common, cpu_vector, gpu_pallas, mul4,
                           muladd2, ops, packed_matmul, quant_matmul, ref,
                           registry, simd_add)

__all__ = ["autotune", "common", "cpu_vector", "gpu_pallas", "mul4",
           "muladd2", "ops", "packed_matmul", "quant_matmul", "ref",
           "registry", "simd_add"]
