"""Packed-arithmetic Pallas TPU kernels -- the "custom RTL modules" of the
SILVIA flow (paper sec. 3.3/3.4), adapted to the TPU memory/compute hierarchy.

simd_add       SWAR four8/two16 add/sub        (paper sec. 2.1, SILVIAAdd)
autotune       block-size search + on-disk cache for the matmul kernels
muladd2        factor-2 shared-operand MAD      (paper sec. 2.2, wp486)
mul4           factor-4 4-bit multiplications   (paper sec. 2.3, incl. the
                                                 paper's novel unsigned form)
quant_matmul   w8a8 MXU GEMM                    (serving baseline)
packed_matmul  w4a8 packed-weight MXU GEMM      (the packing insight applied
                                                 to the HBM-bound fast path)
ref            pure-jnp oracles for all of the above
ops            backend dispatch (Pallas on TPU / oracle on CPU)
"""
from repro.kernels import (autotune, common, mul4, muladd2, ops,
                           packed_matmul, quant_matmul, ref, simd_add)

__all__ = ["autotune", "common", "mul4", "muladd2", "ops", "packed_matmul",
           "quant_matmul", "ref", "simd_add"]
