"""w4a8 packed-weight matmul Pallas kernel -- the paper's DSP packing idea
applied to the TPU serving fast path.

The FPGA DSP packs two narrow multiplies per slice because the wide
multiplier port has headroom bits.  The MXU's int8 port has none, so the
TPU-native translation targets the *memory system* instead: two int4
weights live in each int8 HBM word (kernels/ref.pack_w4 layout:
word = (w_even + 8) | (w_odd << 4)), HALVING weight bytes -- the dominant
roofline term of decode serving.  The kernel unpacks words to int8 lanes in
VMEM with 3 cheap VPU ops and feeds the MXU at full int8 throughput.

So: same insight (pack narrow operands into the wide container the hardware
actually provisions), different scarce resource (HBM bandwidth vs DSP
slices) -- see DESIGN.md sec. 2.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


def _pmm_kernel(x_ref, wp_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = common.unpack_w4_words(wp_ref[...])
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.int32)


def packed_w4_matmul_acc(x_q, w_packed, *, block=None,
                         interpret: bool | None = None):
    """int8[M,K] @ packed-int4[K,N] (stored int8[K,N//2]) -> int32[M,N].

    block=None resolves through kernels/autotune.py: persisted best block
    for this (M,K,N) if one exists, else the static default."""
    interpret = common.interpret_default() if interpret is None else interpret
    m, k = x_q.shape
    k2, n_half = w_packed.shape
    assert k == k2
    n = 2 * n_half
    if block is None:
        block = autotune.resolve("packed_w4_matmul", m, k, n,
                                 lowering="tpu-pallas", interpret=interpret)
    bm = min(block[0], max(8, m))
    bn = min(block[1], max(256, n))
    bn -= bn % 2
    bk = min(block[2], max(128, k))
    mp, np_, kp = (common.cdiv(m, bm) * bm, common.cdiv(n, bn) * bn,
                   common.cdiv(k, bk) * bk)
    # NOTE: padded packed words must encode w=0, i.e. byte 0x08 (low nibble
    # biased by +8) -- a zero byte would decode to w_even = -8.
    x_p = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    w_p = jnp.pad(w_packed, ((0, kp - k), (0, np_ // 2 - n_half)),
                  constant_values=0x08)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _pmm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x_p, w_p)
    return out[:m, :n]


def packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                     out_dtype=jnp.float32, block=None,
                     interpret: bool | None = None):
    acc = packed_w4_matmul_acc(x_q, w_packed, block=block,
                               interpret=interpret)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
