"""SWAR SIMD add/sub Pallas kernel -- SILVIAAdd's packed unit.

Paper (sec. 2.1): the DSP48E2 ALU adds four 12-bit or two 24-bit pairs per
slice.  TPU adaptation: one int32 VPU op adds four 8-bit or two 16-bit lanes
per word using classic carry-kill SWAR:

    add: s = ((x & ~H) + (y & ~H)) ^ ((x ^ y) & H)
    sub: s = ((x | H) - (y & ~H)) ^ ((x ^ ~y) & H)

where H holds each lane's MSB.  The kernel operates on pre-packed u32 words
(pack/unpack helpers live in common.py; weights/biases pack offline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


def _swar_kernel(x_ref, y_ref, o_ref, *, lane_bits: int, sub: bool):
    o_ref[...] = common.swar_add_sub(x_ref[...], y_ref[...], lane_bits,
                                     sub=sub)


def simd_add_packed(x_packed, y_packed, *, lane_bits: int = 8,
                    sub: bool = False, block=None,
                    interpret: bool | None = None):
    """Lane-wise add/sub on SWAR-packed u32 words: the packed fast path.

    x_packed, y_packed: uint32 tensors of identical shape (each word holds
    32//lane_bits logical operands).  One VPU op per word -> 4x (8-bit) or
    2x (16-bit) op-density, the paper's four12/two24 rescaled to 32 bits.

    block=None resolves through kernels/autotune.py (persisted winner for
    this padded 2-D layout, else the static default)."""
    assert x_packed.dtype == jnp.uint32 and y_packed.dtype == jnp.uint32
    interpret = common.interpret_default() if interpret is None else interpret
    x2, shape, n = common.pad_to_2d(x_packed, common.TILE_32)
    y2, _, _ = common.pad_to_2d(y_packed, common.TILE_32)
    rows, cols = x2.shape
    if block is None:
        block = autotune.resolve("simd_add", rows, cols,
                                 lowering="tpu-pallas", interpret=interpret)
    bm = min(block[0], rows)
    bn = min(block[1], cols)
    # round block to tile multiples
    bm = max(common.TILE_32[0], bm - bm % common.TILE_32[0])
    bn = max(common.TILE_32[1], bn - bn % common.TILE_32[1])
    rows_p, cols_p = common.cdiv(rows, bm) * bm, common.cdiv(cols, bn) * bn
    x2 = jnp.pad(x2, ((0, rows_p - rows), (0, cols_p - cols)))
    y2 = jnp.pad(y2, ((0, rows_p - rows), (0, cols_p - cols)))
    rows, cols = rows_p, cols_p
    grid = (rows // bm, cols // bn)
    out = pl.pallas_call(
        functools.partial(_swar_kernel, lane_bits=lane_bits, sub=sub),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x2, y2)
    return common.unpad_from_2d(out, shape, n)


def simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False,
             interpret: bool | None = None):
    """Unpacked-operand entry point: packs k narrow tensors into SWAR words
    (common.simd_add_lanes -- shorter tuples pad with zero lanes, a
    partially-filled DSP, paper sec. 3.2), runs the packed kernel,
    unpacks."""
    return common.simd_add_lanes(
        lambda xw, yw: simd_add_packed(xw, yw, lane_bits=lane_bits,
                                       sub=sub, interpret=interpret),
        xs, ys, lane_bits)
