"""Vectorized pure-jnp lowerings (the "cpu-vector" registry family).

The `ref` oracle (kernels/ref.py) is deliberately scalar-per-lane: a Python
loop emits one jnp op per logical lane, which is the clearest statement of
the semantics but leaves k-way SWAR parallelism on the table.  These
lowerings compute the SAME bit-exact results through the packed-word
arithmetic the Pallas kernels use -- one vector op per u32 word / one packed
multiply per chain element -- but stay at the jnp level, so XLA:CPU
vectorizes them without any Pallas machinery.  Micro-benchmarks
(benchmarks/lowering_matrix.py) show per-op winners vs the oracle flipping
with shape and host, so auto-selection on CPU conservatively stays on ref
(kernels/lowerings.py); this family is reached by forcing
(REPRO_LOWERING='*=cpu-vector'), which the CI cpu-vector row does
suite-wide.

Exactness mirrors the kernel contracts:

* simd_add: the carry-kill SWAR identity equals two's-complement lane wrap
  for ALL inputs (no legality assumption needed).
* muladd2: exact while |p_b| < 2^15 (the Eq. 2 chain bound the SILVIA
  legality check enforces -- identical contract to the Pallas kernel).
* mul4: exact for 4-bit operands (|w| * |b| < 2^31, see kernels/mul4.py).
* matmuls: integer GEMMs are exact; scaling applies in the same float32
  op order as the oracle, so results are bitwise equal, not just close.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.kernels import common


def simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False):
    """k lane-wise adds/subs via SWAR words at the jnp level: pack the k
    narrow tensors into uint32 words, one carry-kill vector op per word,
    unpack.  Bit-exact vs ref.simd_add_ref (wrap == wrap)."""
    return common.simd_add_lanes(
        lambda xw, yw: common.swar_add_sub(xw, yw, lane_bits, sub=sub),
        xs, ys, lane_bits)


def muladd2(a, b, c):
    """a, b, c: stacked (n, ...) int8.  The wp486 packed-operand trick
    vectorized over the whole chain (common.madd2_reduce): ONE multiply
    per chain element."""
    return common.madd2_reduce(a.astype(jnp.int32), b.astype(jnp.int32),
                               c.astype(jnp.int32))


def mul4(a, b):
    """a: stacked (4, ...) int8 4-bit values; b: (...) int8 4-bit factor.
    The full-32-bit-lane layout of kernels/mul4.py vectorized in jnp
    (common.mul4_reduce): one multiply for four products."""
    return common.mul4_reduce(a.astype(jnp.int32), b.astype(jnp.int32))


def quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32):
    """w8a8 GEMM straight on the int8 operands (the oracle widens to int32
    first): XLA:CPU keeps the narrow dtype through its vectorized GEMM.
    Scaling matches the oracle's float32 op order bit-for-bit."""
    acc = lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                     out_dtype=jnp.float32):
    """w4a8 GEMM with vectorized nibble unpack to int8 (not int32 like the
    oracle) feeding the narrow-dtype GEMM."""
    w = common.unpack_w4_words(w_packed)
    acc = lax.dot_general(x_q, w, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
