"""Hardened persistent JSON caches (autotune blocks, lowering timings).

The tuning caches are *derived* data -- every entry can be recomputed by
re-timing -- so the one unforgivable failure mode is a cache file that
CRASHES an engine.  This module wraps the on-disk format in a defensive
envelope so the consumers (`kernels/autotune.py`, `kernels/timings.py`)
can treat any damaged file as simply empty:

    {"schema": <int>, "checksum": "sha256:<hex of canonical entries>",
     "entries": {...}}

* `load` returns the entries dict, or `{}` with a `warnings.warn` for
  every way a file can be wrong: unreadable, truncated/corrupt JSON,
  not-a-dict, missing/foreign schema version (legacy pre-envelope flat
  files land here too), or a checksum that doesn't match the entries
  (partial write, manual edit, bit rot).  It never raises.
* `store` writes atomically: serialize to a tempfile in the target
  directory, fsync, `os.replace` -- a reader sees the old complete file
  or the new complete file, never a prefix.
* `locked` serializes read-merge-write cycles between engines on one
  host with an `fcntl` lock on a `.lock` sidecar (the data file itself
  is replaced atomically, so locking it would lock a dead inode).  On
  platforms/filesystems without flock it degrades to unlocked -- the
  atomic replace still prevents torn files, concurrent writers can then
  only lose each other's merges, not corrupt them.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import pathlib
import tempfile
import warnings


def checksum(entries: dict) -> str:
    canon = json.dumps(entries, sort_keys=True, separators=(",", ":"))
    return "sha256:" + hashlib.sha256(canon.encode()).hexdigest()


def _warn(path: pathlib.Path, why: str) -> None:
    warnings.warn(f"ignoring cache file {path}: {why} (entries will be "
                  f"recomputed)", stacklevel=3)


def load(path: pathlib.Path, schema: int) -> dict:
    """Entries from `path`, or {} (with a warning) for anything damaged.
    A missing file is the normal cold-start case and stays silent."""
    try:
        text = path.read_text()
    except FileNotFoundError:
        return {}
    except OSError as e:
        _warn(path, f"unreadable ({e})")
        return {}
    try:
        doc = json.loads(text)
    except ValueError as e:
        _warn(path, f"corrupt JSON ({e})")
        return {}
    if not isinstance(doc, dict):
        _warn(path, f"expected a JSON object, got {type(doc).__name__}")
        return {}
    if doc.get("schema") != schema:
        _warn(path, f"schema {doc.get('schema')!r} != expected {schema} "
                    "(foreign version or legacy flat format)")
        return {}
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        _warn(path, "missing entries object")
        return {}
    if doc.get("checksum") != checksum(entries):
        _warn(path, "checksum mismatch (truncated or edited)")
        return {}
    return entries


def store(path: pathlib.Path, schema: int, entries: dict) -> bool:
    """Atomic tmp+fsync+rename write of the envelope; False (never an
    exception) on unwritable filesystems -- callers keep their in-process
    cache either way."""
    doc = {"schema": schema, "checksum": checksum(entries),
           "entries": entries}
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise
        return True
    except OSError:
        return False


@contextlib.contextmanager
def locked(path: pathlib.Path):
    """Exclusive advisory lock for a read-merge-write cycle on `path`
    (taken on a `.lock` sidecar; see module docstring).  Best-effort:
    yields unlocked when flock is unavailable."""
    lock_path = pathlib.Path(str(path) + ".lock")
    f = None
    try:
        try:
            import fcntl
            lock_path.parent.mkdir(parents=True, exist_ok=True)
            f = open(lock_path, "a+")
            fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        except (ImportError, OSError):
            if f is not None:
                f.close()
                f = None
        yield
    finally:
        if f is not None:
            try:
                import fcntl
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            except (ImportError, OSError):
                pass
            f.close()
