"""Factor-4 4-bit multiplication Pallas kernels (paper sec. 2.3).

Two variants:

* `mul4_split` -- the paper-faithful port of Fig. 3 / Eq. 4 (including the
  paper's NOVEL unsigned-operand mechanism): three 4-bit lanes plus the 3
  MSBs of a3 go through the wide multiply; the final product is patched with
      p3 = (a3[3:1] * b) * 2 + (a3 & 1) * b
  where the patch ops are cheap VPU and/ shift/ add (the paper's "small
  amount of LUTs").  This mirrors the 27-bit port constraint of the DSP.

* `mul4_full32` -- the TPU-native variant: an i32 lane has 32 > 27 operand
  bits, so all four 4-bit operands fit at offsets 0/8/16/24 without the
  split; the products are recovered by sequential lane extraction.  This is
  a beyond-paper improvement enabled by the wider unit (recorded in
  DESIGN.md / EXPERIMENTS.md).

Both compute p_i = a_i * b exactly for signed or unsigned 4-bit a_i and
4-bit b, via exact integer arithmetic:
P = (sum_i a_i * 2^(8i)) * b, |a_i * b| < 2^7 guarantees lossless recovery.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


_extract_lane = common.extract_lane8   # shared identity (common.py)


def _mul4_full32_kernel(a_ref, b_ref, p_ref, *, signed: bool):
    # Unsigned x unsigned products reach 225 * 2^24 > 2^31 in the top lane:
    # the same port-width pressure that forces the paper's Fig. 3 split on
    # the 27-bit DSP.  With a full 32-bit lane we instead compute modulo
    # 2^32 (uint32), which is exact since the true value < 2^32.
    dt = jnp.int32 if signed else jnp.uint32
    a = a_ref[...].astype(jnp.int32).astype(dt)   # (4, bm, bn)
    b = b_ref[...].astype(jnp.int32).astype(dt)   # (bm, bn)
    w = a[0] + (a[1] << 8) + (a[2] << 16) + (a[3] << 24)
    p = w * b                              # ONE multiply for 4 products
    p0, r = _extract_lane(p, signed)
    p1, r = _extract_lane(r, signed)
    p2, r = _extract_lane(r, signed)
    p3 = r                                 # top lane: remaining bits
    p_ref[...] = jnp.stack([p0, p1, p2, p3]).astype(jnp.int32)


def _mul4_split_kernel(a_ref, b_ref, p_ref, *, signed: bool):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    a3 = a[3]
    a3_hi = a3 >> 1                        # a3[3:1] (arithmetic: sign kept)
    a3_lo = a3 & 1                         # a3[0]
    # 27-bit port layout (paper Fig. 3a): 3 full lanes + 3-bit top lane
    w = a[0] + (a[1] << 8) + (a[2] << 16) + (a3_hi << 24)
    p = w * b
    p0, r = _extract_lane(p, signed)
    p1, r = _extract_lane(r, signed)
    p2, r = _extract_lane(r, signed)
    p3_hi = r
    # Eq. 4: p3 = (a3[3:1] * b) * 2 + a3[0] * b ; the multiply by a single
    # bit is an AND-like select (paper: "hardware friendly").
    p3 = (p3_hi << 1) + jnp.where(a3_lo != 0, b, 0)
    p_ref[...] = jnp.stack([p0, p1, p2, p3])


def _run(kernel, a, b, block, interpret, signed=True, kind="mul4"):
    kernel = functools.partial(kernel, signed=signed)
    interpret = common.interpret_default() if interpret is None else interpret
    assert a.shape[0] == 4 and a.shape[1:] == b.shape
    inner = b.shape
    b2, shape, cnt = common.pad_to_2d(b, common.TILE_8)
    rows, cols = b2.shape
    if block is None:
        block = autotune.resolve(kind, rows, cols,
                                 lowering="tpu-pallas", interpret=interpret)
    bm = max(common.TILE_8[0], min(block[0], rows) // common.TILE_8[0] * common.TILE_8[0])
    bn = max(common.TILE_8[1], min(block[1], cols) // common.TILE_8[1] * common.TILE_8[1])
    rows = common.cdiv(rows, bm) * bm
    cols = common.cdiv(cols, bn) * bn
    b2 = jnp.pad(b2, ((0, rows - b2.shape[0]), (0, cols - b2.shape[1])))
    flat = a.reshape(4, -1)
    a2 = jnp.pad(flat, ((0, 0), (0, rows * cols - flat.shape[1]))).reshape(
        4, rows, cols)
    grid = (rows // bm, cols // bn)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((4, rows, cols), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((4, bm, bn), lambda i, j: (0, i, j)),
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((4, bm, bn), lambda i, j: (0, i, j)),
        interpret=interpret,
    )(a2, b2)
    return [common.unpad_from_2d(out[i], inner, cnt) for i in range(4)]


def mul4_full32(a, b, *, block=None, interpret: bool | None = None,
                signed: bool = True):
    """a: (4, ...) 4-bit-valued int8; b: (...) 4-bit-valued int8.
    Returns [p0..p3] int32.  TPU-native full 32-bit lane layout.
    `signed=False` only when ALL products are provably non-negative.
    block=None resolves through kernels/autotune.py."""
    return _run(_mul4_full32_kernel, a, b, block, interpret, signed)


def mul4_split(a, b, *, block=None, interpret: bool | None = None,
               signed: bool = True):
    """Paper-faithful Fig. 3 / Eq. 4 variant (27-bit port + correction).
    block=None resolves through its own "mul4_split" autotune kind (the
    split layout has a different cost profile than full32)."""
    return _run(_mul4_split_kernel, a, b, block, interpret, signed,
                kind="mul4_split")
