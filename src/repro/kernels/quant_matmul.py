"""w8a8 quantized matmul Pallas kernel (serving baseline path).

Classic blocked GEMM: grid (M/bm, N/bn, K/bk) with K innermost (sequential);
int8 blocks feed the MXU (int8 x int8 -> int32 is the TPU's native
high-throughput mode, 2x bf16 peak on v5e); int32 accumulation happens in
the output block across K steps; scales apply outside the kernel.

Block defaults keep the working set comfortably inside ~16 MiB VMEM:
bm=256, bn=256, bk=512 -> x 128 KiB + w 128 KiB + acc 256 KiB.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


def _qmm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.int32)


def quant_matmul_acc(x_q, w_q, *, block=None,
                     interpret: bool | None = None):
    """int8[M,K] @ int8[K,N] -> int32[M,N] accumulator.

    block=None resolves through kernels/autotune.py: persisted best block
    for this (M,K,N) if one exists, else the static default."""
    interpret = common.interpret_default() if interpret is None else interpret
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    if block is None:
        block = autotune.resolve("quant_matmul", m, k, n,
                                 lowering="tpu-pallas", interpret=interpret)
    bm = min(block[0], max(8, m))
    bn = min(block[1], max(128, n))
    bk = min(block[2], max(128, k))
    # zero-pad to block multiples (exact for GEMM); slice the result back
    mp, np_, kp = (common.cdiv(m, bm) * bm, common.cdiv(n, bn) * bn,
                   common.cdiv(k, bk) * bk)
    x_p = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    w_p = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x_p, w_p)
    return out[:m, :n]


def quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32,
                 block=None, interpret: bool | None = None):
    acc = quant_matmul_acc(x_q, w_q, block=block, interpret=interpret)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
