"""Jit'd dispatch wrappers over the packed kernels.

This is the paper's sec. 3.3/3.4 "placeholder function -> custom RTL module"
replacement step: the SILVIA packed primitives evaluate through these
wrappers, which pick the Pallas TPU kernel on TPU backends and the exact
pure-jnp reference elsewhere (CPU tests exercise the kernels explicitly in
interpret mode).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import mul4 as _mul4
from repro.kernels import muladd2 as _muladd2
from repro.kernels import packed_matmul as _pmm
from repro.kernels import quant_matmul as _qmm
from repro.kernels import ref
from repro.kernels import simd_add as _simd_add


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "tpu"


def set_autotune(on: bool = True) -> None:
    """Enable block-size autotuning for the Pallas kernels -- the matmuls
    and the SWAR units (see kernels/autotune.py; results persist in an
    on-disk cache)."""
    autotune.enable(on)


def simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False):
    if _use_pallas():
        shape = jnp.broadcast_shapes(*[x.shape for x in (*xs, *ys)])
        dt = jnp.int8 if lane_bits == 8 else jnp.int16
        n8 = [jnp.broadcast_to(x, shape).astype(dt) for x in xs]
        m8 = [jnp.broadcast_to(y, shape).astype(dt) for y in ys]
        return _simd_add.simd_add(n8, m8, lane_bits=lane_bits, sub=sub)
    return ref.simd_add_ref(xs, ys, sub=sub, lane_bits=lane_bits)


def muladd2(a, b, c):
    """Chain MAD: sequences a/b/c of tensors -> (p_a, p_b) int32."""
    if _use_pallas():
        shape = jnp.broadcast_shapes(*[x.shape for x in (*a, *b, *c)])
        st = lambda seq: jnp.stack([jnp.broadcast_to(x, shape).astype(jnp.int8)
                                    for x in seq])
        return _muladd2.muladd2(st(a), st(b), st(c))
    return ref.muladd2_ref(a, b, c)


def mul4(a, b):
    if _use_pallas():
        shape = jnp.broadcast_shapes(*[x.shape for x in a], b.shape)
        a4 = jnp.stack([jnp.broadcast_to(x, shape).astype(jnp.int8) for x in a])
        return _mul4.mul4_full32(a4, jnp.broadcast_to(b, shape).astype(jnp.int8))
    return ref.mul4_ref(a, b)


def quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32):
    if _use_pallas():
        return _qmm.quant_matmul(x_q, w_q, x_scale, w_scale,
                                 out_dtype=out_dtype)
    return ref.quant_matmul_ref(x_q, w_q, x_scale, w_scale, out_dtype)


def packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                     out_dtype=jnp.float32):
    if _use_pallas():
        return _pmm.packed_w4_matmul(x_q, w_packed, x_scale, w_scale,
                                     out_dtype=out_dtype)
    return ref.packed_w4_matmul_ref(x_q, w_packed, x_scale, w_scale,
                                    out_dtype)
