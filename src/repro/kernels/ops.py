"""Compatibility wrappers over the lowering registry (kernels/registry.py).

The boolean Pallas-or-oracle switch that used to live here (`_use_pallas()`)
is gone: every packed op now resolves through the registry's named,
capability-gated, per-backend lowerings (`tpu-pallas` / `gpu-pallas` /
`cpu-vector` / `ref`), with `REPRO_LOWERING` / `registry.force()` overrides
and cached resolution.  New call sites should use `registry.dispatch()`
directly; these wrappers keep the historical `kernels.ops` API working.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import autotune, registry


def set_autotune(on: bool = True) -> None:
    """Enable block-size autotuning for the Pallas kernels -- the matmuls
    and the SWAR units (see kernels/autotune.py; results persist in an
    on-disk cache keyed by lowering id + mode)."""
    autotune.enable(on)


def simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False):
    return registry.dispatch("simd_add", xs, ys, lane_bits=lane_bits,
                             sub=sub)


def muladd2(a, b, c):
    """Chain MAD: sequences a/b/c of tensors -> (p_a, p_b) int32."""
    return registry.dispatch("muladd2", a, b, c)


def mul4(a, b):
    return registry.dispatch("mul4", a, b)


def quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32):
    return registry.dispatch("quant_matmul", x_q, w_q, x_scale, w_scale,
                             out_dtype=out_dtype)


def packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                     out_dtype=jnp.float32):
    return registry.dispatch("packed_w4_matmul", x_q, w_packed, x_scale,
                             w_scale, out_dtype=out_dtype)
