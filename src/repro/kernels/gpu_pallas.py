"""Triton-Pallas (GPU) lowerings of the SWAR + matmul kernels.

Same packed arithmetic as the Mosaic TPU kernels (simd_add.py / muladd2.py /
mul4.py / *_matmul.py), restructured for the GPU lowering path:

* **parallel grid axes**: on TPU the grid is sequential, so the GEMMs
  accumulate into the output block across a K grid axis.  Triton program
  instances run concurrently -- accumulating across a grid axis is a race --
  so the GEMMs here keep the full K stripe inside the kernel body and use a
  2-D (M, N) grid only.
* **no TPU tile constraint**: blocks are plain powers of two, not (8, 128) /
  (32, 128) vreg-tile multiples; elementwise kernels run on a flat
  (rows, 128) layout with only the row block tunable.
* block=None resolves through kernels/autotune.py under the "gpu-pallas"
  lowering id (its timings never collide with TPU or interpret entries --
  the v2 cache key includes lowering id and mode).

On non-GPU hosts the kernels run in Pallas interpret mode, which is how the
parity matrix (tests/test_lowering_matrix.py) validates them on CPU; the
capability predicate in kernels/lowerings.py keeps *auto*-selection
GPU-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune, common


def interpret_default() -> bool:
    """Interpret everywhere but this family's native backend."""
    return common.interpret_default_for("gpu-pallas")


_COLS = 128   # fixed column width of the flattened elementwise layout


def _pad_rows(x2, bm):
    rows, cols = x2.shape
    rows_p = common.cdiv(rows, bm) * bm
    return jnp.pad(x2, ((0, rows_p - rows), (0, 0)))


# ---------------------------------------------------------------------------
# simd_add: SWAR carry-kill add/sub on u32 words
# ---------------------------------------------------------------------------

def _swar_kernel(x_ref, y_ref, o_ref, *, lane_bits: int, sub: bool):
    o_ref[...] = common.swar_add_sub(x_ref[...], y_ref[...], lane_bits,
                                     sub=sub)


def simd_add_packed(x_packed, y_packed, *, lane_bits: int = 8,
                    sub: bool = False, block=None,
                    interpret: bool | None = None):
    assert x_packed.dtype == jnp.uint32 and y_packed.dtype == jnp.uint32
    interpret = interpret_default() if interpret is None else interpret
    x2, shape, n = common.pad_to_2d(x_packed, (1, _COLS))
    y2, _, _ = common.pad_to_2d(y_packed, (1, _COLS))
    rows, cols = x2.shape
    if block is None:
        block = autotune.resolve("simd_add", rows, cols,
                                 lowering="gpu-pallas", interpret=interpret)
    bm = min(block[0], rows)
    x2, y2 = _pad_rows(x2, bm), _pad_rows(y2, bm)
    grid = (x2.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_swar_kernel, lane_bits=lane_bits, sub=sub),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, cols), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((bm, cols), lambda i: (i, 0)),
        interpret=interpret,
    )(x2, y2)
    return common.unpad_from_2d(out, shape, n)


def simd_add(xs, ys, *, lane_bits: int = 8, sub: bool = False,
             interpret: bool | None = None):
    """Canonical-operand entry point (k broadcast lane-dtype tensors)."""
    return common.simd_add_lanes(
        lambda xw, yw: simd_add_packed(xw, yw, lane_bits=lane_bits,
                                       sub=sub, interpret=interpret),
        xs, ys, lane_bits)


# ---------------------------------------------------------------------------
# muladd2: factor-2 shared-operand MAD chains
# ---------------------------------------------------------------------------

def _muladd2_kernel(a_ref, b_ref, c_ref, pa_ref, pb_ref):
    p_a, p_b = common.madd2_reduce(a_ref[...].astype(jnp.int32),
                                   b_ref[...].astype(jnp.int32),
                                   c_ref[...].astype(jnp.int32))
    pa_ref[...] = p_a
    pb_ref[...] = p_b


def muladd2(a, b, c, *, block=None, interpret: bool | None = None):
    """a, b, c: stacked (n, ...) int8 -> (p_a, p_b) int32 of shape (...)."""
    interpret = interpret_default() if interpret is None else interpret
    assert a.shape == b.shape == c.shape and a.ndim >= 1
    n = a.shape[0]
    inner = a.shape[1:]
    a2, shape, cnt = common.pad_to_2d(a.reshape(n, -1)[0], (1, _COLS))
    rows, cols = a2.shape
    if block is None:
        block = autotune.resolve("muladd2", n, rows, cols,
                                 lowering="gpu-pallas", interpret=interpret)
    bm = min(block[0], rows)
    rows_p = common.cdiv(rows, bm) * bm

    def prep(x):
        flat = x.reshape(n, -1)
        return jnp.pad(flat, ((0, 0), (0, rows_p * cols - flat.shape[1]))) \
            .reshape(n, rows_p, cols)

    spec_in = pl.BlockSpec((n, bm, cols), lambda i: (0, i, 0))
    spec_out = pl.BlockSpec((bm, cols), lambda i: (i, 0))
    p_a, p_b = pl.pallas_call(
        _muladd2_kernel,
        out_shape=[jax.ShapeDtypeStruct((rows_p, cols), jnp.int32)] * 2,
        grid=(rows_p // bm,),
        in_specs=[spec_in, spec_in, spec_in],
        out_specs=[spec_out, spec_out],
        interpret=interpret,
    )(prep(a), prep(b), prep(c))
    return (common.unpad_from_2d(p_a, inner, cnt),
            common.unpad_from_2d(p_b, inner, cnt))


# ---------------------------------------------------------------------------
# mul4: factor-4 4-bit multiplications (full-32-bit-lane layout)
# ---------------------------------------------------------------------------

def _mul4_kernel(a_ref, b_ref, p_ref):
    p_ref[...] = jnp.stack(common.mul4_reduce(
        a_ref[...].astype(jnp.int32), b_ref[...].astype(jnp.int32)))


def mul4(a, b, *, block=None, interpret: bool | None = None):
    """a: stacked (4, ...) int8; b: (...) int8 -> [p0..p3] int32."""
    interpret = interpret_default() if interpret is None else interpret
    assert a.shape[0] == 4 and a.shape[1:] == b.shape
    inner = b.shape
    b2, shape, cnt = common.pad_to_2d(b, (1, _COLS))
    rows, cols = b2.shape
    if block is None:
        block = autotune.resolve("mul4", rows, cols,
                                 lowering="gpu-pallas", interpret=interpret)
    bm = min(block[0], rows)
    rows_p = common.cdiv(rows, bm) * bm
    b2 = _pad_rows(b2, bm)
    flat = a.reshape(4, -1)
    a2 = jnp.pad(flat, ((0, 0), (0, rows_p * cols - flat.shape[1]))) \
        .reshape(4, rows_p, cols)
    out = pl.pallas_call(
        _mul4_kernel,
        out_shape=jax.ShapeDtypeStruct((4, rows_p, cols), jnp.int32),
        grid=(rows_p // bm,),
        in_specs=[pl.BlockSpec((4, bm, cols), lambda i: (0, i, 0)),
                  pl.BlockSpec((bm, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4, bm, cols), lambda i: (0, i, 0)),
        interpret=interpret,
    )(a2, b2)
    return [common.unpad_from_2d(out[i], inner, cnt) for i in range(4)]


# ---------------------------------------------------------------------------
# quantized GEMMs: 2-D parallel grid, K inside the kernel body
# ---------------------------------------------------------------------------

def _qmm_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.int32)


def quant_matmul_acc(x_q, w_q, *, block=None, interpret: bool | None = None):
    """int8[M,K] @ int8[K,N] -> int32[M,N]; (bm, bn) output tiles over a
    parallel grid, full-K stripes per instance (block[2] is accepted for
    autotune-candidate compatibility but unused)."""
    interpret = interpret_default() if interpret is None else interpret
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    if block is None:
        block = autotune.resolve("quant_matmul", m, k, n,
                                 lowering="gpu-pallas", interpret=interpret)
    bm = min(block[0], max(16, m))
    bn = min(block[1], max(16, n))
    mp, np_ = common.cdiv(m, bm) * bm, common.cdiv(n, bn) * bn
    x_p = jnp.pad(x_q, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w_q, ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x_p, w_p)
    return out[:m, :n]


def quant_matmul(x_q, w_q, x_scale, w_scale, *, out_dtype=jnp.float32,
                 block=None, interpret: bool | None = None):
    acc = quant_matmul_acc(x_q, w_q, block=block, interpret=interpret)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


def _pmm_kernel(x_ref, wp_ref, o_ref):
    w = common.unpack_w4_words(wp_ref[...])
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.int32)


def packed_w4_matmul_acc(x_q, w_packed, *, block=None,
                         interpret: bool | None = None):
    """int8[M,K] @ packed-int4[K,N] (stored int8[K,N//2]) -> int32[M,N],
    nibble unpack inside the kernel (see kernels/packed_matmul.py for the
    0x08 zero-word encoding of padding)."""
    interpret = interpret_default() if interpret is None else interpret
    m, k = x_q.shape
    k2, n_half = w_packed.shape
    assert k == k2
    n = 2 * n_half
    if block is None:
        block = autotune.resolve("packed_w4_matmul", m, k, n,
                                 lowering="gpu-pallas", interpret=interpret)
    bm = min(block[0], max(16, m))
    bn = min(block[1], max(16, n))
    bn -= bn % 2
    mp, np_ = common.cdiv(m, bm) * bm, common.cdiv(n, bn) * bn
    x_p = jnp.pad(x_q, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w_packed, ((0, 0), (0, np_ // 2 - n_half)),
                  constant_values=0x08)
    out = pl.pallas_call(
        _pmm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bn // 2), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x_p, w_p)
    return out[:m, :n]


def packed_w4_matmul(x_q, w_packed, x_scale, w_scale, *,
                     out_dtype=jnp.float32, block=None,
                     interpret: bool | None = None):
    acc = packed_w4_matmul_acc(x_q, w_packed, block=block,
                               interpret=interpret)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
