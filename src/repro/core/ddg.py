"""Data-dependence graph + initiation-interval analysis (paper sec. 3.5.1).

    II_min = max over cycles theta of ceil(latency_theta / distance_theta)

Intra-iteration edges have distance 0; loop-carried edges (scan carry
outputs feeding carry inputs of the next iteration) have distance 1.
Packing a tuple merges its candidates into one super-node, which can create
a new critical cycle and raise II_min -- the paper's Fig. 5 edge case.  The
paper leaves handling to future work; we provide the analyzer plus an
optional conservative tuple filter (`would_increase_ii`), used by tests to
reproduce Fig. 5 and available as a pass option (a beyond-paper feature).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

DEFAULT_LATENCY = 1


@dataclasses.dataclass
class DDG:
    """Nodes 0..n-1 with latencies; edges (u, v, distance)."""
    latencies: list[int]
    edges: list[tuple[int, int, int]]

    def with_merged(self, group: Sequence[int]) -> "DDG":
        """Merge `group` nodes into one super-node (packed tuple): the merged
        node's latency is the max member latency (they execute together) and
        all member edges re-target the super-node."""
        group_set = set(group)
        rep = min(group_set)
        remap = {}
        new_lat = []
        for i, lat in enumerate(self.latencies):
            if i in group_set and i != rep:
                continue
            remap[i] = len(new_lat)
            new_lat.append(max(self.latencies[g] for g in group_set)
                           if i == rep else lat)
        for g in group_set:
            remap[g] = remap[rep]
        new_edges = set()
        for u, v, d in self.edges:
            nu, nv = remap[u], remap[v]
            if nu == nv and d == 0:
                continue  # intra-super-node edge disappears
            new_edges.add((nu, nv, d))
        return DDG(new_lat, sorted(new_edges))

    def ii_min(self, max_ii: int | None = None) -> int:
        """Smallest II such that no cycle violates Eq. 5.

        For candidate II, a cycle theta is violated iff
        sum(latency) - II * sum(distance) > 0.  We detect a positive-weight
        cycle with weights w(u->v) = latency(u) - II * distance(u,v) via
        Bellman-Ford and increase II until feasible."""
        n = len(self.latencies)
        if n == 0:
            return 1
        cap = max_ii or (sum(self.latencies) + 1)
        ii = 1
        while ii <= cap:
            if not self._has_positive_cycle(ii):
                return ii
            ii += 1
        return cap

    def _has_positive_cycle(self, ii: int) -> bool:
        n = len(self.latencies)
        dist = [0.0] * n     # longest-path relaxation from all sources
        for it in range(n):
            changed = False
            for u, v, d in self.edges:
                w = self.latencies[u] - ii * d
                if dist[u] + w > dist[v] + 1e-9:
                    dist[v] = dist[u] + w
                    changed = True
            if not changed:
                return False
        return True  # still relaxing after n iterations -> positive cycle


def ddg_from_scan_body(closed, num_carry: int, num_consts: int = 0,
                       latencies: Mapping[str, int] | None = None) -> DDG:
    """Build the DDG of a scan body jaxpr: distance-0 def->use edges plus
    distance-1 edges from the eqn defining carry output i to every eqn using
    carry input i (the loop-carried dependencies).

    Scan body convention: invars = [*consts, *carry, *xs],
                          outvars = [*carry_out, *ys].
    `num_carry`/`num_consts` come from the scan eqn's params."""
    from repro.core import ir
    jaxpr = closed.jaxpr
    eqns = jaxpr.eqns
    lat_map = latencies or {}
    lats = [lat_map.get(e.primitive.name, DEFAULT_LATENCY) for e in eqns]
    def_idx, use_idxs = ir.defs_uses(eqns, jaxpr.outvars)
    edges = []
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if not ir.is_literal(v) and v in def_idx:
                edges.append((def_idx[v], i, 0))
    for ci in range(num_carry):
        v_out = jaxpr.outvars[ci]
        if ir.is_literal(v_out):
            continue
        d = def_idx.get(v_out)
        if d is None:
            continue  # carry passes through an invar untouched
        v_in = jaxpr.invars[num_consts + ci]
        for u in use_idxs.get(v_in, []):
            if u != ir.OUT_SENTINEL:
                edges.append((d, u, 1))
    return DDG(lats, sorted(set(edges)))


def ddg_from_edges(latencies: Sequence[int],
                   edges: Sequence[tuple[int, int, int]]) -> DDG:
    return DDG(list(latencies), list(edges))


def would_increase_ii(ddg: DDG, group: Sequence[int]) -> bool:
    """True if merging `group` (packing the tuple) raises II_min (Fig. 5)."""
    return ddg.with_merged(group).ii_min() > ddg.ii_min()
