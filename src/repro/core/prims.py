"""JAX primitives for SILVIA packed operations.

These play the role of the paper's `call @silvia_*` functions (Fig. 4c): a
tuple of narrow scalar-per-lane operations is replaced by ONE call to a packed
implementation.  Each primitive:

* counts as a single "functional unit" for the Ops/Unit metric (its params
  carry the number of logical narrow ops it computes), and
* binds to a concrete backend implementation through the lowering registry
  (kernels/registry.py) -- the paper's sec. 3.3 placeholder-function ->
  technology-library binding: Mosaic kernels on TPU, Triton-Pallas on GPU,
  vectorized jnp on CPU, with the pure-jnp oracle (`ref`) as the
  always-legal fallback that defines the functional contract.

There is also `silvia_width_hint_p`, the analogue of the HLS frontend's width
minimization metadata: an identity op that declares "this tensor's values fit
in `width` bits", letting quantization layers mark int4-valued int8 storage.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax.extend import core as jex_core
from jax.interpreters import batching, mlir

from repro.kernels import registry

# ---------------------------------------------------------------------------
# silvia_width_hint: value-range metadata
# ---------------------------------------------------------------------------

silvia_width_hint_p = jex_core.Primitive("silvia_width_hint")


@silvia_width_hint_p.def_impl
def _width_hint_impl(x, *, width, signed):
    return x


@silvia_width_hint_p.def_abstract_eval
def _width_hint_abs(x, *, width, signed):
    return x


mlir.register_lowering(
    silvia_width_hint_p,
    mlir.lower_fun(lambda x, *, width, signed: x, multiple_results=False))
batching.primitive_batchers[silvia_width_hint_p] = (
    lambda args, dims, **params: (silvia_width_hint_p.bind(*args, **params), dims[0]))


def width_hint(x, width: int, signed: bool = True):
    """Declare that `x` (an integer tensor) only holds `width`-bit values."""
    return silvia_width_hint_p.bind(x, width=int(width), signed=bool(signed))


def _width_hint_jvp(primals, tangents, *, width, signed):
    (x,), (t,) = primals, tangents
    return silvia_width_hint_p.bind(x, width=width, signed=signed), t


jax.interpreters.ad.primitive_jvps[silvia_width_hint_p] = _width_hint_jvp


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _broadcast_aval(avals, dtype):
    shape = jnp.broadcast_shapes(*[a.shape for a in avals])
    return jcore.ShapedArray(shape, dtype)


def _register(prim, impl, abstract_eval):
    prim.multiple_results = True
    prim.def_impl(impl)
    prim.def_abstract_eval(abstract_eval)
    mlir.register_lowering(prim, mlir.lower_fun(impl, multiple_results=True))


# ---------------------------------------------------------------------------
# silvia_packed_add: k lane-wise additions/subtractions per unit (SILVIAAdd)
# ---------------------------------------------------------------------------

silvia_packed_add_p = jex_core.Primitive("silvia_packed_add")


def _packed_add_impl(*ops, mode, lane_bits, sub, out_dtypes, n_lanes):
    xs, ys = ops[:n_lanes], ops[n_lanes:]
    outs = registry.dispatch("simd_add", xs, ys, sub=sub,
                             lane_bits=lane_bits)
    return [o.astype(d) for o, d in zip(outs, out_dtypes)]


def _packed_add_abs(*ops, mode, lane_bits, sub, out_dtypes, n_lanes):
    xs, ys = ops[:n_lanes], ops[n_lanes:]
    return [_broadcast_aval([x, y], np.dtype(d))
            for x, y, d in zip(xs, ys, out_dtypes)]


_register(silvia_packed_add_p, _packed_add_impl, _packed_add_abs)


def packed_add(xs, ys, *, mode: str, lane_bits: int, sub: bool, out_dtypes):
    return silvia_packed_add_p.bind(
        *xs, *ys, mode=mode, lane_bits=int(lane_bits), sub=bool(sub),
        out_dtypes=tuple(np.dtype(d).name for d in out_dtypes),
        n_lanes=len(xs))


# ---------------------------------------------------------------------------
# silvia_packed_muladd: factor-2 shared-operand MAD chain (SILVIAMuladd)
# ---------------------------------------------------------------------------

silvia_packed_muladd_p = jex_core.Primitive("silvia_packed_muladd")


def _packed_muladd_impl(*ops, n, out_dtype, m_bits, c_bits):
    a, b, c = ops[:n], ops[n:2 * n], ops[2 * n:]
    p_a, p_b = registry.dispatch("muladd2", a, b, c)
    return [p_a.astype(out_dtype), p_b.astype(out_dtype)]


def _packed_muladd_abs(*ops, n, out_dtype, m_bits, c_bits):
    aval = _broadcast_aval(list(ops), np.dtype(out_dtype))
    return [aval, aval]


_register(silvia_packed_muladd_p, _packed_muladd_impl, _packed_muladd_abs)


def packed_muladd(a, b, c, *, out_dtype, m_bits: int = 8, c_bits: int = 8):
    """p_a = sum_i a_i*c_i ; p_b = sum_i b_i*c_i (paper Eq. 1)."""
    assert len(a) == len(b) == len(c)
    return silvia_packed_muladd_p.bind(
        *a, *b, *c, n=len(a), out_dtype=np.dtype(out_dtype).name,
        m_bits=int(m_bits), c_bits=int(c_bits))


# ---------------------------------------------------------------------------
# silvia_packed_mul4: factor-4 4-bit multiplications (SILVIAMuladd, sec. 2.3)
# ---------------------------------------------------------------------------

silvia_packed_mul4_p = jex_core.Primitive("silvia_packed_mul4")


def _packed_mul4_impl(*ops, out_dtypes, a_signed, b_signed):
    a, b = ops[:4], ops[4]
    outs = registry.dispatch("mul4", a, b)
    return [o.astype(d) for o, d in zip(outs, out_dtypes)]


def _packed_mul4_abs(*ops, out_dtypes, a_signed, b_signed):
    return [_broadcast_aval([ai, ops[4]], np.dtype(d))
            for ai, d in zip(ops[:4], out_dtypes)]


_register(silvia_packed_mul4_p, _packed_mul4_impl, _packed_mul4_abs)


def packed_mul4(a, b, *, out_dtypes, a_signed: bool, b_signed: bool):
    """p_i = a_i * b, i in 0..3 (paper Eq. 3)."""
    assert len(a) == 4
    return silvia_packed_mul4_p.bind(
        *a, b, out_dtypes=tuple(np.dtype(d).name for d in out_dtypes),
        a_signed=bool(a_signed), b_signed=bool(b_signed))


# ---------------------------------------------------------------------------
# op-count metadata: logical narrow ops computed per packed unit
# ---------------------------------------------------------------------------

PACKED_PRIMS = {
    silvia_packed_add_p,
    silvia_packed_muladd_p,
    silvia_packed_mul4_p,
}


def packed_op_counts(eqn) -> dict:
    """Return {'mul': m, 'add': a} logical narrow op counts for a packed eqn."""
    p = eqn.primitive
    if p is silvia_packed_add_p:
        return {"mul": 0, "add": eqn.params["n_lanes"]}
    if p is silvia_packed_muladd_p:
        n = eqn.params["n"]
        return {"mul": 2 * n, "add": 2 * (n - 1)}
    if p is silvia_packed_mul4_p:
        return {"mul": 4, "add": 0}
    raise ValueError(f"not a packed primitive: {p}")
