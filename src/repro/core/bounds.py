"""Lane budgets and chain-length bounds for packed operations.

This module re-derives the paper's Eq. 2 chain-length bound for arbitrary
accumulator/lane widths so the same formula serves both

* the FPGA DSP configuration of the paper (48-bit ALU, 18-bit low product
  lane on the 27x18 multiplier) -- used in tests to reproduce the paper's
  published N <= 7 bound for signed 8-bit MAD chains, and
* the TPU adaptation (32-bit integer VPU lanes / int32 accumulators), which
  is what the SILVIA passes in this repo actually use.

Eq. 2 (paper):                        N <= floor((2^(L-1) - 1) / (2^(m-1) * 2^(n-1)))   if signed
                                      N <= floor((2^L - 1) / ((2^m - 1) * (2^n - 1)))   otherwise
where L is the bit width reserved for the low product lane, m the width of
the packed (per-lane) operand and n the width of the shared operand.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------

# The paper's target: AMD UltraScale DSP48E2 (27x18 multiplier, 48-bit ALU).
FPGA_DSP48E2 = dict(mult_bits=45, alu_bits=48, low_lane_bits=18)

# Our target: a 32-bit integer lane in the TPU VPU (sub-32-bit integer
# arithmetic is widened to i32 lanes by the Mosaic/XLA stack, so one i32 lane
# op is the unit the packing amortizes -- the analogue of one DSP slice).
TPU_I32_LANE = dict(mult_bits=32, alu_bits=32, low_lane_bits=16)


@dataclasses.dataclass(frozen=True)
class LaneBudget:
    """A concrete packed-operation configuration."""

    name: str
    n_lanes: int          # how many logical ops per unit op
    lane_bits: int        # width of each packed lane
    operand_bits: int     # max width of packable operands
    signed: bool


# SILVIAAdd modes.  The paper's DSP SIMD modes are four12/two24 on the 48-bit
# ALU; rescaled to the 32-bit TPU lane they become four8/two16.
ADD_MODES = {
    # TPU-native modes (used by the pass).
    "four8": LaneBudget("four8", n_lanes=4, lane_bits=8, operand_bits=8, signed=True),
    "two16": LaneBudget("two16", n_lanes=2, lane_bits=16, operand_bits=16, signed=True),
    # Paper's original FPGA modes (kept for parity tests / documentation).
    "four12": LaneBudget("four12", n_lanes=4, lane_bits=12, operand_bits=12, signed=True),
    "two24": LaneBudget("two24", n_lanes=2, lane_bits=24, operand_bits=24, signed=True),
}


def eq2_max_chain(m: int, n: int, low_lane_bits: int, signed: bool = True) -> int:
    """Paper Eq. 2: max number of MADs accumulated per packed unit before the
    low product lane overflows into the high lane.

    m: bit width of the per-lane packed operands (a_i / b_i)
    n: bit width of the shared operand (c_i)
    low_lane_bits: bits reserved for the low product lane (paper: 18)
    """
    if signed:
        return (2 ** (low_lane_bits - 1) - 1) // (2 ** (m - 1) * 2 ** (n - 1))
    return (2 ** low_lane_bits - 1) // ((2 ** m - 1) * (2 ** n - 1))


def muladd2_max_chain(m: int = 8, n: int = 8, *, target: dict = TPU_I32_LANE,
                      signed: bool = True) -> int:
    """Chain bound for factor-2 MAD packing on the given target.

    On the paper's DSP (L=18, m=n=8, signed) this returns 7 -- the figure
    quoted in paper section 2.2.  On the TPU i32 lane (L=16) the same
    operands give N=1 (pack the multiply only; accumulate outside), while
    4-bit packed operands (m=4) give N=31, enabling genuine in-lane chains
    for the w4a8 serving path.
    """
    return max(1, eq2_max_chain(m, n, target["low_lane_bits"], signed))


def mul4_layout(target: dict = TPU_I32_LANE) -> dict:
    """Bit layout for factor-4 4-bit multiplication packing (paper sec. 2.3).

    The paper maps three zero-padded 4-bit operands plus the 3 MSBs of the
    fourth onto the 27-bit multiplier port; the fourth product is patched with
    `(a3 & 1) * b` in LUTs (Eq. 4).  On a 32-bit integer lane the same layout
    uses 8-bit product lanes at offsets 0/8/16/24, with lane 3 carrying
    a3[3:1] so its partial product (<= 2^3 * 2^3 * 2^24 = 2^30) cannot
    overflow the 32-bit register.
    """
    assert target["mult_bits"] >= 32
    return dict(lane_bits=8, offsets=(0, 8, 16, 24), msb_lane=3, msb_shift=1)


def add_mode_for_width(width: int, prefer_tpu: bool = True) -> LaneBudget | None:
    """Pick the SIMD-add mode for an operand width (None if unpackable)."""
    modes = ("four8", "two16") if prefer_tpu else ("four12", "two24")
    for name in modes:
        if width <= ADD_MODES[name].operand_bits:
            return ADD_MODES[name]
    return None


Signedness = Literal["signed", "unsigned"]
