"""SILVIA on TPU: automated superword-level packing passes over jaxprs.

The paper's contribution as a composable JAX module:

    from repro import core as silvia

    fast = silvia.optimize(fn, [silvia.PassConfig(op="muladd"),
                                silvia.PassConfig(op="add", op_size=8)])
"""
from repro.core import bounds, ddg, dce, ir, opcount, prims
from repro.core.pipeline import (DEFAULT_PASSES, PassConfig, RewriteCache,
                                 optimize, optimize_closed_jaxpr,
                                 optimized_jaxpr)
from repro.core.prims import width_hint
from repro.core.silvia import SILVIA
from repro.core.silvia_add import SILVIAAdd
from repro.core.silvia_muladd import SILVIAMul4, SILVIAMuladd

__all__ = [
    "DEFAULT_PASSES", "PassConfig", "RewriteCache", "SILVIA", "SILVIAAdd",
    "SILVIAMul4", "SILVIAMuladd", "bounds", "ddg", "dce", "ir", "opcount",
    "optimize", "optimize_closed_jaxpr", "optimized_jaxpr", "prims",
    "width_hint",
]
