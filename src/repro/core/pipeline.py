"""SILVIA pass manager -- the analogue of the paper's `SILVIA::csynth_design`
Tcl drop-in (Fig. 6): an ordered list of pass configs applied between the
"frontend" (jax.make_jaxpr) and the "backend" (jit/XLA), with recursion into
higher-order primitives (each sub-jaxpr is its own basic block).

    passes = [PassConfig(op="muladd"), PassConfig(op="add", op_size=8)]
    fast_fn = silvia.optimize(fn, passes)          # same signature as fn

mirrors the paper's

    set SILVIA::PASSES [list [dict create OP "muladd"] \
                             [dict create OP "add" OP_SIZE 12]]
    SILVIA::csynth_design

The paper's headline property is that SILVIA is a *zero-cost drop-in*: the
passes run once at synthesis time.  The serving analogue is compile-once /
run-many, realized by three cache layers:

* a **trace cache** in `optimize()`: tracing + the SILVIA rewrite + jit
  compilation happen once per (pytree structure, input avals) signature;
  subsequent calls dispatch straight into the compiled executable,
* a **sub-jaxpr rewrite memo** (`RewriteCache`): structurally identical
  inner BBs (repeated layer bodies, identical scan/cond branches) are
  rewritten once and the result is spliced everywhere,
* a **shared analysis cache** (`ir.AnalysisCache`): the ALAP schedule,
  def/use maps and width analysis of a BB are built once per traced BB and
  shared by every pass in the pipeline; packing rewrites PATCH the context
  in place (`BBContext.patch` splices the item schedule and locally repairs
  def/use + widths -- counted as `analysis_patched`) and the rewritten BB
  is retraced once after the whole pipeline, not once per rewriting pass.

`optimize()`-wrapped functions expose `cache_info()` / `cache_clear()` so
tests and benchmarks can assert the compile-once behaviour.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax.extend import core as jex_core

from repro.core import ir
from repro.core import silvia as silvia_mod
from repro.core.silvia import SILVIA
from repro.core.silvia_add import SILVIAAdd
from repro.core.silvia_muladd import SILVIAMul4, SILVIAMuladd

ClosedJaxpr = jex_core.ClosedJaxpr


@dataclasses.dataclass(frozen=True)
class PassConfig:
    """One entry of SILVIA::PASSES (paper Fig. 6)."""
    op: str                       # "add" | "muladd" | "mul4"
    op_size: int | None = None    # SILVIAAdd lane operand size (8 | 16)
    inst: str = "both"            # SILVIAAdd: "add" | "sub" | "both"
    max_chain_len: int | None = None   # SILVIAMuladd MAX_CHAIN_LEN
    m_bits: int = 8
    c_bits: int = 8
    # paper 3.5.1 future work: drop tuples that raise II_min in loop bodies
    filter_ii: bool = False

    def instantiate(self) -> SILVIA:
        if self.op == "add":
            p = SILVIAAdd(op_size=self.op_size or 8, inst=self.inst)
        elif self.op == "muladd":
            p = SILVIAMuladd(m_bits=self.m_bits, c_bits=self.c_bits,
                             max_chain_len=self.max_chain_len)
        elif self.op == "mul4":
            p = SILVIAMul4()
        else:
            raise ValueError(f"unknown SILVIA pass op: {self.op}")
        p.filter_ii = self.filter_ii
        return p


DEFAULT_PASSES = (
    PassConfig(op="muladd"),
    PassConfig(op="mul4"),
    PassConfig(op="add", op_size=8),
    PassConfig(op="add", op_size=16),
)

# Higher-order primitives whose sub-jaxprs we optimize as separate BBs.
_RECURSE_PRIMS = {"scan", "while", "cond", "pjit", "closed_call",
                  "custom_vjp_call", "remat", "checkpoint"}


def _map_subjaxprs(eqn, fn):
    """Apply fn to every ClosedJaxpr in eqn.params (one level)."""
    if eqn.primitive.name not in _RECURSE_PRIMS:
        return eqn, False
    new_params = dict(eqn.params)
    changed = False
    for k, v in eqn.params.items():
        if isinstance(v, ClosedJaxpr):
            nv = fn(v)
            if nv is not v:
                new_params[k] = nv
                changed = True
        elif isinstance(v, (tuple, list)) and v and all(
                isinstance(x, ClosedJaxpr) for x in v):
            nvs = type(v)(fn(x) for x in v)
            if any(a is not b for a, b in zip(nvs, v)):
                new_params[k] = nvs
                changed = True
    if not changed:
        return eqn, False
    return eqn.replace(params=new_params), True


# ---------------------------------------------------------------------------
# rewrite-level caches
# ---------------------------------------------------------------------------

# Consts up to this many bytes are fingerprinted by content; larger ones by
# object identity (kept alive by the cache entry), trading cross-object
# sharing for O(1) keys on weight-sized arrays.
_CONST_KEY_MAX_BYTES = 1 << 12


def _const_key(c) -> Any:
    a = np.asarray(c) if not hasattr(c, "dtype") else c
    nbytes = getattr(a, "nbytes", None)
    if nbytes is not None and nbytes <= _CONST_KEY_MAX_BYTES:
        try:
            return ("bytes", str(a.dtype), a.shape, np.asarray(a).tobytes())
        except Exception:
            pass
    return ("id", id(c))


def _jaxpr_fingerprint(closed: ClosedJaxpr) -> Any:
    """Structural key for a ClosedJaxpr: the canonical pretty-print (var
    names are assigned per-print in order of appearance, so structurally
    identical jaxprs print identically) plus a fingerprint of the consts."""
    return (str(closed.jaxpr),
            tuple(_const_key(c) for c in closed.consts))


class RewriteCache:
    """State shared across one or many `optimize_closed_jaxpr` walks.

    * `analysis`: per-BB-version BBContext cache (ir.AnalysisCache),
    * `subjaxpr`: (pass signature, fingerprint) -> rewritten ClosedJaxpr,
      so repeated layer bodies / identical scan bodies are optimized once
      -- but never across *different* pass lists sharing one cache,
    * keepalive of the memoized inputs so id()-based const keys stay valid.
    """

    def __init__(self):
        self.analysis = ir.AnalysisCache()
        self.subjaxpr: dict[Any, ClosedJaxpr] = {}
        self._keepalive: list = []
        self.subjaxpr_hits = 0
        self.subjaxpr_misses = 0

    def memo_sub(self, sub: ClosedJaxpr, loop_info, rewrite, passes=()):
        key = (_pass_signature(passes), _jaxpr_fingerprint(sub), loop_info)
        hit = self.subjaxpr.get(key)
        if hit is not None:
            self.subjaxpr_hits += 1
            return hit
        self.subjaxpr_misses += 1
        out = rewrite(sub)
        self._keepalive.append((sub, tuple(passes)))  # id()-key stability
        self.subjaxpr[key] = out
        return out

    def info(self) -> dict:
        return {
            "subjaxpr_hits": self.subjaxpr_hits,
            "subjaxpr_misses": self.subjaxpr_misses,
            "analysis_builds": self.analysis.builds,
            "analysis_hits": self.analysis.hits,
            "analysis_patched": self.analysis.patched,
        }

    def clear(self):
        self.analysis.clear()
        self.subjaxpr.clear()
        self._keepalive.clear()
        self.subjaxpr_hits = 0
        self.subjaxpr_misses = 0


def optimize_closed_jaxpr(closed: ClosedJaxpr, passes: Sequence[SILVIA],
                          stats: list | None = None,
                          loop_info=None,
                          cache: RewriteCache | None = None) -> ClosedJaxpr:
    """Apply the pass list to a ClosedJaxpr, recursing into sub-jaxprs.

    loop_info: (num_consts, num_carry) when `closed` is a scan body --
    unlocks the II-aware tuple filter for passes with filter_ii=True.
    cache: shared RewriteCache; sub-jaxpr rewrites are memoized on it and
    BB analyses are shared across the passes (a fresh private cache is used
    when None, preserving the stateless call signature)."""
    if cache is None:
        cache = RewriteCache()
    # 1. recurse into inner BBs first
    new_eqns, changed = [], False
    for eqn in closed.jaxpr.eqns:
        inner_loop_info = None
        if eqn.primitive.name == "scan":
            inner_loop_info = (eqn.params.get("num_consts", 0),
                               eqn.params.get("num_carry", 0))
        rewrite = functools.partial(optimize_closed_jaxpr, passes=passes,
                                    stats=stats, loop_info=inner_loop_info,
                                    cache=cache)
        rec = functools.partial(cache.memo_sub, loop_info=inner_loop_info,
                                rewrite=rewrite, passes=passes)
        ne, ch = _map_subjaxprs(eqn, rec)
        new_eqns.append(ne)
        changed |= ch
    if changed:
        jaxpr = closed.jaxpr.replace(eqns=new_eqns)
        closed = ClosedJaxpr(jaxpr, closed.consts)
    # 2. run each pass on this BB against ONE shared analysis context.
    #    Packing rewrites patch the context in place (def/use + width info
    #    repaired locally -- ir.AnalysisCache.patched counts them) and the
    #    rewritten BB is emitted/retraced ONCE after the whole pipeline,
    #    instead of once per rewriting pass.
    if not passes:
        return closed
    ctx = None
    for pass_i, p in enumerate(passes):
        ctx = cache.analysis.get_or_build(
            closed.jaxpr, lambda: silvia_mod.BBContext(closed))
        if pass_i == 0 and ctx.dirty:
            # stale: a previous walk (different pass list sharing this
            # cache) patched the context past closed.jaxpr -- this walk
            # must start from the un-rewritten BB
            ctx = cache.analysis.rebuild(
                closed.jaxpr, lambda: silvia_mod.BBContext(closed))
        before = ctx.patches
        st = p.run_ctx(ctx, loop_info=loop_info)
        if ctx.patches != before:
            cache.analysis.patched += 1
        if stats is not None:
            st["pass"] = p.name
            stats.append(st)
    if ctx is not None and ctx.dirty:
        closed = ir.emit_closed_jaxpr(closed, ctx.eqns)
    return closed


# ---------------------------------------------------------------------------
# optimize(): the compile-once / run-many drop-in wrapper
# ---------------------------------------------------------------------------

def _pass_signature(passes) -> tuple:
    """Hashable identity of a pass list (for trace-cache keys)."""
    sig = []
    for p in passes:
        if isinstance(p, PassConfig):
            sig.append(("cfg",) + dataclasses.astuple(p))
        else:
            sig.append(("obj", id(p)))
    return tuple(sig)


def _aval_key(x) -> Any:
    try:
        a = jax.api_util.shaped_abstractify(x)
        return (a.shape, str(a.dtype), getattr(a, "weak_type", False))
    except Exception:
        return ("py", type(x), x if isinstance(x, (int, float, bool, str,
                                                   bytes, type(None))) else id(x))


@dataclasses.dataclass
class _TraceEntry:
    runner: Callable
    out_tree: Any
    rewrite_ms: float


def optimize(fn, passes: Sequence[PassConfig | SILVIA] = DEFAULT_PASSES,
             collect_stats: list | None = None, *, jit: bool = True):
    """Return a drop-in replacement for `fn` whose jaxpr has been rewritten
    by the SILVIA passes.  Works under jit / grad / shard_map / scan.

    Tracing, the SILVIA rewrite and (with jit=True, the default) XLA
    compilation happen ONCE per input-signature (pytree structure + avals);
    later calls with the same signature dispatch straight into the cached
    executable.  A shape/dtype/structure change re-traces.  Identical
    sub-jaxprs (repeated layer bodies) are rewritten once per wrapper, even
    across signatures.

    The wrapper exposes:
      wrapped.cache_info()  -> dict with trace_hits / trace_misses /
                               subjaxpr_* / analysis_* counters and the
                               cumulative rewrite wall time (ms),
      wrapped.cache_clear() -> drop all cached traces and rewrites.

    collect_stats: list that per-BB pass stats dicts are appended to on
    every cache MISS (hits skip the pipeline entirely, by design).
    """
    pass_objs = [p.instantiate() if isinstance(p, PassConfig) else p
                 for p in passes]

    trace_cache: dict[Any, _TraceEntry] = {}
    rewrite_cache = RewriteCache()
    counters = {"trace_hits": 0, "trace_misses": 0, "rewrite_ms": 0.0}

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        key = (in_tree, tuple(_aval_key(x) for x in flat))
        entry = trace_cache.get(key)
        if entry is None:
            counters["trace_misses"] += 1

            def flat_fn(*flat_args):
                a, k = jax.tree_util.tree_unflatten(in_tree, flat_args)
                return fn(*a, **k)

            t0 = time.perf_counter()
            closed, out_shape = jax.make_jaxpr(flat_fn,
                                               return_shape=True)(*flat)
            out_tree = jax.tree_util.tree_structure(out_shape)
            closed = optimize_closed_jaxpr(closed, pass_objs, collect_stats,
                                           cache=rewrite_cache)
            rewrite_ms = (time.perf_counter() - t0) * 1e3
            counters["rewrite_ms"] += rewrite_ms
            # BBContexts can't be reused by the next trace (fresh jaxpr
            # objects); drop them so long-lived wrappers don't accumulate
            # analysis state.  The sub-jaxpr memo IS reusable across
            # traces and is bounded by distinct body structures, so it
            # stays.
            rewrite_cache.analysis.evict()
            runner = jex_core.jaxpr_as_fun(closed)
            if jit:
                runner = jax.jit(runner)
            entry = _TraceEntry(runner, out_tree, rewrite_ms)
            trace_cache[key] = entry
        else:
            counters["trace_hits"] += 1
        outs = entry.runner(*flat)
        return jax.tree_util.tree_unflatten(entry.out_tree, outs)

    def cache_info() -> dict:
        return {**counters, **rewrite_cache.info(),
                "traces": len(trace_cache)}

    def cache_clear():
        trace_cache.clear()
        rewrite_cache.clear()
        counters.update(trace_hits=0, trace_misses=0, rewrite_ms=0.0)

    wrapped.cache_info = cache_info
    wrapped.cache_clear = cache_clear
    return wrapped


def optimized_jaxpr(fn, *example_args, passes=DEFAULT_PASSES,
                    stats: list | None = None,
                    cache: RewriteCache | None = None) -> ClosedJaxpr:
    """Trace fn and return its SILVIA-optimized ClosedJaxpr (for inspection,
    op counting and tests)."""
    pass_objs = [p.instantiate() if isinstance(p, PassConfig) else p
                 for p in passes]
    closed = jax.make_jaxpr(fn)(*example_args)
    return optimize_closed_jaxpr(closed, pass_objs, stats, cache=cache)
