"""SILVIA pass manager -- the analogue of the paper's `SILVIA::csynth_design`
Tcl drop-in (Fig. 6): an ordered list of pass configs applied between the
"frontend" (jax.make_jaxpr) and the "backend" (jit/XLA), with recursion into
higher-order primitives (each sub-jaxpr is its own basic block).

    passes = [PassConfig(op="muladd"), PassConfig(op="add", op_size=8)]
    fast_fn = silvia.optimize(fn, passes)          # same signature as fn

mirrors the paper's

    set SILVIA::PASSES [list [dict create OP "muladd"] \
                             [dict create OP "add" OP_SIZE 12]]
    SILVIA::csynth_design
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
from jax.extend import core as jex_core

from repro.core.silvia import SILVIA
from repro.core.silvia_add import SILVIAAdd
from repro.core.silvia_muladd import SILVIAMul4, SILVIAMuladd

ClosedJaxpr = jex_core.ClosedJaxpr


@dataclasses.dataclass(frozen=True)
class PassConfig:
    """One entry of SILVIA::PASSES (paper Fig. 6)."""
    op: str                       # "add" | "muladd" | "mul4"
    op_size: int | None = None    # SILVIAAdd lane operand size (8 | 16)
    inst: str = "both"            # SILVIAAdd: "add" | "sub" | "both"
    max_chain_len: int | None = None   # SILVIAMuladd MAX_CHAIN_LEN
    m_bits: int = 8
    c_bits: int = 8
    # paper 3.5.1 future work: drop tuples that raise II_min in loop bodies
    filter_ii: bool = False

    def instantiate(self) -> SILVIA:
        if self.op == "add":
            p = SILVIAAdd(op_size=self.op_size or 8, inst=self.inst)
        elif self.op == "muladd":
            p = SILVIAMuladd(m_bits=self.m_bits, c_bits=self.c_bits,
                             max_chain_len=self.max_chain_len)
        elif self.op == "mul4":
            p = SILVIAMul4()
        else:
            raise ValueError(f"unknown SILVIA pass op: {self.op}")
        p.filter_ii = self.filter_ii
        return p


DEFAULT_PASSES = (
    PassConfig(op="muladd"),
    PassConfig(op="mul4"),
    PassConfig(op="add", op_size=8),
    PassConfig(op="add", op_size=16),
)

# Higher-order primitives whose sub-jaxprs we optimize as separate BBs.
_RECURSE_PRIMS = {"scan", "while", "cond", "pjit", "closed_call",
                  "custom_vjp_call", "remat", "checkpoint"}


def _map_subjaxprs(eqn, fn):
    """Apply fn to every ClosedJaxpr in eqn.params (one level)."""
    if eqn.primitive.name not in _RECURSE_PRIMS:
        return eqn, False
    new_params = dict(eqn.params)
    changed = False
    for k, v in eqn.params.items():
        if isinstance(v, ClosedJaxpr):
            nv = fn(v)
            if nv is not v:
                new_params[k] = nv
                changed = True
        elif isinstance(v, (tuple, list)) and v and all(
                isinstance(x, ClosedJaxpr) for x in v):
            nvs = type(v)(fn(x) for x in v)
            if any(a is not b for a, b in zip(nvs, v)):
                new_params[k] = nvs
                changed = True
    if not changed:
        return eqn, False
    return eqn.replace(params=new_params), True


def optimize_closed_jaxpr(closed: ClosedJaxpr, passes: Sequence[SILVIA],
                          stats: list | None = None,
                          loop_info=None) -> ClosedJaxpr:
    """Apply the pass list to a ClosedJaxpr, recursing into sub-jaxprs.

    loop_info: (num_consts, num_carry) when `closed` is a scan body --
    unlocks the II-aware tuple filter for passes with filter_ii=True."""
    # 1. recurse into inner BBs first
    new_eqns, changed = [], False
    for eqn in closed.jaxpr.eqns:
        inner_loop_info = None
        if eqn.primitive.name == "scan":
            inner_loop_info = (eqn.params.get("num_consts", 0),
                               eqn.params.get("num_carry", 0))
        rec = functools.partial(optimize_closed_jaxpr, passes=passes,
                                stats=stats, loop_info=inner_loop_info)
        ne, ch = _map_subjaxprs(eqn, rec)
        new_eqns.append(ne)
        changed |= ch
    if changed:
        jaxpr = closed.jaxpr.replace(eqns=new_eqns)
        closed = ClosedJaxpr(jaxpr, closed.consts)
    # 2. run each pass on this BB
    for p in passes:
        closed, st = p.run(closed, loop_info=loop_info)
        if stats is not None:
            st["pass"] = p.name
            stats.append(st)
    return closed


def optimize(fn, passes: Sequence[PassConfig | SILVIA] = DEFAULT_PASSES,
             collect_stats: list | None = None):
    """Return a drop-in replacement for `fn` whose jaxpr has been rewritten
    by the SILVIA passes.  Works under jit / grad / shard_map / scan."""
    pass_objs = [p.instantiate() if isinstance(p, PassConfig) else p
                 for p in passes]

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))

        def flat_fn(*flat_args):
            a, k = jax.tree_util.tree_unflatten(in_tree, flat_args)
            return fn(*a, **k)

        closed, out_shape = jax.make_jaxpr(flat_fn, return_shape=True)(*flat)
        out_tree = jax.tree_util.tree_structure(out_shape)
        closed = optimize_closed_jaxpr(closed, pass_objs, collect_stats)
        outs = jex_core.jaxpr_as_fun(closed)(*flat)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return wrapped


def optimized_jaxpr(fn, *example_args, passes=DEFAULT_PASSES,
                    stats: list | None = None) -> ClosedJaxpr:
    """Trace fn and return its SILVIA-optimized ClosedJaxpr (for inspection,
    op counting and tests)."""
    pass_objs = [p.instantiate() if isinstance(p, PassConfig) else p
                 for p in passes]
    closed = jax.make_jaxpr(fn)(*example_args)
    return optimize_closed_jaxpr(closed, pass_objs, stats)
