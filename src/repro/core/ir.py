"""Basic-block model over jaxprs for the SILVIA passes.

LLVM IR (the paper's substrate) and jaxprs line up closely: a jaxpr is
straight-line SSA where control flow lives inside higher-order primitives
(`scan`, `cond`, `while`, `pjit`), so a jaxpr body *is* a basic block.  This
module provides what Algorithm 1 needs on that substrate:

* def-use chains over the equation list (`defs_uses`),
* ALAP scheduling (`alap_schedule`) -- the generalization of the paper's
  `moveUsesALAP`: every equation is placed as late as its uses allow, which
  maximizes the last-definition -> first-use interval of every candidate at
  once,
* width inference (`WidthAnalysis`) -- the analogue of relying on the HLS
  frontend's width minimization: bit widths are traced through
  `convert_element_type`, broadcasts and `silvia_width_hint` metadata,
* the schedule-item representation used to splice packed calls in and
  candidates out, plus `emit_closed_jaxpr` to rebuild a functionally
  equivalent ClosedJaxpr (the paper's BB -> BB* rewrite), and
* dead-code elimination over schedule items (paper sec. 3.4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax.extend import core as jex_core

Literal = jex_core.Literal
ClosedJaxpr = jex_core.ClosedJaxpr


def is_literal(v) -> bool:
    return isinstance(v, Literal)


def is_drop_var(v) -> bool:
    return type(v).__name__ == "DropVar"


# ---------------------------------------------------------------------------
# def-use chains
# ---------------------------------------------------------------------------

OUT_SENTINEL = 1 << 60  # "position" of the BB's outvars


def defs_uses(eqns: Sequence, outvars: Sequence):
    """Return (def_idx, use_idxs): var -> defining eqn index / list of using
    eqn indices.  Uses by the BB outputs appear as OUT_SENTINEL."""
    def_idx: dict[Any, int] = {}
    use_idxs: dict[Any, list[int]] = {}
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if not is_literal(v):
                use_idxs.setdefault(v, []).append(i)
        for v in eqn.outvars:
            if not is_drop_var(v):
                def_idx[v] = i
    for v in outvars:
        if not is_literal(v):
            use_idxs.setdefault(v, []).append(OUT_SENTINEL)
    return def_idx, use_idxs


# ---------------------------------------------------------------------------
# ALAP scheduling (generalized moveUsesALAP)
# ---------------------------------------------------------------------------

def alap_schedule(eqns: Sequence, outvars: Sequence) -> list:
    """Reorder equations so each is placed as late as possible while
    preserving data dependencies; equations with effects keep their relative
    order (the analogue of the paper's conservative treatment of calls that
    may alias memory).  Stable: ties resolve to original order."""
    n = len(eqns)
    if n == 0:
        return list(eqns)
    def_idx, _ = defs_uses(eqns, outvars)
    # consumers[i] = eqn indices that must come after eqn i
    consumers: list[set[int]] = [set() for _ in range(n)]
    prev_effectful = None
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if not is_literal(v) and v in def_idx:
                consumers[def_idx[v]].add(i)
        if eqn.effects:
            if prev_effectful is not None:
                consumers[prev_effectful].add(i)
            prev_effectful = i
    # ALAP level: each eqn sits at min(consumer levels) - 1; eqns consumed
    # only by the BB outputs sit at level n.  Stable sort by (level,
    # original index) realizes the latest legal schedule.
    level = [n] * n
    order = _topo_order(consumers, n)
    for i in reversed(order):
        for j in consumers[i]:
            level[i] = min(level[i], level[j] - 1)
    idx = sorted(range(n), key=lambda i: (level[i], i))
    return [eqns[i] for i in idx]


def _topo_order(consumers, n):
    indeg = [0] * n
    for i in range(n):
        for j in consumers[i]:
            indeg[j] += 1
    stack = [i for i in range(n) if indeg[i] == 0]
    out = []
    while stack:
        i = stack.pop()
        out.append(i)
        for j in consumers[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(j)
    assert len(out) == n, "dependency cycle in jaxpr (impossible)"
    return out


# ---------------------------------------------------------------------------
# width inference
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Width:
    bits: int
    signed: bool
    value_src: Any   # var (or literal) holding the same VALUES, narrowest dtype
    match_src: Any   # var for shared-operand identity (traces through broadcast)


_INT_BITS = {"int4": 4, "uint4": 4, "int8": 8, "uint8": 8,
             "int16": 16, "uint16": 16, "int32": 32, "uint32": 32,
             "int64": 64, "uint64": 64, "bool": 1}


def dtype_bits(dtype) -> int | None:
    return _INT_BITS.get(np.dtype(dtype).name if np.dtype(dtype).name in _INT_BITS
                         else str(dtype), None)


def _literal_width(val) -> tuple[int, bool]:
    if isinstance(val, (bool, np.bool_)):
        return 1, False
    if isinstance(val, (int, np.integer)):
        v = int(val)
        mag = v if v >= 0 else -v - 1
        return mag.bit_length() + 1, True
    if isinstance(val, np.ndarray) and val.dtype.kind in "iu":
        b = dtype_bits(val.dtype)
        return (b if b is not None else 64), val.dtype.kind == "i"
    return 64, True


class WidthAnalysis:
    """Lazy width inference over a BB's equations."""

    def __init__(self, eqns: Sequence, outvars: Sequence):
        self.def_idx, _ = defs_uses(eqns, outvars)
        self.eqns = eqns
        self._memo: dict[Any, Width] = {}

    def width_of(self, v) -> Width:
        if is_literal(v):
            bits, signed = _literal_width(v.val)
            return Width(bits, signed, v, v)
        if v in self._memo:
            return self._memo[v]
        w = self._compute(v)
        self._memo[v] = w
        return w

    def rebind(self, eqns: Sequence, outvars: Sequence, avail: set) -> None:
        """Re-point the analysis at a PATCHED item schedule (a packing
        rewrite of the same BB) without discarding the memo.

        Packing is value-preserving and keeps the root output vars, so a
        memoized width stays correct as long as the vars it references are
        still live: entries whose subject or value/match source was DCE'd
        away are pruned (a later pass must not emit a read of a var that
        no longer has a definition); everything else is carried over --
        this is what makes patching ~free next to a full rebuild."""
        self.eqns = eqns
        self.def_idx, _ = defs_uses(eqns, outvars)

        def live(v):
            return is_literal(v) or v in avail

        self._memo = {v: w for v, w in self._memo.items()
                      if v in avail and live(w.value_src)
                      and live(w.match_src)}

    def _leaf(self, v) -> Width:
        b = dtype_bits(v.aval.dtype)
        signed = np.dtype(v.aval.dtype).kind != "u" if b is not None else True
        return Width(b if b is not None else 999, signed, v, v)

    def _compute(self, v) -> Width:
        i = self.def_idx.get(v)
        if i is None:
            return self._leaf(v)
        eqn = self.eqns[i]
        name = eqn.primitive.name
        if name == "convert_element_type":
            inw = self.width_of(eqn.invars[0])
            out_bits = dtype_bits(eqn.params["new_dtype"])
            if out_bits is not None and out_bits >= inw.bits:
                # widening conversion preserves values -> keep narrow source
                return Width(inw.bits, inw.signed, inw.value_src, inw.match_src)
            return self._leaf(v)
        if name == "silvia_width_hint":
            inw = self.width_of(eqn.invars[0])
            return Width(min(eqn.params["width"], inw.bits),
                         eqn.params["signed"], eqn.invars[0], inw.match_src)
        if name == "broadcast_in_dim":
            inw = self.width_of(eqn.invars[0])
            # broadcast replicates values: identity for matching, but the
            # VALUE source is the broadcasted var itself (shape matters).
            return Width(inw.bits, inw.signed, v, inw.match_src)
        if name == "and":
            # masking with a constant bounds the width
            for a, b in ((eqn.invars[0], eqn.invars[1]),
                         (eqn.invars[1], eqn.invars[0])):
                if is_literal(b) and isinstance(b.val, (int, np.integer)) and int(b.val) >= 0:
                    inw = self.width_of(a)
                    return Width(min(inw.bits, int(b.val).bit_length()),
                                 False, v, v)
            return self._leaf(v)
        return self._leaf(v)


# ---------------------------------------------------------------------------
# shared per-BB analysis cache
# ---------------------------------------------------------------------------

class AnalysisCache:
    """Identity-keyed cache of per-BB analysis state (BBContext).

    The SILVIA passes run as an ordered pipeline over the same BB: a pass
    that finds nothing to rewrite returns the *same* ClosedJaxpr object, so
    the next pass can reuse the ALAP schedule, def/use maps and width
    analysis instead of rebuilding them.  A pass that does rewrite emits a
    fresh jaxpr object, which misses here -- that identity change IS the
    invalidation: every distinct BB version is analyzed exactly once.

    Entries keep a strong reference to their jaxpr so CPython cannot recycle
    the id() while the entry is live.

    `patched` counts in-place schedule patches (BBContext.patch): a packing
    rewrite that used to cost a full re-emit + re-analysis but now only
    splices the item schedule and locally repairs def/use + width state.
    The pass pipeline increments it; patched >> builds is the incremental
    re-analysis proof (tests/test_pipeline_cache.py).
    """

    def __init__(self):
        self._entries: dict[int, tuple[Any, Any]] = {}
        self.builds = 0
        self.hits = 0
        self.patched = 0

    def get_or_build(self, jaxpr, build: Callable[[], Any]):
        ent = self._entries.get(id(jaxpr))
        if ent is not None and ent[0] is jaxpr:
            self.hits += 1
            return ent[1]
        self.builds += 1
        val = build()
        self._entries[id(jaxpr)] = (jaxpr, val)
        return val

    def rebuild(self, jaxpr, build: Callable[[], Any]):
        """Force-build a pristine entry, replacing whatever was cached.

        Needed when a cached context was PATCHED past `jaxpr` by a previous
        pipeline walk (e.g. a different pass list sharing this cache): the
        entry no longer describes the un-rewritten BB, so the new walk must
        start from a fresh analysis."""
        self.builds += 1
        val = build()
        self._entries[id(jaxpr)] = (jaxpr, val)
        return val

    def evict(self):
        """Drop cached contexts, keep counters.  Entries are only reusable
        within one pipeline walk (every new trace makes fresh jaxpr
        objects), so callers evict between walks to bound memory."""
        self._entries.clear()

    def clear(self):
        self._entries.clear()
        self.builds = 0
        self.hits = 0
        self.patched = 0


# ---------------------------------------------------------------------------
# schedule items + emit
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EqnItem:
    eqn: Any

    @property
    def invars(self):
        return self.eqn.invars

    @property
    def outvars(self):
        return self.eqn.outvars

    @property
    def effects(self):
        return self.eqn.effects

    @property
    def primitive(self):
        return self.eqn.primitive

    @property
    def params(self):
        return self.eqn.params


class _PackedPrimitive:
    """Duck-type stand-in so schedule items are uniform: passes and the
    width analysis probe `item.primitive.name`, and a packed call must look
    like an opaque equation (its name matches no packable pattern, so a
    later pass never tries to re-pack it)."""
    name = "silvia_packed"
    multiple_results = True


_PACKED_PRIM = _PackedPrimitive()


@dataclasses.dataclass
class PackedItem:
    """A packed-operation call replacing a tuple of candidates.

    build(invals) -> list of output values bound to `outvars` (the original
    candidates' root output vars, so downstream uses are rewired for free).
    """
    build: Callable[[list], list]
    in_vars: list           # Vars/Literals the packed call reads
    out_vars: list          # original root vars its results replace
    describe: str = "packed"

    @property
    def invars(self):
        return self.in_vars

    @property
    def outvars(self):
        return self.out_vars

    @property
    def effects(self):
        return ()

    @property
    def primitive(self):
        return _PACKED_PRIM

    @property
    def params(self):
        return {}


def dce_items(items: list, outvars: Sequence) -> list:
    """Backward liveness over schedule items (paper sec. 3.4 DCE)."""
    live = {v for v in outvars if not is_literal(v)}
    keep = [False] * len(items)
    for i in range(len(items) - 1, -1, -1):
        it = items[i]
        if it.effects or any((not is_drop_var(v)) and v in live for v in it.outvars):
            keep[i] = True
            for v in it.invars:
                if not is_literal(v):
                    live.add(v)
    return [it for i, it in enumerate(items) if keep[i]]


def emit_fn(closed: ClosedJaxpr, items: list):
    """Build a python callable evaluating the item schedule (flat in/out)."""
    jaxpr = closed.jaxpr

    def read(env, v):
        return v.val if is_literal(v) else env[v]

    def fn(*flat_args):
        env = {}
        for v, c in zip(jaxpr.constvars, closed.consts):
            env[v] = c
        for v, a in zip(jaxpr.invars, flat_args):
            env[v] = a
        for it in items:
            if isinstance(it, EqnItem):
                eqn = it.eqn
                outs = eqn.primitive.bind(
                    *[read(env, v) for v in eqn.invars], **eqn.params)
                if not eqn.primitive.multiple_results:
                    outs = [outs]
            else:
                outs = it.build([read(env, v) for v in it.in_vars])
            for ov, o in zip(it.outvars, outs):
                if not is_drop_var(ov):
                    env[ov] = o
        return [read(env, v) for v in jaxpr.outvars]

    return fn


def emit_closed_jaxpr(closed: ClosedJaxpr, items: list) -> ClosedJaxpr:
    """Rebuild a ClosedJaxpr from a transformed item schedule (BB -> BB*)."""
    fn = emit_fn(closed, items)
    return jax.make_jaxpr(fn)(*closed.in_avals)


def items_of(closed: ClosedJaxpr) -> list:
    return [EqnItem(e) for e in closed.jaxpr.eqns]
