"""SILVIAMuladd: pack shared-operand multiply-and-add trees (paper sec. 2.2,
2.3, 3).

Factor-2 (SILVIAMuladd): two MAD trees `p_a = sum a_i*c_i`, `p_b = sum b_i*c_i`
sharing the c_i operands pack onto one unit (wp486).  A degenerate tree of a
single multiplication is a valid candidate too, so mul-only packing falls out
for free (paper sec. 3.1).  Chains longer than the Eq. 2 bound split into
balanced segments summed by an external adder tree (paper sec. 3.3).

Factor-4 (SILVIAMul4): four <=4-bit multiplications by one shared factor pack
onto one unit (paper sec. 2.3, including the unsigned variant the paper
introduces).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import bounds, ir, prims
from repro.core.silvia import BBContext, Candidate, SILVIA, Tuple_


def _key_of(src) -> Any:
    """Hashable identity key for a shared-operand source (Var or Literal)."""
    if ir.is_literal(src):
        v = src.val
        return ("lit", str(np.asarray(v).dtype), np.asarray(v).tobytes()
                if np.asarray(v).size < 64 else id(src))
    return src


@dataclasses.dataclass
class Leaf:
    mul_idx: int
    ops: tuple          # ((width, value_src, match_key), (width, value_src, match_key))
    shape: tuple


@dataclasses.dataclass
class Tree:
    root_idx: int
    eqns: frozenset
    leaves: list        # of Leaf
    root_var: Any
    out_dtype: str
    shape: tuple


def _collect_trees(ctx: BBContext, m_bits: int, c_bits: int) -> list[Tree]:
    """Find maximal add-trees whose leaves are narrow multiplications
    (paper sec. 3.1, getCandidates of SILVIAMuladd)."""
    use_counts = {v: len(us) for v, us in ctx.use_idxs.items()}
    info: dict[int, Tree] = {}           # eqn idx -> tree rooted there
    consumed_roots: set[int] = set()     # roots absorbed by a larger tree
    for i, eqn in enumerate(ctx.eqns):
        name = eqn.primitive.name
        if eqn.effects or not eqn.outvars or ir.is_drop_var(eqn.outvars[0]):
            continue
        out = eqn.outvars[0]
        dt = np.dtype(out.aval.dtype)
        if dt.kind not in "iu":
            continue
        if name == "mul":
            w0 = ctx.widths.width_of(eqn.invars[0])
            w1 = ctx.widths.width_of(eqn.invars[1])
            # one operand within m_bits (packed lanes), other within c_bits
            # (shared); either assignment may hold -- resolved at pairing.
            fits = ((w0.bits <= m_bits and w1.bits <= c_bits)
                    or (w1.bits <= m_bits and w0.bits <= c_bits))
            if not fits:
                continue
            leaf = Leaf(
                mul_idx=i,
                ops=((w0.bits, w0.value_src, _key_of(w0.match_src)),
                     (w1.bits, w1.value_src, _key_of(w1.match_src))),
                shape=out.aval.shape)
            info[i] = Tree(i, frozenset([i]), [leaf], out, dt.name,
                           out.aval.shape)
        elif name == "add":
            subs = []
            ok = True
            for v in eqn.invars:
                if ir.is_literal(v):
                    ok = False
                    break
                d = ctx.def_idx.get(v)
                if d is None or d not in info or use_counts.get(v, 0) != 1:
                    ok = False
                    break
                subs.append(d)
            if not ok or len(set(subs)) != 2:
                continue
            t0, t1 = info[subs[0]], info[subs[1]]
            info[i] = Tree(i, t0.eqns | t1.eqns | frozenset([i]),
                           t0.leaves + t1.leaves, out, dt.name, out.aval.shape)
            consumed_roots |= {subs[0], subs[1]}
    return [t for i, t in info.items() if i not in consumed_roots]


def _match_leaves(t1: Tree, t2: Tree, m_bits: int, c_bits: int):
    """Pair leaves of two trees by a shared operand (paper Eq. 1): returns
    [(a_src, b_src, c_src)] per pair or None.  Greedy bipartite match on
    shared-operand identity."""
    if len(t1.leaves) != len(t2.leaves):
        return None
    used = [False] * len(t2.leaves)
    pairs = []
    for l1 in t1.leaves:
        found = False
        for j, l2 in enumerate(t2.leaves):
            if used[j]:
                continue
            # choose which operand is shared: same match key, fits c_bits;
            # the remaining operands must fit m_bits.
            for s1 in (0, 1):
                for s2 in (0, 1):
                    cw1, csrc1, ck1 = l1.ops[s1]
                    cw2, _, ck2 = l2.ops[s2]
                    aw, asrc, _ = l1.ops[1 - s1]
                    bw, bsrc, _ = l2.ops[1 - s2]
                    if (ck1 == ck2 and cw1 <= c_bits and cw2 <= c_bits
                            and aw <= m_bits and bw <= m_bits):
                        pairs.append((asrc, bsrc, csrc1))
                        used[j] = True
                        found = True
                        break
                if found:
                    break
            if found:
                break
        if not found:
            return None
    return pairs


class SILVIAMuladd(SILVIA):
    """Factor-2 shared-operand MAD packing (paper sec. 2.2)."""

    name = "silvia_muladd"

    def __init__(self, m_bits: int = 8, c_bits: int = 8,
                 max_chain_len: int | None = None):
        self.m_bits = m_bits
        self.c_bits = c_bits
        self.n_max = bounds.muladd2_max_chain(m_bits, c_bits)
        if max_chain_len is not None:      # paper's MAX_CHAIN_LEN option
            self.n_max = min(self.n_max, max_chain_len)

    def get_candidates(self, ctx: BBContext):
        cands = []
        for t in _collect_trees(ctx, self.m_bits, self.c_bits):
            reads = []
            for leaf in t.leaves:
                reads.extend([leaf.ops[0][1], leaf.ops[1][1]])
            cands.append(Candidate(
                root=t.root_idx, covered=t.eqns, reads=tuple(reads),
                root_vars=(t.root_var,), meta=t))
        return cands

    def can_pack(self, tup: Tuple_, cand: Candidate, ctx: BBContext) -> bool:
        t1: Tree = tup.cands[0].meta
        t2: Tree = cand.meta
        if t1.shape != t2.shape or t1.out_dtype != t2.out_dtype:
            return False
        return _match_leaves(t1, t2, self.m_bits, self.c_bits) is not None

    def is_tuple_full(self, tup: Tuple_) -> bool:
        return len(tup.cands) == 2

    def tuple_viable(self, tup: Tuple_) -> bool:
        return False   # a lone MAD tree stays as-is (resource sharing note, 3.5.2)

    def pack_tuple(self, tup: Tuple_, ctx: BBContext) -> ir.PackedItem:
        t1: Tree = tup.cands[0].meta
        t2: Tree = tup.cands[1].meta
        pairs = _match_leaves(t1, t2, self.m_bits, self.c_bits)
        assert pairs is not None
        n = len(pairs)
        a_srcs = [p[0] for p in pairs]
        b_srcs = [p[1] for p in pairs]
        c_srcs = [p[2] for p in pairs]
        out_dtype = t1.out_dtype
        n_max, m_bits, c_bits = self.n_max, self.m_bits, self.c_bits

        def build(invals):
            a = invals[:n]
            b = invals[n:2 * n]
            c = invals[2 * n:]
            # Eq. 2 split: balanced segments, external adder tree (sec. 3.3)
            n_seg = -(-n // n_max)
            seg_len = -(-n // n_seg)
            pa_parts, pb_parts = [], []
            for s in range(0, n, seg_len):
                e = min(s + seg_len, n)
                pa, pb = prims.packed_muladd(
                    a[s:e], b[s:e], c[s:e], out_dtype=out_dtype,
                    m_bits=m_bits, c_bits=c_bits)
                pa_parts.append(pa)
                pb_parts.append(pb)
            p_a = sum(pa_parts[1:], pa_parts[0])
            p_b = sum(pb_parts[1:], pb_parts[0])
            return [p_a, p_b]

        return ir.PackedItem(
            build=build, in_vars=a_srcs + b_srcs + c_srcs,
            out_vars=[t1.root_var, t2.root_var],
            describe=f"muladd2 n={n}")


class SILVIAMul4(SILVIA):
    """Factor-4 4-bit multiplication packing (paper sec. 2.3)."""

    name = "silvia_mul4"

    def __init__(self, allow_partial_as_pairs: bool = False):
        self.allow_partial_as_pairs = allow_partial_as_pairs

    def get_candidates(self, ctx: BBContext):
        cands = []
        for t in _collect_trees(ctx, m_bits=4, c_bits=4):
            if len(t.leaves) != 1:     # mul-only packing
                continue
            leaf = t.leaves[0]
            cands.append(Candidate(
                root=t.root_idx, covered=t.eqns,
                reads=(leaf.ops[0][1], leaf.ops[1][1]),
                root_vars=(t.root_var,), meta=t))
        return cands

    def _shared_key(self, tup: Tuple_):
        """Shared-operand keys compatible with every member so far."""
        keys = None
        for c in tup.cands:
            leaf = c.meta.leaves[0]
            ks = {leaf.ops[0][2], leaf.ops[1][2]}
            keys = ks if keys is None else keys & ks
        return keys or set()

    def can_pack(self, tup: Tuple_, cand: Candidate, ctx: BBContext) -> bool:
        t1: Tree = tup.cands[0].meta
        t2: Tree = cand.meta
        if t1.shape != t2.shape or t1.out_dtype != t2.out_dtype:
            return False
        leaf = t2.leaves[0]
        return bool(self._shared_key(tup) & {leaf.ops[0][2], leaf.ops[1][2]})

    def is_tuple_full(self, tup: Tuple_) -> bool:
        return len(tup.cands) == 4

    def tuple_viable(self, tup: Tuple_) -> bool:
        return len(tup.cands) == 4

    def pack_tuple(self, tup: Tuple_, ctx: BBContext) -> ir.PackedItem:
        shared = sorted(self._shared_key(tup), key=str)[0]
        a_srcs, b_src, signs = [], None, []
        for c in tup.cands:
            leaf = c.meta.leaves[0]
            if leaf.ops[0][2] == shared:
                ci, ai = leaf.ops[0], leaf.ops[1]
            else:
                ci, ai = leaf.ops[1], leaf.ops[0]
            a_srcs.append(ai[1])
            if b_src is None:
                b_src = ci[1]
        out_dtypes = tuple(c.meta.out_dtype for c in tup.cands)

        def build(invals):
            a, b = invals[:4], invals[4]
            return prims.packed_mul4(a, b, out_dtypes=out_dtypes,
                                     a_signed=True, b_signed=True)

        return ir.PackedItem(
            build=build, in_vars=a_srcs + [b_src],
            out_vars=[c.root_vars[0] for c in tup.cands],
            describe="mul4")
