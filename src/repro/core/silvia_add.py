"""SILVIAAdd: pack independent narrow additions/subtractions into one
SIMD lane op (paper sec. 2.1 / 3).

Paper modes (48-bit DSP ALU): four12 / two24.
TPU modes   (32-bit i32 lane): four8 / two16 (see core/bounds.py).

Legality: the packed lanes compute wrapped `lane_bits` two's-complement sums.
A candidate is exact iff (a) its result provably fits the lane
(max operand width + 1 <= lane_bits), or (b) the original op already wraps at
the lane width (out dtype bits == lane_bits), mirroring the paper's
"operands up to 12/24 bits" constraint.
"""
from __future__ import annotations

import numpy as np

from repro.core import bounds, ir, prims
from repro.core.silvia import BBContext, Candidate, SILVIA, Tuple_

_ADD_PRIMS = {"add": False, "sub": True}


class SILVIAAdd(SILVIA):
    name = "silvia_add"

    def __init__(self, op_size: int = 8, inst: str = "both",
                 allow_partial: bool = True):
        assert op_size in (8, 16), "TPU lane modes: four8 (8) / two16 (16)"
        self.mode = bounds.ADD_MODES["four8" if op_size == 8 else "two16"]
        self.inst = inst
        self.allow_partial = allow_partial

    # -- candidate identification (paper sec. 3.1) --------------------------
    def get_candidates(self, ctx: BBContext):
        cands = []
        lane = self.mode.lane_bits
        for i, eqn in enumerate(ctx.eqns):
            name = eqn.primitive.name
            if name not in _ADD_PRIMS or eqn.effects:
                continue
            if self.inst != "both" and name != self.inst:
                continue
            out = eqn.outvars[0]
            if ir.is_drop_var(out):
                continue
            dt = np.dtype(out.aval.dtype)
            if dt.kind not in "iu":
                continue
            wx = ctx.widths.width_of(eqn.invars[0])
            wy = ctx.widths.width_of(eqn.invars[1])
            exact = max(wx.bits, wy.bits) + 1 <= lane
            wraps = ir.dtype_bits(dt) == lane
            if not (exact or wraps):
                continue
            cands.append(Candidate(
                root=i, covered=frozenset([i]),
                reads=(wx.value_src, wy.value_src),
                root_vars=(out,),
                meta=dict(sub=_ADD_PRIMS[name], shape=out.aval.shape,
                          out_dtype=dt.name)))
        return cands

    # -- operation-specific tuple validity (paper sec. 3.2.2) ---------------
    def can_pack(self, tup: Tuple_, cand: Candidate, ctx: BBContext) -> bool:
        m0 = tup.cands[0].meta
        return (m0["sub"] == cand.meta["sub"]
                and m0["shape"] == cand.meta["shape"])

    def is_tuple_full(self, tup: Tuple_) -> bool:
        return len(tup.cands) == self.mode.n_lanes

    def tuple_viable(self, tup: Tuple_) -> bool:
        return self.allow_partial and len(tup.cands) >= 2

    # -- tuple packing (paper sec. 3.3) --------------------------------------
    def pack_tuple(self, tup: Tuple_, ctx: BBContext) -> ir.PackedItem:
        cands = tup.cands
        k = len(cands)
        xs = [c.reads[0] for c in cands]
        ys = [c.reads[1] for c in cands]
        out_dtypes = tuple(c.meta["out_dtype"] for c in cands)
        sub = cands[0].meta["sub"]
        mode_name = self.mode.name
        lane_bits = self.mode.lane_bits

        def build(invals):
            bx, by = invals[:k], invals[k:]
            return prims.packed_add(bx, by, mode=mode_name,
                                    lane_bits=lane_bits, sub=sub,
                                    out_dtypes=out_dtypes)

        return ir.PackedItem(
            build=build, in_vars=xs + ys,
            out_vars=[c.root_vars[0] for c in cands],
            describe=f"{mode_name} x{k}")
