"""SILVIA base transformation pass -- paper Algorithm 1 on jaxpr BBs.

    C   <- getCandidates(BB)
    BB* <- BB
    for c in C: BB* <- moveUsesALAP(c, BB*)      # here: one global ALAP pass
    T   <- getTuples(C)                          # legality + canPack + full
    for T in T: BB* <- replaceTuple(T, packTuple(T), BB*)
    (then dead-code elimination)

Derived passes override `get_candidates`, `can_pack`, `is_tuple_full` and
`pack_tuple`, exactly mirroring the paper's class structure (sec. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.core import ir


@dataclasses.dataclass
class Candidate:
    """A packable pattern rooted at one equation.

    covered:   indices of ALL eqns consumed by packing this candidate
               (a single add for SILVIAAdd; a whole MAD tree for SILVIAMuladd).
    reads:     vars (or literals) the packed implementation will read
               (narrow value sources -- the original converts become dead).
    root_vars: output vars whose uses must be rewired to the packed results.
    meta:      pass-specific payload (widths, leaves, shared operands ...).
    """
    root: int
    covered: frozenset
    reads: tuple
    root_vars: tuple
    meta: Any = None


@dataclasses.dataclass
class Tuple_:
    cands: list
    last_def: int      # max position of any read's definition
    first_use: int     # min position of any external use of any root var
    defs: set = dataclasses.field(default_factory=set)   # vars defined by
    reads: set = dataclasses.field(default_factory=set)  # covered eqns


class BBContext:
    """Analysis state for one basic block (one jaxpr body).

    `eqns` is a schedule of ITEMS (ir.EqnItem / ir.PackedItem) rather than
    raw jaxpr equations: a packing rewrite splices packed items in via
    `patch()` and the analysis state (def/use, widths) is repaired locally,
    so one context survives the whole pass pipeline and the rewritten BB is
    re-emitted (retraced) only once at the end.
    """

    def __init__(self, closed):
        self.closed = closed
        self.eqns = ir.alap_schedule(ir.items_of(closed),
                                     closed.jaxpr.outvars)
        self.outvars = closed.jaxpr.outvars
        self.def_idx, self.use_idxs = ir.defs_uses(self.eqns, self.outvars)
        self.widths = ir.WidthAnalysis(self.eqns, self.outvars)
        self.patches = 0        # in-place packing rewrites applied

    @property
    def dirty(self) -> bool:
        """True when the schedule diverged from closed.jaxpr.eqns and the
        caller must emit_closed_jaxpr(closed, ctx.eqns) to materialize."""
        return self.patches > 0

    def _avail_vars(self) -> set:
        avail = set(self.def_idx)
        avail.update(v for v in self.closed.jaxpr.invars)
        avail.update(v for v in self.closed.jaxpr.constvars)
        return avail

    def patch(self, items: list) -> None:
        """Splice a rewritten (packed + DCE'd) item schedule in WITHOUT
        re-emitting the jaxpr: re-ALAP over the items, rebuild the (cheap)
        def/use maps, and rebind the width analysis pruning only memo
        entries whose vars died -- the incremental alternative to the old
        whole-BB invalidation (ROADMAP carried item)."""
        self.eqns = ir.alap_schedule(items, self.outvars)
        self.def_idx, self.use_idxs = ir.defs_uses(self.eqns, self.outvars)
        self.widths.rebind(self.eqns, self.outvars, self._avail_vars())
        self.patches += 1

    def pos_of_def(self, v) -> int:
        """Schedule position of v's defining eqn (-1 for invars/consts)."""
        if ir.is_literal(v):
            return -1
        return self.def_idx.get(v, -1)

    def last_def(self, reads: Sequence) -> int:
        return max([self.pos_of_def(v) for v in reads], default=-1)

    def first_external_use(self, root_vars: Sequence, covered: frozenset) -> int:
        first = ir.OUT_SENTINEL
        for v in root_vars:
            for u in self.use_idxs.get(v, []):
                if u == ir.OUT_SENTINEL or u not in covered:
                    first = min(first, u)
        return first

    def interval(self, cand: Candidate) -> tuple[int, int]:
        return (self.last_def(cand.reads),
                self.first_external_use(cand.root_vars, cand.covered))


class SILVIA:
    """Base pass.  run() applies Algorithm 1 to one ClosedJaxpr."""

    name = "silvia"
    # paper sec. 3.5.1 leaves II-aware tuple filtering to future work;
    # setting filter_ii=True drops tuples whose super-node would create a
    # new critical cycle in a loop body (requires loop_info from the
    # enclosing scan -- supplied by the pass pipeline).
    filter_ii = False

    # -- hooks for derived passes (paper sec. 3: blue functions) ------------
    def get_candidates(self, ctx: BBContext) -> list[Candidate]:
        raise NotImplementedError

    def can_pack(self, tup: Tuple_, cand: Candidate, ctx: BBContext) -> bool:
        return True

    def is_tuple_full(self, tup: Tuple_) -> bool:
        raise NotImplementedError

    def tuple_viable(self, tup: Tuple_) -> bool:
        """Is a (possibly partial) tuple worth packing?  Default: >= 2."""
        return len(tup.cands) >= 2

    def pack_tuple(self, tup: Tuple_, ctx: BBContext) -> ir.PackedItem:
        raise NotImplementedError

    # -- Algorithm 1 ---------------------------------------------------------
    def get_tuples(self, cands: list[Candidate], ctx: BBContext) -> list[Tuple_]:
        """Greedy in-schedule-order grouping under (a) independence +
        (b) insertion-point existence + (c) operation-specific constraints.

        Interval intersection (last_def < first_use pairwise-merged) implies
        candidate independence (paper sec. 3.2.1)."""
        open_tuples: list[Tuple_] = []
        closed: list[Tuple_] = []
        used_eqns: set[int] = set()

        def defs_of(cand: Candidate) -> set:
            out = set()
            for i in cand.covered:
                for v in ctx.eqns[i].outvars:
                    if not ir.is_drop_var(v):
                        out.add(v)
            return out

        def reads_of(cand: Candidate) -> set:
            return {v for v in cand.reads if not ir.is_literal(v)}

        for cand in sorted(cands, key=lambda c: c.root):
            if cand.covered & used_eqns:
                continue
            last_def, first_use = ctx.interval(cand)
            if last_def >= first_use:
                continue  # no room even alone (pre-ALAP Fig. 4a situation)
            c_defs, c_reads = defs_of(cand), reads_of(cand)
            placed = False
            for tup in open_tuples:
                new_ld = max(tup.last_def, last_def)
                new_fu = min(tup.first_use, first_use)
                if new_ld >= new_fu:
                    continue  # no common insertion point
                # paper condition (a): candidates must not depend on each
                # other.  Interval intersection handles transitive paths;
                # DIRECT def->use between candidates is checked explicitly.
                if (c_reads & tup.defs) or (tup.reads & c_defs):
                    continue
                if not self.can_pack(tup, cand, ctx):
                    continue
                tup.cands.append(cand)
                tup.last_def, tup.first_use = new_ld, new_fu
                tup.defs |= c_defs
                tup.reads |= c_reads
                used_eqns |= cand.covered
                placed = True
                if self.is_tuple_full(tup):
                    open_tuples.remove(tup)
                    closed.append(tup)
                break
            if not placed:
                tup = Tuple_([cand], last_def, first_use, c_defs, c_reads)
                used_eqns |= cand.covered
                open_tuples.append(tup)
        closed.extend(t for t in open_tuples if self.tuple_viable(t))
        return closed

    def run_ctx(self, ctx: BBContext, loop_info=None) -> dict:
        """Apply Algorithm 1 against a shared BBContext, rewriting IN PLACE
        via ctx.patch() (no retrace).  Returns the stats dict; the caller
        checks ctx.dirty / ctx.patches to decide whether to re-emit.

        loop_info: optional (num_consts, num_carry) when this BB is a scan
        body -- enables the II-aware tuple filter (sec. 3.5.1)."""
        cands = self.get_candidates(ctx)
        stats = {"candidates": len(cands), "tuples": 0, "packed_ops": 0,
                 "ii_dropped": 0}
        if not cands:
            return stats
        tuples = self.get_tuples(cands, ctx)
        if tuples and self.filter_ii and loop_info is not None:
            tuples, dropped = self._filter_ii_tuples(tuples, ctx, ctx.closed,
                                                     loop_info)
            stats["ii_dropped"] = dropped
        if not tuples:
            return stats
        stats["tuples"] = len(tuples)
        stats["packed_ops"] = sum(len(t.cands) for t in tuples)
        # replaceTuple: splice packed items in at a valid insertion point,
        # drop covered eqns, then DCE.
        consumed: set[int] = set()
        inserts: dict[int, list[ir.PackedItem]] = {}
        for tup in tuples:
            item = self.pack_tuple(tup, ctx)
            pos = tup.first_use if tup.first_use != ir.OUT_SENTINEL else len(ctx.eqns)
            inserts.setdefault(pos, []).append(item)
            for c in tup.cands:
                consumed |= c.covered
        items: list = []
        for i, it in enumerate(ctx.eqns):
            for ins in inserts.get(i, []):
                items.append(ins)
            if i not in consumed:
                items.append(it)
        for ins in inserts.get(len(ctx.eqns), []):
            items.append(ins)
        ctx.patch(ir.dce_items(items, ctx.outvars))
        return stats

    def run(self, closed, loop_info=None, cache=None) -> tuple[Any, dict]:
        """Apply the pass to one ClosedJaxpr; returns (new_closed, stats).

        Compatibility wrapper over run_ctx for single-pass callers: builds
        (or fetches from `cache`, an ir.AnalysisCache) the BBContext, packs
        in place, and emits a fresh ClosedJaxpr only if this call packed
        something."""
        if cache is None:
            ctx = BBContext(closed)
        else:
            ctx = cache.get_or_build(closed.jaxpr, lambda: BBContext(closed))
        before = ctx.patches
        stats = self.run_ctx(ctx, loop_info=loop_info)
        if ctx.patches == before:
            return closed, stats
        return ir.emit_closed_jaxpr(closed, ctx.eqns), stats

    def _filter_ii_tuples(self, tuples, ctx, closed, loop_info):
        """Drop tuples whose packed super-node raises II_min (Fig. 5).

        The DDG is built over the ALAP-scheduled eqn order (ctx.eqns) with
        loop-carried distance-1 edges from scan carry outputs to carry
        inputs."""
        from repro.core import ddg as ddg_mod
        num_consts, num_carry = loop_info
        jaxpr = closed.jaxpr
        eqns = ctx.eqns
        n = len(eqns)
        lats = [1] * n
        edges = []
        for i, eqn in enumerate(eqns):
            for v in eqn.invars:
                if not ir.is_literal(v) and v in ctx.def_idx:
                    edges.append((ctx.def_idx[v], i, 0))
        for ci in range(num_carry):
            v_out = jaxpr.outvars[ci]
            if ir.is_literal(v_out) or v_out not in ctx.def_idx:
                continue
            v_in = jaxpr.invars[num_consts + ci]
            for u in ctx.use_idxs.get(v_in, []):
                if u != ir.OUT_SENTINEL:
                    edges.append((ctx.def_idx[v_out], u, 1))
        g = ddg_mod.DDG(lats, sorted(set(edges)))
        base_ii = g.ii_min()
        kept, dropped = [], 0
        for tup in tuples:
            group = sorted(set().union(*[c.covered for c in tup.cands]))
            if g.with_merged(group).ii_min() > base_ii:
                dropped += 1
            else:
                kept.append(tup)
        return kept, dropped
