"""Ops/Unit metric -- the paper's Table 1 headline metric.

"The operation density (Ops/Unit) is defined as the ratio between the number
of arithmetic operations and the number of functional units computing them,
at the IR level."

On our substrate an IR-level operation is a jaxpr equation; a packed
primitive equation is ONE functional unit computing k logical narrow ops
(its params record k).  Counting is recursive over sub-jaxprs (a rolled scan
body counts once, like a rolled loop in LLVM IR; unrolled compute unrolls the
count, exactly as HLS unrolling does in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from jax.extend import core as jex_core

from repro.core import prims

ClosedJaxpr = jex_core.ClosedJaxpr

_MUL_PRIMS = {"mul"}
_ADD_PRIMS = {"add", "sub"}


@dataclasses.dataclass
class OpCount:
    mul_ops: int = 0        # logical multiplications
    add_ops: int = 0        # logical additions/subtractions
    mul_units: int = 0      # units computing multiplications
    add_units: int = 0      # units computing additions
    packed_units: int = 0   # packed units (the "DSP count" analogue)
    madd_units: int = 0     # units computing both (packed MADs)

    @property
    def mul_density(self) -> float:
        u = self.mul_units
        return self.mul_ops / u if u else 0.0

    @property
    def add_density(self) -> float:
        u = self.add_units
        return self.add_ops / u if u else 0.0

    def merged(self, other: "OpCount") -> "OpCount":
        return OpCount(*[a + b for a, b in
                         zip(dataclasses.astuple(self),
                             dataclasses.astuple(other))])


def _iter_subjaxprs(eqn) -> Iterable[ClosedJaxpr]:
    for v in eqn.params.values():
        if isinstance(v, ClosedJaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, ClosedJaxpr):
                    yield x


def count_ops(closed: ClosedJaxpr, int_only: bool = True) -> OpCount:
    c = OpCount()
    for eqn in closed.jaxpr.eqns:
        name = eqn.primitive.name
        if eqn.primitive in prims.PACKED_PRIMS:
            k = prims.packed_op_counts(eqn)
            c.packed_units += 1
            c.mul_ops += k["mul"]
            c.add_ops += k["add"]
            if k["mul"]:
                c.mul_units += 1
            if k["add"] and not k["mul"]:
                c.add_units += 1
            if k["mul"] and k["add"]:
                c.madd_units += 1
            continue
        if name in _MUL_PRIMS or name in _ADD_PRIMS:
            import numpy as np
            dt = np.dtype(eqn.outvars[0].aval.dtype)
            if int_only and dt.kind not in "iu":
                continue
            if name in _MUL_PRIMS:
                c.mul_ops += 1
                c.mul_units += 1
            else:
                c.add_ops += 1
                c.add_units += 1
            continue
        for sub in _iter_subjaxprs(eqn):
            c = c.merged(count_ops(sub, int_only))
    return c


def density_report(before: OpCount, after: OpCount) -> dict:
    """Paper Table 1 row: Ops/Unit and unit counts, baseline vs SILVIA."""
    def units(c):
        return c.mul_units + c.add_units + c.madd_units
    return {
        "ops_per_unit_mul_baseline": round(before.mul_density, 2),
        "ops_per_unit_mul_silvia": round(after.mul_density, 2),
        "ops_per_unit_add_baseline": round(before.add_density, 2),
        "ops_per_unit_add_silvia": round(after.add_density, 2),
        "units_baseline": units(before),
        "units_silvia": units(after),
        "unit_reduction": round(1 - units(after) / units(before), 3)
        if units(before) else 0.0,
    }
