"""Standalone dead-code elimination over a ClosedJaxpr (paper sec. 3.4).

The SILVIA pass runs DCE over its item schedule internally; this module
exposes the same liveness logic as a jaxpr->jaxpr pass for reuse and tests.
"""
from __future__ import annotations

from jax.extend import core as jex_core

from repro.core import ir


def dce_closed_jaxpr(closed: jex_core.ClosedJaxpr) -> jex_core.ClosedJaxpr:
    items = ir.dce_items(ir.items_of(closed), closed.jaxpr.outvars)
    if len(items) == len(closed.jaxpr.eqns):
        return closed
    return ir.emit_closed_jaxpr(closed, items)
