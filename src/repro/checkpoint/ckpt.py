"""Checkpointing for fault-tolerant training.

Layout per step:

    <dir>/step_000123/
        index.json            tree structure + leaf manifest + metadata
        shard_h000.npz        this host's leaf arrays (flat key -> array)
        _COMMITTED            written LAST; restore ignores dirs without it

Properties needed at scale, all implemented here:

* **Atomicity**: writes go to `step_X.tmp/` and are renamed into place after
  the commit marker -- a preempted save can never be half-restored.
* **Elastic restore**: leaves are stored whole per host (single-host sim) or
  per shard with their index; `restore_checkpoint` reassembles and the
  caller re-shards onto WHATEVER mesh is current (device count may differ
  from save time -- jax.device_put with the new sharding handles the move).
* **Keep-last-k** garbage collection.
* **QTensor/quantized leaves** round-trip (pytrees of plain arrays).
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_COMMIT = "_COMMITTED"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = [f"leaf_{i:05d}" for i in range(len(flat))]
    return flat, paths, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    host_id: int = 0, keep: int = 3,
                    extra_meta: Optional[dict] = None) -> str:
    """Serialize `tree` (any pytree of arrays/scalars) atomically."""
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, paths, treedef = _flatten_with_paths(tree)
    arrays = {}
    manifest = []
    for key, leaf in zip(paths, flat):
        arr = np.asarray(jax.device_get(leaf))
        # numpy's npz cannot store ml_dtypes (bfloat16, float8, int4...);
        # store the raw bits as a uint view and encode the dtype in the key
        if arr.dtype.kind not in "biufc":   # ml_dtypes load back as void
            raw_dt = np.dtype(f"u{arr.dtype.itemsize}")
            arrays[f"{key}__{arr.dtype.name}"] = arr.view(raw_dt)
        else:
            arrays[key] = arr
        manifest.append({"key": key, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, f"shard_h{host_id:03d}.npz"), **arrays)
    if host_id == 0:
        # treedef string is informational; restore rebuilds from `like`
        # (proto serialization rejects custom nodes such as QTensor)
        try:
            treedef_repr = str(jax.tree_util.tree_structure(tree))
        except Exception:   # noqa: BLE001
            treedef_repr = None
        index = {
            "step": step,
            "treedef": treedef_repr,
            "manifest": manifest,
            "meta": extra_meta or {},
        }
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _committed_steps(ckpt_dir: str) -> list:
    """Committed step numbers, ascending.  Foreign step_* dirs (bad
    suffix) are skipped, never raised on -- a stray file in the ckpt dir
    must not take restore down with it."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _COMMIT)):
                try:
                    steps.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    warnings.warn(f"ignoring malformed checkpoint dir "
                                  f"{name!r} in {ckpt_dir}")
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def _read_meta(ckpt_dir: str, step: int) -> Optional[dict]:
    """The index.json meta of one committed step, or None (with a
    warning) when the index is missing/corrupt -- a damaged checkpoint
    degrades to "not restorable", it never crashes the restore path."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}", "index.json")
    try:
        with open(path) as f:
            doc = json.load(f)
        meta = doc["meta"]
    except (OSError, ValueError, KeyError, TypeError) as e:
        warnings.warn(f"checkpoint step {step} in {ckpt_dir} has a "
                      f"missing/corrupt index.json ({e}); skipping it")
        return None
    return meta


def load_meta(ckpt_dir: str, *, step: Optional[int] = None):
    """(extra_meta dict, step) of the latest (or given) committed
    checkpoint, or (None, None).  Readable BEFORE building a `like`
    template -- restore flows whose tree structure is described by the
    metadata (e.g. launch/resilience.py request snapshots) need it first.
    When no step is pinned and the newest committed checkpoint is
    damaged, earlier committed steps are tried (warn-and-fall-back)."""
    if step is not None:
        meta = _read_meta(ckpt_dir, step)
        return (None, None) if meta is None else (meta, step)
    for s in reversed(_committed_steps(ckpt_dir)):
        meta = _read_meta(ckpt_dir, s)
        if meta is not None:
            return meta, s
    return None, None


def restore_checkpoint(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
                       host_id: int = 0, shardings: Any = None):
    """Restore into the structure of `like` (a pytree template, e.g. from
    jax.eval_shape).  If `shardings` (matching pytree of NamedShardings) is
    given, leaves are placed onto the current mesh -- this is the elastic
    path: the mesh NOW may differ from the mesh at save time."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    data = np.load(os.path.join(d, f"shard_h{host_id:03d}.npz"))
    by_key = {}
    for k in data.files:
        if "__" in k:
            base, dt_name = k.rsplit("__", 1)
            import ml_dtypes  # noqa: F401  (registers the dtypes)
            by_key[base] = data[k].view(np.dtype(dt_name))
        else:
            by_key[k] = data[k]
    flat_like, paths, treedef = _flatten_with_paths(like)
    flat = []
    for key, leaf in zip(paths, flat_like):
        arr = by_key[key]
        want_dt = getattr(leaf, "dtype", arr.dtype)
        flat.append(jnp.asarray(arr, want_dt))
    tree = jax.tree_util.tree_unflatten(treedef, flat)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step


def _gc(ckpt_dir: str, keep: int):
    steps = _committed_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
