"""Sharded, atomic, resumable checkpointing."""
from repro.checkpoint.ckpt import (latest_step, load_meta,
                                   restore_checkpoint, save_checkpoint)

__all__ = ["latest_step", "load_meta", "restore_checkpoint",
           "save_checkpoint"]
