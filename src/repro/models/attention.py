"""Grouped-query attention with RoPE/M-RoPE, KV cache, and cross-attention.

Modes:
  full(x)                      -- causal self-attention over the sequence
                                  (training / prefill; optionally emits cache)
  decode(x_t, cache, pos)      -- one new token against a static-size cache
  cross(x, memory)             -- encoder-decoder cross attention (whisper)

The KV cache is a dict {k: [B, S_max, KV, D], v: ..., } with positions filled
up to `pos`; decode updates in place via dynamic_update_slice (functional).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import context as dctx
from repro.models import common
from repro.quant.qtensor import QTensor, qmatmul
from repro.models.config import ModelConfig


def _attn_tp():
    """Active serve-time tensor-parallel context for attention (set inside
    the engine's shard_map body; see distributed/context.py).  When
    active, projections compute only this shard's heads and the head
    outputs are all_gathered before the merged wo matmul -- collectives
    are exact concats, never partial-sum reductions, so sharded decode
    stays bit-identical to the single-device path."""
    tp = dctx.tp_current()
    return tp if tp is not None and tp.attn else None


def _tp_slice_cols(w, j, width: int):
    """Columns [j*width, (j+1)*width) of a dense or QTensor weight
    [..., K, N] (w4a8 packs two logical columns per stored word)."""
    if isinstance(w, QTensor):
        if w.fmt == "w4a8":
            assert width % 2 == 0, (width, "w4a8 needs even column slices")
            q = jax.lax.dynamic_slice_in_dim(
                w.q, j * (width // 2), width // 2, axis=w.q.ndim - 1)
        else:
            q = jax.lax.dynamic_slice_in_dim(w.q, j * width, width,
                                             axis=w.q.ndim - 1)
        scale = jax.lax.dynamic_slice_in_dim(w.scale, j * width, width,
                                             axis=w.scale.ndim - 1)
        return QTensor(q, scale, w.fmt)
    return jax.lax.dynamic_slice_in_dim(w, j * width, width, axis=w.ndim - 1)


def _tp_gather_heads(out):
    """all_gather the per-shard head outputs along the feature axis before
    the merged output projection (tiled: shard-major concat == the
    original head order, since shards own contiguous head blocks)."""
    tp = _attn_tp()
    if tp is None:
        return out
    return jax.lax.all_gather(out, tp.axis, axis=out.ndim - 1, tiled=True)


def init_attn(rng, cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    r = common.split_rngs(rng, 4)
    p = {
        "wq": common.dense_init(r[0], d, cfg.q_dim, dt),
        "wk": common.dense_init(r[1], d, cfg.kv_dim, dt),
        "wv": common.dense_init(r[2], d, cfg.kv_dim, dt),
        "wo": common.dense_init(r[3], cfg.q_dim, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def _project_q(p, x, cfg: ModelConfig):
    tp = _attn_tp()
    wq, bq = p["wq"], p.get("bq")
    h = cfg.n_heads
    if tp is not None:
        h = cfg.n_heads // tp.size
        j = jax.lax.axis_index(tp.axis)
        wq = _tp_slice_cols(wq, j, h * cfg.head_dim)
        if bq is not None:
            bq = jax.lax.dynamic_slice_in_dim(bq, j * h * cfg.head_dim,
                                              h * cfg.head_dim, axis=0)
    q = qmatmul(x, wq)
    if bq is not None:
        q = q + bq
    b, s, _ = q.shape
    return q.reshape(b, s, h, cfg.head_dim)


def _project_kv(p, x, cfg: ModelConfig):
    tp = _attn_tp()
    wk, wv = p["wk"], p["wv"]
    bk, bv = p.get("bk"), p.get("bv")
    kv = cfg.n_kv
    if tp is not None:
        kv = cfg.n_kv // tp.size
        j = jax.lax.axis_index(tp.axis)
        wk = _tp_slice_cols(wk, j, kv * cfg.head_dim)
        wv = _tp_slice_cols(wv, j, kv * cfg.head_dim)
        if bk is not None:
            sl = lambda b_: jax.lax.dynamic_slice_in_dim(
                b_, j * kv * cfg.head_dim, kv * cfg.head_dim, axis=0)
            bk, bv = sl(bk), sl(bv)
    k = qmatmul(x, wk)
    v = qmatmul(x, wv)
    if bk is not None:
        k, v = k + bk, v + bv
    b, s, _ = k.shape
    return (k.reshape(b, s, kv, cfg.head_dim),
            v.reshape(b, s, kv, cfg.head_dim))


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: [B,S,H,D], k: [B,T,KV,D] -> scores [B,KV,G,S,T] (G = H//KV)."""
    b, s, h, d = q.shape
    kv = k.shape[2]     # shape-driven, not cfg.n_kv: under serve TP the
    g = h // kv         # projections carry only this shard's head block
    q = q.reshape(b, s, kv, g, d)
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w, v, cfg: ModelConfig):
    """w: [B,KV,G,S,T], v: [B,T,KV,D] -> [B,S,H*D]."""
    b, kv, g, s, t = w.shape
    o = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return o.reshape(b, s, kv * g * o.shape[-1])


def _kv_quantize(t):
    """Per-position symmetric int8 quantization of a [B,S,KV,D] tensor:
    returns (int8 values, [B,S,KV] f32 scales)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-8
    q = jnp.round(t.astype(jnp.float32) / scale[..., None]
                  ).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _cache_insert(cache_t, scale_t, new, pos, quantized: bool):
    """Insert [B,1,KV,D] `new` at per-row positions into the cache."""
    if quantized:
        q, s = _kv_quantize(new)
        t = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache_t, q, pos)
        sc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(scale_t, s, pos)
        return t, sc
    t = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0, 0)))(cache_t, new, pos)
    return t, None


def _attn_chunked(q, k, v, srcpos, cfg: ModelConfig, q_chunk: int):
    """Causal attention with the query dim scanned in chunks: only a
    [B, KV, G, q_chunk, T] score block is ever live (flash-attention memory
    behaviour expressed at the XLA level)."""
    b, s, h, d = q.shape
    nc = s // q_chunk
    scale = 1.0 / np.sqrt(cfg.head_dim)
    q_c = jnp.moveaxis(q.reshape(b, nc, q_chunk, h, d), 1, 0)
    p_c = jnp.moveaxis(srcpos.reshape(b, nc, q_chunk), 1, 0)

    def body(_, inp):
        qi, pi = inp
        scores = _gqa_scores(qi, k, cfg) * scale      # [B,KV,G,qc,T]
        mask = pi[:, None, None, :, None] >= srcpos[:, None, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return None, _gqa_out(w, v, cfg)              # [B,qc,H*D]

    _, outs = jax.lax.scan(body, None, (q_c, p_c))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h * d)


def attn_full(p, x, cfg: ModelConfig, positions=None, causal: bool = True,
              return_cache: bool = False, cache_len: Optional[int] = None,
              kv_lengths=None):
    """Self attention over the full sequence (train / prefill).

    kv_lengths: optional [B] int32 per-row count of REAL source positions
    (non-causal / encoder use): keys at positions >= kv_lengths[b] are
    masked out of row b's softmax.  Masked weights are exact float zeros,
    so a right-padded batch attends bit-identically to an unpadded one --
    the invariant that lets the serve engine bucket ragged encoder
    lengths (variable-length whisper features) without perturbing any
    real position by a single ULP."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    if not cfg.learned_pos:   # whisper-style models use absolute embeddings
        q = common.apply_rope(q, positions, cfg.rope_theta, cfg.m_rope_sections)
        k = common.apply_rope(k, positions, cfg.rope_theta, cfg.m_rope_sections)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    srcpos = positions if positions.ndim == 2 else positions[0]
    if (cfg.attn_q_chunk and causal and s > cfg.attn_q_chunk
            and s % cfg.attn_q_chunk == 0):
        out = qmatmul(_tp_gather_heads(
            _attn_chunked(q, k, v, srcpos, cfg, cfg.attn_q_chunk)), p["wo"])
        if not return_cache:
            return out
        s_max = cache_len or s
        pad = s_max - s
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if cfg.serve_kv_dtype == "int8":
            kq, ks = _kv_quantize(kp)
            vq, vs = _kv_quantize(vp)
            return out, {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
        return out, {"k": kp, "v": vp}
    scores = _gqa_scores(q, k, cfg) * scale
    if causal:
        mask = srcpos[:, None, None, :, None] >= srcpos[:, None, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
    if kv_lengths is not None:
        valid = jnp.arange(s)[None, :] < kv_lengths[:, None]        # [B,T]
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = qmatmul(_tp_gather_heads(_gqa_out(w, v, cfg)), p["wo"])
    if not return_cache:
        return out
    s_max = cache_len or s
    pad = s_max - s
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.serve_kv_dtype == "int8":
        kq, ks = _kv_quantize(kp)
        vq, vs = _kv_quantize(vp)
        return out, {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
    return out, {"k": kp, "v": vp}


def _mask_inactive(new, old, active):
    """Keep `old` rows wherever active is False (slot not serving a
    request): inactive slots must not mutate their KV pages."""
    m = active.reshape((active.shape[0],) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def attn_decode(p, x_t, cache, pos, cfg: ModelConfig, active=None):
    """Decode C new tokens against the cache: x_t [B, C, d] (C=1 is the
    classic single-token step; C>1 is a chunked-prefill step); pos [B] int32
    position of the FIRST new token per row; active: optional [B] bool slot
    mask -- inactive rows leave their cache untouched.

    Returns (out [B,C,d], new_cache).  Token c of row b is written at cache
    position pos[b]+c and attends causally to positions <= pos[b]+c."""
    b, c = x_t.shape[:2]
    qpos = pos[:, None] + jnp.arange(c, dtype=pos.dtype)    # [B,C]
    if cfg.m_rope_sections is not None:
        posq = jnp.broadcast_to(qpos[None], (3, b, c))
    else:
        posq = qpos
    q = _project_q(p, x_t, cfg)
    k_t, v_t = _project_kv(p, x_t, cfg)
    if not cfg.learned_pos:
        q = common.apply_rope(q, posq, cfg.rope_theta, cfg.m_rope_sections)
        k_t = common.apply_rope(k_t, posq, cfg.rope_theta, cfg.m_rope_sections)
    # insert the C new rows at per-row positions pos..pos+C-1
    quantized = cfg.serve_kv_dtype == "int8"
    kc, ksc = _cache_insert(cache["k"], cache.get("k_s"), k_t, pos,
                            quantized)
    vc, vsc = _cache_insert(cache["v"], cache.get("v_s"), v_t, pos,
                            quantized)
    if active is not None:
        kc = _mask_inactive(kc, cache["k"], active)
        vc = _mask_inactive(vc, cache["v"], active)
        if quantized:
            ksc = _mask_inactive(ksc, cache["k_s"], active)
            vsc = _mask_inactive(vsc, cache["v_s"], active)
    if quantized:
        k = _kv_dequant(kc, ksc, x_t.dtype)
        v = _kv_dequant(vc, vsc, x_t.dtype)
        new_cache = {"k": kc, "v": vc, "k_s": ksc, "v_s": vsc}
    else:
        k, v = kc, vc
        new_cache = {"k": kc, "v": vc}
    scale = 1.0 / np.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, cfg) * scale      # [B,KV,G,C,T]
    t = k.shape[1]
    valid = jnp.arange(t)[None, None, :] <= qpos[:, :, None]   # [B,C,T]
    scores = jnp.where(valid[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x_t.dtype)
    out = qmatmul(_tp_gather_heads(_gqa_out(w, v, cfg)), p["wo"])
    return out, new_cache


def attn_cross(p, x, memory, cfg: ModelConfig, mem_kv=None, enc_lengths=None):
    """Cross attention (decoder -> encoder memory).  If mem_kv is given
    (precomputed at prefill), memory projection is skipped.

    enc_lengths: optional [B] int32 count of real encoder positions per
    row; memory positions >= enc_lengths[b] contribute exactly-zero
    softmax weight, so a cross-KV page right-padded to a bucket width is
    bit-identical to the unpadded computation (ragged encdec serving).
    A `len` leaf stored in mem_kv by prefill serves as the default, so
    the decode path picks the mask up from the slot cache for free.
    Rows with length 0 (inactive slots) get a uniform finite softmax --
    never NaN -- and their output is discarded by the slot mask."""
    q = _project_q(p, x, cfg)
    if mem_kv is None:
        k, v = _project_kv(p, memory, cfg)
    else:
        k, v = mem_kv["k"], mem_kv["v"]
        if enc_lengths is None:
            enc_lengths = mem_kv.get("len")
    scale = 1.0 / np.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, cfg) * scale
    if enc_lengths is not None:
        valid = jnp.arange(k.shape[1])[None, :] < enc_lengths[:, None]
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return qmatmul(_tp_gather_heads(_gqa_out(w, v, cfg)), p["wo"])


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    shape = (batch, s_max, cfg.n_kv, cfg.head_dim)
    if cfg.serve_kv_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    dt = dtype or jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
