"""Top-level language models: init / forward / prefill / decode per family.

All families share the skeleton:

    embed (or frontend-stub embeddings) -> scan(blocks) -> norm -> lm_head

with layer params stacked on a leading axis and the stack run under
jax.lax.scan (optionally remat'd), so jaxpr/HLO size is depth-independent.

Caches are pytrees stacked over the scan axis; decode threads them through
the same scan.  Whisper (encdec) runs two scans and carries cross-attention
KV in the cache.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import blocks, common, slot_state, ssm
from repro.models.config import ModelConfig
from repro.quant.qtensor import qmatmul

BLOCK_FNS = {
    "dense": (blocks.init_dense_block, blocks.dense_block),
    "vlm": (blocks.init_dense_block, blocks.dense_block),
    "moe": (blocks.init_moe_block, blocks.moe_block),
    "ssm": (blocks.init_ssm_block, blocks.ssm_block),
    "hybrid": (blocks.init_hybrid_block, blocks.hybrid_block),
}


def n_scan_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.hybrid.period == 0
        return cfg.n_layers // cfg.hybrid.period
    return cfg.n_layers


def _stacked_init(rng, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig, max_seq: int = 4096):
    r = common.split_rngs(rng, 6)
    dt = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {}
    p["embed"] = common.embed_init(r[0], cfg.vocab, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(r[1], cfg.d_model, cfg.vocab, dt)
    p["final_norm"] = common.norm_init(cfg.d_model, cfg.norm)
    if cfg.learned_pos:
        p["pos_embed"] = common.embed_init(r[2], max_seq, cfg.d_model, dt)

    if cfg.family == "encdec":
        p["enc"] = _stacked_init(r[3], cfg.n_layers,
                                 lambda k: blocks.init_enc_block(k, cfg))
        p["enc_norm"] = common.norm_init(cfg.d_model, cfg.norm)
        p["enc_pos"] = common.embed_init(r[5], max_seq, cfg.d_model, dt)
        nd = cfg.n_decoder_layers or cfg.n_layers
        p["dec"] = _stacked_init(r[4], nd,
                                 lambda k: blocks.init_dec_block(k, cfg))
    else:
        init_fn, _ = BLOCK_FNS[cfg.family]
        p["blocks"] = _stacked_init(r[3], n_scan_units(cfg),
                                    lambda k: init_fn(k, cfg))
    return p


def _lm_head(p, x, cfg: ModelConfig):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return qmatmul(x, w).astype(jnp.float32)


def _embed(p, tokens_or_embeds, cfg: ModelConfig):
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        return jnp.take(p["embed"], tokens_or_embeds, axis=0)
    # frontend stub: precomputed frame/patch embeddings
    return tokens_or_embeds.astype(jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# decoder-only forward (train) / prefill / decode
# ---------------------------------------------------------------------------

def forward(params, inputs, cfg: ModelConfig, *, remat: bool = True,
            positions=None):
    """inputs: [B,S] int tokens or [B,S,d] stub embeddings -> logits, aux."""
    if cfg.family == "encdec":
        return encdec_forward(params, inputs, cfg, remat=remat)
    x = _embed(params, inputs, cfg)
    if cfg.learned_pos:
        x = x + params["pos_embed"][None, :x.shape[1], :]
    if cfg.m_rope_sections is not None and positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, b, s))
    _, block_fn = BLOCK_FNS[cfg.family]

    def body(carry, layer_params):
        h, aux = carry
        h2, _, aux_i = block_fn(layer_params, h, cfg, mode="train",
                                positions=positions)
        return (h2, aux + aux_i), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return _lm_head(params, x, cfg), aux


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               s_enc: Optional[int] = None):
    """Stacked per-scan-unit cache pytree."""
    n = n_scan_units(cfg)

    def one(_):
        if cfg.family in ("dense", "vlm", "moe"):
            return attn_mod.init_cache(cfg, batch, s_max)
        if cfg.family == "ssm":
            return ssm.init_ssm_state(cfg, batch)
        if cfg.family == "hybrid":
            return {
                "mamba": jax.tree_util.tree_map(
                    lambda t: jnp.broadcast_to(
                        t, (cfg.hybrid.period - 1,) + t.shape),
                    ssm.init_ssm_state(cfg, batch)),
                "attn": attn_mod.init_cache(cfg, batch, s_max),
            }
        if cfg.family == "encdec":
            return {
                "self": attn_mod.init_cache(cfg, batch, s_max),
                "cross": {
                    "k": jnp.zeros((batch, s_enc or s_max, cfg.n_kv,
                                    cfg.head_dim), jnp.dtype(cfg.dtype)),
                    "v": jnp.zeros((batch, s_enc or s_max, cfg.n_kv,
                                    cfg.head_dim), jnp.dtype(cfg.dtype)),
                    # real encoder frames per row; attn_cross masks the
                    # padded tail so ragged enc lengths share one page shape
                    "len": jnp.zeros((batch,), jnp.int32),
                },
            }
        raise ValueError(cfg.family)

    if cfg.family == "encdec":
        n = cfg.n_decoder_layers or cfg.n_layers
    unit = one(None)
    return jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), unit)


def prefill(params, inputs, cfg: ModelConfig, cache_len: int,
            positions=None, last_positions=None, enc_lengths=None,
            enc_pad=None):
    """Run the prompt, return (last-position logits, cache).

    last_positions: optional [B] int32 -- per-row index of the last REAL
    prompt token (for right-padded ragged batches; the serve engine pads
    prompts up to a shape bucket).  Default: the final column.
    enc_lengths / enc_pad (encdec only): per-row real encoder frame
    counts and the static cross-KV page width to pad to."""
    if cfg.family == "encdec":
        return encdec_prefill(params, inputs, cfg, cache_len,
                              last_positions=last_positions,
                              enc_lengths=enc_lengths, enc_pad=enc_pad)
    x = _embed(params, inputs, cfg)
    if cfg.learned_pos:
        x = x + params["pos_embed"][None, :x.shape[1], :]
    if cfg.m_rope_sections is not None and positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, b, s))
    _, block_fn = BLOCK_FNS[cfg.family]
    # per-row real lengths: attention masks right-padding causally, but
    # SSM state is sequential -- padded steps must become identity
    # updates.  Always materialized so every prefill (static generate()
    # and the engine's padded prompt buckets alike) runs ssd_forward on
    # the same FIXED chunk grid -- the bit-exactness precondition
    if last_positions is None:
        lengths = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    else:
        lengths = last_positions + 1

    def body(h, layer_params):
        h2, cache, _ = block_fn(layer_params, h, cfg, mode="prefill",
                                positions=positions, cache_len=cache_len,
                                lengths=lengths)
        return h2, cache

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if last_positions is None:
        x_last = x[:, -1:, :]
    else:
        x_last = x[jnp.arange(x.shape[0]), last_positions][:, None, :]
    return _lm_head(params, x_last, cfg), caches


def decode_step(params, token_t, cache, pos, cfg: ModelConfig, active=None):
    """token_t: [B,C] int (or [B,C,d] stub embed); pos: [B] int32 position
    of the first new token per row; active: optional [B] bool slot mask --
    inactive rows compute but neither mutate their cache nor (at the caller)
    contribute sampled tokens.  C=1 is the serving decode step; C>1 is a
    chunked-prefill step over the same cache layout.

    Returns (logits [B,C,V], new_cache).  Every family has a masked state
    update (attention: masked KV insert; SSM: masked {ssm, conv} state;
    encdec: masked self-KV, read-only cross-KV), so inactive slots are
    bit-identical across the step for any registered family
    (models/slot_state.py; property-tested in tests/test_slot_state.py)."""
    if cfg.family == "encdec":
        return encdec_decode_step(params, token_t, cache, pos, cfg,
                                  active=active)
    x = _embed(params, token_t, cfg)
    if cfg.learned_pos:
        qpos = pos[:, None] + jnp.arange(x.shape[1], dtype=pos.dtype)
        x = x + jnp.take(params["pos_embed"], qpos, axis=0)
    _, block_fn = BLOCK_FNS[cfg.family]

    def body(h, xs):
        layer_params, layer_cache = xs
        h2, new_cache, _ = block_fn(layer_params, h, cfg, mode="decode",
                                    cache=layer_cache, pos=pos,
                                    active=active)
        return h2, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], cache))
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return _lm_head(params, x, cfg), new_caches


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, embeds, cfg: ModelConfig, lengths=None):
    """lengths: optional [B] int32 real-frame counts; padded frames are
    masked out of every encoder self-attention, so real positions of a
    right-padded batch are bit-identical to an unpadded encode."""
    x = embeds.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos"][None, :x.shape[1], :]

    def body(h, layer_params):
        return blocks.enc_block(layer_params, h, cfg, lengths=lengths), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return common.norm_apply(x, params["enc_norm"], cfg.norm, cfg.norm_eps)


def encdec_forward(params, inputs, cfg: ModelConfig, *, remat: bool = True):
    """inputs: (audio_embeds [B,S_enc,d], dec_tokens [B,S_dec])."""
    audio, dec_tokens = inputs
    memory = encode(params, audio, cfg)
    x = jnp.take(params["embed"], dec_tokens, axis=0)
    x = x + params["pos_embed"][None, :x.shape[1], :]

    def body(carry, layer_params):
        h, = carry
        h2, _, _ = blocks.dec_block(layer_params, h, cfg, memory=memory,
                                    mode="train")
        return (h2,), None

    if remat:
        body = jax.checkpoint(body)
    (x,), _ = jax.lax.scan(body, (x,), params["dec"])
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return _lm_head(params, x, cfg), jnp.float32(0.0)


def encdec_prefill(params, inputs, cfg: ModelConfig, cache_len: int,
                   last_positions=None, enc_lengths=None, enc_pad=None):
    audio, dec_tokens = inputs
    memory = encode(params, audio, cfg, lengths=enc_lengths)
    x = jnp.take(params["embed"], dec_tokens, axis=0)
    x = x + params["pos_embed"][None, :x.shape[1], :]

    def body(h, layer_params):
        h2, cache, _ = blocks.dec_block(layer_params, h, cfg, memory=memory,
                                        mode="prefill", cache_len=cache_len,
                                        enc_lengths=enc_lengths,
                                        enc_pad=enc_pad)
        return h2, cache

    x, caches = jax.lax.scan(body, x, params["dec"])
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if last_positions is None:
        x_last = x[:, -1:, :]
    else:
        x_last = x[jnp.arange(x.shape[0]), last_positions][:, None, :]
    return _lm_head(params, x_last, cfg), caches


def encdec_decode_step(params, token_t, cache, pos, cfg: ModelConfig,
                       active=None):
    x = jnp.take(params["embed"], token_t, axis=0)
    x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None, :]

    def body(h, xs):
        layer_params, layer_cache = xs
        h2, new_cache, _ = blocks.dec_block(layer_params, h, cfg, memory=None,
                                            mode="decode", cache=layer_cache,
                                            pos=pos, active=active)
        return h2, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["dec"], cache))
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return _lm_head(params, x, cfg), new_caches


# ---------------------------------------------------------------------------
# embedding method (serve `embed`)
# ---------------------------------------------------------------------------

def embed_pool(params, inputs, cfg: ModelConfig, last_positions=None,
               enc_lengths=None):
    """Final-hidden-state embedding of a prompt: run the stack exactly as
    prefill does (per-token MoE routing, SSM identity updates on padded
    rows, masked encoder frames) and masked-mean-pool the post-final-norm
    hidden states over the real positions, in float32.

    Riding the prefill code path is what makes embeddings batch-
    composition invariant: a request's vector is bit-identical whatever
    its batch mates or padding, the same invariant the engine's token
    bit-exactness tests rest on.  Returns [B, d_model] float32; no KV is
    materialized (the caches the blocks emit are dropped, so XLA DCEs
    the page writes)."""
    if cfg.family == "encdec":
        audio, dec_tokens = inputs
        memory = encode(params, audio, cfg, lengths=enc_lengths)
        x = jnp.take(params["embed"], dec_tokens, axis=0)
        x = x + params["pos_embed"][None, :x.shape[1], :]

        def body(h, layer_params):
            h2, _, _ = blocks.dec_block(layer_params, h, cfg, memory=memory,
                                        mode="prefill",
                                        cache_len=x.shape[1],
                                        enc_lengths=enc_lengths)
            return h2, None

        x, _ = jax.lax.scan(body, x, params["dec"])
    else:
        x = _embed(params, inputs, cfg)
        if cfg.learned_pos:
            x = x + params["pos_embed"][None, :x.shape[1], :]
        positions = None
        if cfg.m_rope_sections is not None:
            b, s = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(s)[None, None, :],
                                         (3, b, s))
        if last_positions is None:
            lengths = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
        else:
            lengths = last_positions + 1
        _, block_fn = BLOCK_FNS[cfg.family]

        def body(h, layer_params):
            h2, _, _ = block_fn(layer_params, h, cfg, mode="prefill",
                                positions=positions, cache_len=x.shape[1],
                                lengths=lengths)
            return h2, None

        x, _ = jax.lax.scan(body, x, params["blocks"])
    x = common.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    b, s = x.shape[:2]
    if last_positions is None:
        lengths = jnp.full((b,), s, jnp.int32)
    else:
        lengths = last_positions + 1
    mask = (jnp.arange(s)[None, :] < lengths[:, None]).astype(jnp.float32)
    xf = x.astype(jnp.float32) * mask[:, :, None]
    return xf.sum(axis=1) / lengths[:, None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# slot-state registry (models/slot_state.py)
# ---------------------------------------------------------------------------
# The serve engine builds, slices, scatters and compacts per-slot decode
# state through these registrations; axis layout is probed from init_cache,
# so a family only ever declares its builder.  Chunked prefill is limited
# to pure-KV families: SSM/hybrid state updates are sequential and encdec
# prefill must run the encoder, so pushing their prompts through the decode
# path C tokens at a time would change the floating-point reduction order
# (or skip the encoder) and lose bit-exactness against the static path.
for _fam in ("dense", "vlm", "moe"):
    slot_state.register(_fam, init_cache)
for _fam in ("ssm", "hybrid", "encdec"):
    slot_state.register(_fam, init_cache, prefill_chunkable=False)
