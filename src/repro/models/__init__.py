"""Model zoo: functional-JAX implementations of the assigned architectures."""
from repro.models import attention, blocks, common, lm, mlp, ssm
from repro.models.config import (HybridConfig, ModelConfig, MoEConfig,
                                 SSMConfig)

__all__ = ["HybridConfig", "ModelConfig", "MoEConfig", "SSMConfig",
           "attention", "blocks", "common", "lm", "mlp", "ssm"]
