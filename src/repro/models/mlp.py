"""Feed-forward layers: SwiGLU/GELU dense MLPs and top-k MoE.

MoE uses sort-based (megablocks-style) dispatch: token->expert assignments
are sorted by expert id, gathered into fixed-capacity expert batches
(capacity factor -> token dropping, standard practice), processed by an
expert-batched einsum whose expert dimension is sharded over the `model`
mesh axis (expert parallelism -- GSPMD inserts the all-to-all style
resharding between token-sharded and expert-sharded layouts), and
scatter-combined weighted by router probabilities.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.quant.qtensor import QTensor, qmatmul
from repro.models.config import ModelConfig, MoEConfig


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    r = common.split_rngs(rng, 3)
    if cfg.activation == "swiglu":
        return {"wi": common.dense_init(r[0], d, f, dt),
                "wg": common.dense_init(r[1], d, f, dt),
                "wo": common.dense_init(r[2], f, d, dt)}
    return {"wi": common.dense_init(r[0], d, f, dt),
            "bi": jnp.zeros((f,), dt),
            "wo": common.dense_init(r[2], f, d, dt),
            "bo": jnp.zeros((d,), dt)}


def mlp(p, x, cfg: ModelConfig):
    if cfg.activation == "swiglu":
        return qmatmul(jax.nn.silu(qmatmul(x, p["wg"])) * qmatmul(x, p["wi"]),
                       p["wo"])
    return qmatmul(jax.nn.gelu(qmatmul(x, p["wi"]) + p["bi"]), p["wo"]) + p["bo"]


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = jnp.dtype(cfg.dtype)
    r = common.split_rngs(rng, 4)
    scale = 1.0 / jnp.sqrt(d)

    def stack(rng_, d_in, d_out, sc):
        return (jax.random.normal(rng_, (e, d_in, d_out), jnp.float32) * sc
                ).astype(dt)

    return {
        "router": common.dense_init(r[0], d, e, jnp.float32),
        "wi": stack(r[1], d, f, scale),
        "wg": stack(r[2], d, f, scale),
        "wo": stack(r[3], f, d, 1.0 / jnp.sqrt(f)),
    }


def _emm(xe, w):
    """Expert-batched matmul ([E,C,*] x [E,*,*]), QTensor-aware."""
    if isinstance(w, QTensor):
        return qmatmul(xe, w)
    return jnp.einsum("ecd,edf->ecf", xe, w)


def _dispatch_combine(xt, top_e, top_p, p, cfg, cap):
    """Sort-based dispatch over ONE token group.

    xt: [T, d]; top_e/top_p: [T, k].  Returns [T, d]."""
    m: MoEConfig = cfg.moe
    t, d = xt.shape
    e, k = m.n_experts, m.top_k
    flat_e = top_e.reshape(-1)                               # [T*k]
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)                              # stable
    se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]
    same = jnp.cumsum(jnp.ones_like(se), axis=0) - 1
    grp_start = jnp.searchsorted(se, jnp.arange(e))          # [E]
    slot = same - grp_start[se]                              # rank in group
    keep = slot < cap
    dest = se * cap + jnp.where(keep, slot, 0)               # [T*k]
    buf = jnp.zeros((e * cap, d), xt.dtype)
    src = xt[stok] * keep[:, None].astype(xt.dtype)
    buf = buf.at[dest].add(src)                              # unique dests
    ein = buf.reshape(e, cap, d)
    # expert ffn (E sharded over `model` -> expert parallelism)
    h = jax.nn.silu(_emm(ein, p["wg"])) * _emm(ein, p["wi"])
    eout = _emm(h, p["wo"]).reshape(e * cap, d)
    contrib = eout[dest] * (sp * keep).astype(xt.dtype)[:, None]
    return jnp.zeros((t, d), xt.dtype).at[stok].add(contrib)


def moe(p, x, cfg: ModelConfig, per_token: bool = False):
    """x: [B, S, d] -> ([B, S, d], aux_loss scalar).

    per_token=True (serving: prefill/decode) routes every token dropless
    via a dense one-hot combine: all experts run on all tokens and each
    token keeps its top-k, so a token's output depends only on that token.
    Capacity-factor dropping is a training throughput device; batch-coupled
    dropping would make generations depend on which other requests share
    the batch, which breaks the serve engine's slot-packing exactness
    (engine output must be bit-identical to a solo run of the same
    request).  The E/k x compute overhead is the price of exactness at
    smoke scale; a production path would gather the k expert slices per
    token instead."""
    m: MoEConfig = cfg.moe
    if per_token:
        b, s, d = x.shape
        t = b * s
        xt = x.reshape(t, d)
        logits = (xt.astype(jnp.float32) @ p["router"])      # [T, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], m.n_experts,
                                     dtype=jnp.float32), axis=0)
        aux = m.n_experts * jnp.sum(me * ce)
        # gate[t, e] = routing weight iff e is one of t's top-k (distinct)
        gate = jnp.zeros((t, m.n_experts), xt.dtype)
        gate = gate.at[jnp.arange(t)[:, None], top_e].set(
            top_p.astype(xt.dtype))
        xe = jnp.broadcast_to(xt[None], (m.n_experts, t, d))
        h = jax.nn.silu(_emm(xe, p["wg"])) * _emm(xe, p["wi"])
        eout = _emm(h, p["wo"])                              # [E, T, d]
        yt = jnp.einsum("etd,te->td", eout, gate)
        return yt.reshape(b, s, d), aux
    if m.dispatch == "shard_map" and not isinstance(p["wi"], QTensor):
        from repro.distributed import context
        ctx = context.current()
        if ctx is not None:
            return moe_shard_map(p, x, cfg, *ctx)
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    if m.dispatch == "grouped" and t >= m.dispatch_groups > 1 \
            and t % m.dispatch_groups == 0:
        # GShard-style: dispatch within fixed token groups so the argsort
        # and capacity bookkeeping stay LOCAL to a data shard; only the
        # expert exchange itself crosses devices (all-to-all)
        g = m.dispatch_groups
        tg = t // g
        cap = int(m.capacity_factor * k * tg / e) + 1
        yt = jax.vmap(
            lambda xg, eg, pg: _dispatch_combine(xg, eg, pg, p, cfg, cap)
        )(xt.reshape(g, tg, d), top_e.reshape(g, tg, k),
          top_p.reshape(g, tg, k))
        return yt.reshape(b, s, d), aux

    cap = int(m.capacity_factor * k * t / e) + 1
    yt = _dispatch_combine(xt, top_e, top_p, p, cfg, cap)
    return yt.reshape(b, s, d), aux


def moe_shard_map(p, x, cfg: ModelConfig, mesh, dp_axes, model_axis):
    """Explicitly-collective MoE (Megatron/GShard style) under shard_map.

    Why: under pure GSPMD the data-dependent scatter-adds of the dispatch
    partition as replicate+all-reduce of the FULL [E*cap, d] buffers --
    measured at ~13 TB/chip-step on arctic-480b train (EXPERIMENTS §Perf A).
    Inside shard_map every scatter is shard-local; the only collectives are

      * all_gather of the (FSDP-sharded) expert weights over the dp axes,
      * one psum over the model axis to combine expert outputs.

    Layout: tokens sharded over dp (replicated over model); experts
    block-assigned to model shards.  Capacity is per-dp-shard (same token
    dropping semantics as grouped dispatch with G = |dp|)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    e = m.n_experts
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    e_loc = e // mesh.shape[model_axis]
    assert e_loc * mesh.shape[model_axis] == e, (e, model_axis)

    def local_fn(wi, wg, wo, router, xl):
        # wi/wg: [E_loc, d/|dp|, F]; wo: [E_loc, F, d/|dp|] (FSDP-sharded)
        for ax in dp_axes:
            wi = jax.lax.all_gather(wi, ax, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, ax, axis=2, tiled=True)
        bl, sl, _ = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        logits = xt.astype(jnp.float32) @ router            # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32),
                      axis=0)
        aux = e * jnp.sum(me * ce)
        for ax in dp_axes:
            aux = jax.lax.pmean(aux, ax)

        cap = int(m.capacity_factor * m.top_k * tl / e) + 1
        # local sort-based dispatch (identical math to the global path)
        flat_e = top_e.reshape(-1)
        flat_p = top_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), m.top_k)
        order = jnp.argsort(flat_e)
        se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]
        same = jnp.cumsum(jnp.ones_like(se)) - 1
        grp_start = jnp.searchsorted(se, jnp.arange(e))
        slot = same - grp_start[se]
        keep = slot < cap
        dest = se * cap + jnp.where(keep, slot, 0)
        buf = jnp.zeros((e * cap, d), xl.dtype)
        buf = buf.at[dest].add(xt[stok] * keep[:, None].astype(xl.dtype))
        ein = buf.reshape(e, cap, d)
        # this model-shard computes only ITS experts
        j = jax.lax.axis_index(model_axis)
        ein_loc = jax.lax.dynamic_slice_in_dim(ein, j * e_loc, e_loc, 0)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein_loc, wg)) * \
            jnp.einsum("ecd,edf->ecf", ein_loc, wi)
        eout_loc = jnp.einsum("ecf,efd->ecd", h, wo)         # [E_loc,cap,d]
        # pad back to the global expert axis, combine, then psum partials
        eout = jnp.zeros((e, cap, d), xl.dtype)
        eout = jax.lax.dynamic_update_slice_in_dim(
            eout, eout_loc.astype(xl.dtype), j * e_loc, 0)
        flat_out = eout.reshape(e * cap, d)[dest]
        contrib = flat_out * (sp * keep).astype(xl.dtype)[:, None]
        yt = jnp.zeros((tl, d), xl.dtype).at[stok].add(contrib)
        yt = jax.lax.psum(yt, model_axis)
        return yt.reshape(bl, sl, d), aux

    wi_spec = P(model_axis, dp, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(wi_spec, wi_spec, P(model_axis, None, dp), P(None, None),
                  P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    return fn(p["wi"], p["wg"], p["wo"], p["router"], x)
