"""Residual block compositions per architecture family.

Every family defines a homogeneous "scan unit" so the layer stack runs under
one jax.lax.scan with params stacked on a leading axis (keeps the HLO size
O(1) in depth -- essential for the 80-layer / 480B dry-runs):

  dense / vlm       1 unit = pre-norm attn + pre-norm MLP
  moe               1 unit = pre-norm attn + MoE (+ parallel dense FFN for
                    arctic's "dense residual")
  ssm (mamba2)      1 unit = pre-norm SSD mixer (no MLP)
  hybrid (jamba)    1 unit = `period`-layer super-block: mamba mixers with
                    one attention at `attn_index`; alternating dense/MoE FFN
  encdec (whisper)  encoder unit (bidirectional attn + GELU MLP) and
                    decoder unit (causal self-attn + cross-attn + GELU MLP)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, mlp, ssm
from repro.models.config import ModelConfig


def _norm(cfg, x, p):
    return common.norm_apply(x, p, cfg.norm, cfg.norm_eps)


def _norm_init(cfg):
    return common.norm_init(cfg.d_model, cfg.norm)


# ---------------------------------------------------------------------------
# dense / vlm unit
# ---------------------------------------------------------------------------

def init_dense_block(rng, cfg: ModelConfig):
    r = common.split_rngs(rng, 2)
    return {"ln1": _norm_init(cfg), "attn": attn.init_attn(r[0], cfg),
            "ln2": _norm_init(cfg), "mlp": mlp.init_mlp(r[1], cfg)}


def dense_block(p, x, cfg, *, mode="train", cache=None, pos=None,
                positions=None, cache_len=None, active=None, lengths=None):
    h = _norm(cfg, x, p["ln1"])
    if mode == "decode":
        a, new_cache = attn.attn_decode(p["attn"], h, cache, pos, cfg,
                                        active=active)
    elif mode == "prefill":
        a, new_cache = attn.attn_full(p["attn"], h, cfg, positions,
                                      return_cache=True, cache_len=cache_len)
    else:
        a, new_cache = attn.attn_full(p["attn"], h, cfg, positions), None
    x = x + a
    x = x + mlp.mlp(p["mlp"], _norm(cfg, x, p["ln2"]), cfg)
    return x, new_cache, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# moe unit (arctic / granite)
# ---------------------------------------------------------------------------

def init_moe_block(rng, cfg: ModelConfig):
    r = common.split_rngs(rng, 3)
    p = {"ln1": _norm_init(cfg), "attn": attn.init_attn(r[0], cfg),
         "ln2": _norm_init(cfg), "moe": mlp.init_moe(r[1], cfg)}
    if cfg.moe.dense_residual:
        p["dense"] = mlp.init_mlp(r[2], cfg)
    return p


def moe_block(p, x, cfg, *, mode="train", cache=None, pos=None,
              positions=None, cache_len=None, active=None, lengths=None):
    h = _norm(cfg, x, p["ln1"])
    if mode == "decode":
        a, new_cache = attn.attn_decode(p["attn"], h, cache, pos, cfg,
                                        active=active)
    elif mode == "prefill":
        a, new_cache = attn.attn_full(p["attn"], h, cfg, positions,
                                      return_cache=True, cache_len=cache_len)
    else:
        a, new_cache = attn.attn_full(p["attn"], h, cfg, positions), None
    x = x + a
    h2 = _norm(cfg, x, p["ln2"])
    # serving (prefill/decode) routes per token -- dropless, so a row's
    # tokens are independent of batch mates / padding (see mlp.moe)
    y, aux = mlp.moe(p["moe"], h2, cfg, per_token=mode != "train")
    if "dense" in p:                      # arctic: parallel dense residual
        y = y + mlp.mlp(p["dense"], h2, cfg)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# ssm unit (mamba2)
# ---------------------------------------------------------------------------

def init_ssm_block(rng, cfg: ModelConfig):
    return {"ln": _norm_init(cfg), "ssm": ssm.init_ssm(rng, cfg)}


def ssm_block(p, x, cfg, *, mode="train", cache=None, pos=None,
              positions=None, cache_len=None, active=None, lengths=None):
    h = _norm(cfg, x, p["ln"])
    if mode == "decode":
        y, new_cache = ssm.ssd_decode(p["ssm"], h, cache, cfg, active=active)
    elif mode == "prefill":
        y, new_cache = ssm.ssd_forward(p["ssm"], h, cfg, return_state=True,
                                       lengths=lengths)
    else:
        y, new_cache = ssm.ssd_forward(p["ssm"], h, cfg), None
    return x + y, new_cache, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# hybrid super-block (jamba)
# ---------------------------------------------------------------------------

def init_hybrid_block(rng, cfg: ModelConfig):
    hp = cfg.hybrid
    m = cfg.moe
    n_mamba = hp.period - 1
    n_moe = sum(1 for i in range(hp.period) if i % m.interleave == m.interleave - 1)
    n_dense = hp.period - n_moe
    r = common.split_rngs(rng, 4)

    def stacked(rngs, fn):
        return jax.vmap(fn)(jnp.stack(rngs))

    return {
        "mamba": stacked(common.split_rngs(r[0], n_mamba),
                         lambda k: ssm.init_ssm(k, cfg)),
        "mamba_ln": stacked(common.split_rngs(r[0], n_mamba),
                            lambda k: _norm_init(cfg)),
        "attn": attn.init_attn(r[1], cfg),
        "attn_ln": _norm_init(cfg),
        "moe": stacked(common.split_rngs(r[2], n_moe),
                       lambda k: mlp.init_moe(k, cfg)),
        "dense": stacked(common.split_rngs(r[3], n_dense),
                         lambda k: mlp.init_mlp(k, cfg)),
        "ffn_ln": stacked(common.split_rngs(r[3], hp.period),
                          lambda k: _norm_init(cfg)),
    }


def _tree_idx(tree, i):
    return jax.tree_util.tree_map(lambda t: t[i], tree)


def hybrid_block(p, x, cfg, *, mode="train", cache=None, pos=None,
                 positions=None, cache_len=None, active=None, lengths=None):
    """One jamba super-block: period layers, each = mixer + FFN residual."""
    hp, m = cfg.hybrid, cfg.moe
    aux_total = jnp.float32(0.0)
    new_cache = {"mamba": [], "attn": None}
    i_mamba = i_moe = i_dense = 0
    for i in range(hp.period):
        if i == hp.attn_index:
            h = _norm(cfg, x, p["attn_ln"])
            if mode == "decode":
                a, c = attn.attn_decode(p["attn"], h, cache["attn"], pos, cfg,
                                        active=active)
            elif mode == "prefill":
                a, c = attn.attn_full(p["attn"], h, cfg, positions,
                                      return_cache=True, cache_len=cache_len)
            else:
                a, c = attn.attn_full(p["attn"], h, cfg, positions), None
            x = x + a
            new_cache["attn"] = c
        else:
            mp = _tree_idx(p["mamba"], i_mamba)
            ln = _tree_idx(p["mamba_ln"], i_mamba)
            h = _norm(cfg, x, ln)
            if mode == "decode":
                y, c = ssm.ssd_decode(mp, h, _tree_idx(cache["mamba"], i_mamba),
                                      cfg, active=active)
            elif mode == "prefill":
                y, c = ssm.ssd_forward(mp, h, cfg, return_state=True,
                                       lengths=lengths)
            else:
                y, c = ssm.ssd_forward(mp, h, cfg), None
            x = x + y
            new_cache["mamba"].append(c)
            i_mamba += 1
        ln = _tree_idx(p["ffn_ln"], i)
        h2 = _norm(cfg, x, ln)
        if i % m.interleave == m.interleave - 1:
            y, aux = mlp.moe(_tree_idx(p["moe"], i_moe), h2, cfg,
                             per_token=mode != "train")
            aux_total = aux_total + aux
            i_moe += 1
        else:
            y = mlp.mlp(_tree_idx(p["dense"], i_dense), h2, cfg)
            i_dense += 1
        x = x + y
    if mode == "train":
        nc = None
    else:
        nc = {"mamba": jax.tree_util.tree_map(
            lambda *ts: jnp.stack(ts), *new_cache["mamba"]),
            "attn": new_cache["attn"]}
    return x, nc, aux_total


# ---------------------------------------------------------------------------
# encoder / decoder units (whisper)
# ---------------------------------------------------------------------------

def init_enc_block(rng, cfg: ModelConfig):
    r = common.split_rngs(rng, 2)
    return {"ln1": _norm_init(cfg), "attn": attn.init_attn(r[0], cfg),
            "ln2": _norm_init(cfg), "mlp": mlp.init_mlp(r[1], cfg)}


def enc_block(p, x, cfg, lengths=None):
    """lengths: optional [B] int32 real-frame counts -- padded source
    positions are masked out of the bidirectional self-attention so a
    right-padded batch encodes real positions bit-identically."""
    x = x + attn.attn_full(p["attn"], _norm(cfg, x, p["ln1"]), cfg,
                           causal=False, kv_lengths=lengths)
    x = x + mlp.mlp(p["mlp"], _norm(cfg, x, p["ln2"]), cfg)
    return x


def init_dec_block(rng, cfg: ModelConfig):
    r = common.split_rngs(rng, 3)
    return {"ln1": _norm_init(cfg), "self": attn.init_attn(r[0], cfg),
            "ln2": _norm_init(cfg), "cross": attn.init_attn(r[1], cfg),
            "ln3": _norm_init(cfg), "mlp": mlp.init_mlp(r[2], cfg)}


def dec_block(p, x, cfg, *, memory=None, mode="train", cache=None,
              pos=None, cache_len=None, active=None, enc_lengths=None,
              enc_pad=None):
    """cache = {self: kv-cache, cross: precomputed {k, v, len}} (decode).

    active: [B] bool slot mask for decode -- the self-attn KV write is
    masked; the cross KV is read-only during decode, so inactive slots
    carry it through bit-identically for free.

    enc_lengths: [B] int32 real encoder frame counts (ragged serving);
    enc_pad: static target width -- prefill right-pads the cross K/V to
    it (zero rows, masked by `len`) so every enc-length bucket emits a
    slot page of one constant shape."""
    h = _norm(cfg, x, p["ln1"])
    if mode == "decode":
        a, self_c = attn.attn_decode(p["self"], h, cache["self"], pos, cfg,
                                     active=active)
        cross_kv = cache["cross"]
    elif mode == "prefill":
        a, self_c = attn.attn_full(p["self"], h, cfg, return_cache=True,
                                   cache_len=cache_len)
        k, v = attn._project_kv(p["cross"], memory, cfg)
        lens = (enc_lengths if enc_lengths is not None
                else jnp.full((k.shape[0],), k.shape[1], jnp.int32))
        if enc_pad is not None and enc_pad > k.shape[1]:
            pad = ((0, 0), (0, enc_pad - k.shape[1]), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cross_kv = {"k": k, "v": v, "len": lens.astype(jnp.int32)}
    else:
        a, self_c, cross_kv = attn.attn_full(p["self"], h, cfg), None, None
    x = x + a
    x = x + attn.attn_cross(p["cross"], _norm(cfg, x, p["ln2"]), memory, cfg,
                            mem_kv=cross_kv, enc_lengths=enc_lengths)
    x = x + mlp.mlp(p["mlp"], _norm(cfg, x, p["ln3"]), cfg)
    new_cache = None if mode == "train" else {"self": self_c, "cross": cross_kv}
    return x, new_cache, jnp.float32(0.0)
