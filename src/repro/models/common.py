"""Shared model components: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(x.dtype)


def norm_apply(x, params, kind: str, eps: float):
    if kind == "rmsnorm":
        return rms_norm(x, params["w"], eps)
    return layer_norm(x, params["w"], params["b"], eps)


def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0,
               m_rope_sections=None):
    """x: [B, S, H, D]; positions: [B, S] (standard) or [3, B, S] (M-RoPE,
    temporal/height/width position streams per qwen2-vl).

    M-RoPE splits the D/2 frequency slots into three contiguous sections,
    each rotated by its own position stream."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))             # [D/2]
    if m_rope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    else:
        secs = m_rope_sections
        assert sum(secs) == d // 2, (secs, d)
        parts = []
        off = 0
        for i, s in enumerate(secs):
            p = positions[i]                               # [B, S]
            parts.append(p[..., None].astype(jnp.float32) * freqs[off:off + s])
            off += s
        ang = jnp.concatenate(parts, axis=-1)              # [B,S,D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, dtype=jnp.bfloat16, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))
