"""Family-agnostic slot-state layer for the continuous-batching engine.

SILVIA's packing transformation covers heterogeneous op shapes (add2, mul4,
muladd2) behind one uniform DSP-slot interface; this module is the
request-level analogue: every model family's decode-time state -- KV pages,
SSM recurrent state, conv windows, hybrid mixes, encoder-decoder
self+cross caches -- is served through ONE abstract "slot state" interface,
so the engine (launch/engine.py) never special-cases a family.

A family registers an `init(cfg, n_slots, max_cache_len, **kw)` builder
(see the registrations at the bottom of models/lm.py).  From that builder a
`SlotStateSpec` is derived by **shape probing**: the builder is evaluated
under `jax.eval_shape` at two slot counts and two cache lengths, and each
pytree leaf's

* **slot axis** -- the axis that scales with `n_slots` (exactly one per
  leaf), and
* **length axis** -- the axis that scales with `max_cache_len`
  (`None` for constant-size pages: SSM state, conv windows, cross-KV)

are read off the shape diffs.  Probing instead of hand-written descriptors
means a new family only supplies its init fn and the engine's slicing,
scatter, and compaction work unchanged -- and cannot drift out of sync
with the real state layout.

The spec then exposes the four state operations the engine needs:

  init_state(n_slots, t)        fresh slot pages
  slice_live(state, n, t_b)     the bucketed live prefix for one segment
  merge_live(big, sub, n, t_b)  write a segment's result back
  admit(big, rows, slots, g, t) scatter freshly prefilled requests into
                                free slots -- leaves WITHOUT a length axis
                                are overwritten whole (reset-on-admit for
                                constant-size pages); leaves with one are
                                written up to the prefill bucket, the rest
                                being stale-but-masked (engine docstring)
  permute_slots(state, perm)    slot compaction (gather along slot axes)

Masked per-step updates (inactive slots bit-identical) live with the
models themselves -- `attn_decode`, `ssm.ssd_decode`, `blocks.dec_block`
all take an `active` mask -- and are property-tested across every
registered family in tests/test_slot_state.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FamilyState:
    """Registry entry: how to build one family's slot state.

    prefill_chunkable: whether prompts may be fed through the decode path
    C tokens at a time (engine `prefill_chunk`).  True only for families
    whose decode step consumes multi-token chunks with the same summation
    order as full prefill (attention KV); sequential-state families (SSM,
    hybrid) and encdec would change the floating-point reduction order and
    lose bit-exactness vs the static path."""
    family: str
    init: Callable[..., Any]
    prefill_chunkable: bool = True


_REGISTRY: Dict[str, FamilyState] = {}


def register(family: str, init: Callable[..., Any], *,
             prefill_chunkable: bool = True) -> None:
    """Register `init(cfg, n_slots, max_cache_len, **kw) -> state pytree`
    for a family.  Axis layout is probed, not declared (module docstring)."""
    _REGISTRY[family] = FamilyState(family, init, prefill_chunkable)


def families() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_family(family: str) -> FamilyState:
    try:
        return _REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"no slot-state implementation registered for family "
            f"{family!r} (registered: {list(families())}).  Add one with "
            f"repro.models.slot_state.register({family!r}, init_fn) -- "
            f"init_fn(cfg, n_slots, max_cache_len, **kw) must return the "
            f"family's stacked decode cache; see models/slot_state.py and "
            f"the registrations at the bottom of models/lm.py.") from None


@dataclasses.dataclass(frozen=True)
class SlotStateSpec:
    """Probed per-leaf axis layout + the engine's state operations."""
    family: str
    cfg: Any
    init_kwargs: Tuple[Tuple[str, Any], ...]
    treedef: Any
    batch_axes: Tuple[int, ...]
    length_axes: Tuple[Optional[int], ...]
    prefill_chunkable: bool

    @property
    def has_length_axis(self) -> bool:
        """False => constant-size pages: the engine skips cache-length
        bucketing entirely (batch-bucket-only graph growth)."""
        return any(a is not None for a in self.length_axes)

    # -- construction -------------------------------------------------------

    def init_state(self, n_slots: int, max_cache_len: int):
        fam = get_family(self.family)
        return fam.init(self.cfg, n_slots, max_cache_len,
                        **dict(self.init_kwargs))

    # -- leaf-wise application ---------------------------------------------

    def _apply(self, fn, *states):
        flats = []
        for st in states:
            leaves, td = jax.tree_util.tree_flatten(st)
            if td != self.treedef:
                raise ValueError(
                    f"state tree mismatch for family {self.family!r}: "
                    f"got {td}, spec has {self.treedef}")
            flats.append(leaves)
        out = [fn(ba, la, *ls)
               for ba, la, *ls in zip(self.batch_axes, self.length_axes,
                                      *flats)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -- engine operations --------------------------------------------------

    def slice_live(self, state, n_live: int, t_b: Optional[int] = None):
        """The [.., :n_live, (:t_b)] live prefix a decode segment runs on."""
        def f(ba, la, leaf):
            idx = [slice(None)] * leaf.ndim
            idx[ba] = slice(0, n_live)
            if la is not None and t_b is not None:
                idx[la] = slice(0, t_b)
            return leaf[tuple(idx)]
        return self._apply(f, state)

    def merge_live(self, big, sub, n_live: int, t_b: Optional[int] = None):
        """Write a segment's updated prefix back into the full slot state.

        A leaf whose prefix covers it entirely is REPLACED by the updated
        leaf rather than scattered into: slice_live's no-op slice aliases
        the original buffer, which a donating segment dispatch then
        deletes -- the old leaf must not be read, and the replacement also
        skips a same-shape copy."""
        def f(ba, la, bleaf, sleaf):
            covers_b = n_live == bleaf.shape[ba]
            covers_l = (la is None or t_b is None
                        or t_b == bleaf.shape[la])
            if covers_b and covers_l:
                return sleaf
            idx = [slice(None)] * bleaf.ndim
            idx[ba] = slice(0, n_live)
            if la is not None and t_b is not None:
                idx[la] = slice(0, t_b)
            return bleaf.at[tuple(idx)].set(sleaf)
        return self._apply(f, big, sub)

    def admit(self, big, rows, slots, n_new: int,
              t_pre: Optional[int] = None):
        """Scatter the first n_new prefilled rows into slot indices `slots`
        ([n_new] int array).  Constant-size leaves are replaced whole."""
        slots = jnp.asarray(slots)
        def f(ba, la, bleaf, rleaf):
            dst = [slice(None)] * bleaf.ndim
            dst[ba] = slots
            src = [slice(None)] * rleaf.ndim
            src[ba] = slice(0, n_new)
            if la is not None and t_pre is not None:
                dst[la] = slice(0, t_pre)
                src[la] = slice(0, t_pre)
            return bleaf.at[tuple(dst)].set(rleaf[tuple(src)])
        return self._apply(f, big, rows)

    def permute_slots(self, state, perm):
        """Reorder slots (compaction): gather `perm` along each slot axis."""
        perm = jnp.asarray(perm)
        def f(ba, la, leaf):
            return jnp.take(leaf, perm, axis=ba)
        return self._apply(f, state)

    # -- speculative-decode rollback (launch/engine.py) ----------------------
    #
    # Accepting m of k drafted tokens is a masked slot_state update, the
    # same mechanism quarantine scrubbing already uses: leaves WITH a
    # length axis need no rollback at all -- rows written past the
    # accepted position are stale-but-masked, the engine's exactness
    # invariant -- while constant-size leaves (SSM recurrent state, conv
    # windows, cross-KV) are restored from per-step snapshots, so
    # ssm/hybrid rollback is a snapshot-restore of one page.

    def const_leaves(self, state) -> tuple:
        """The constant-size (length_axis=None) leaves of `state` in
        tree_flatten order -- what a speculative scan snapshots per step
        (cheap precisely because these pages are fixed-size)."""
        leaves, td = jax.tree_util.tree_flatten(state)
        if td != self.treedef:
            raise ValueError(
                f"state tree mismatch for family {self.family!r}: "
                f"got {td}, spec has {self.treedef}")
        return tuple(leaf for leaf, la in zip(leaves, self.length_axes)
                     if la is None)

    def rollback_select(self, state, snaps, idx):
        """Roll `state` back to per-slot snapshot index `idx` ([n_slots]
        int): length-axis leaves pass through unchanged, each
        constant-size leaf i is replaced by `snaps[i][idx[slot]]` per
        slot (snapshot leaves carry a LEADING step axis, as stacked by
        `lax.scan` over const_leaves).  Traceable -- runs under jit and
        shard_map with a traced idx."""
        leaves, td = jax.tree_util.tree_flatten(state)
        if td != self.treedef:
            raise ValueError(
                f"state tree mismatch for family {self.family!r}: "
                f"got {td}, spec has {self.treedef}")
        it = iter(snaps)
        out = []
        for leaf, ba, la in zip(leaves, self.batch_axes, self.length_axes):
            if la is not None:
                out.append(leaf)
                continue
            snap = next(it)
            shape = [1] * snap.ndim
            shape[ba + 1] = snap.shape[ba + 1]
            sel = jnp.take_along_axis(
                snap, jnp.reshape(idx.astype(jnp.int32), shape), axis=0)
            out.append(jnp.squeeze(sel, axis=0))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -- prefix pages (launch/prefix_cache.py) ------------------------------
    #
    # A "prefix page" is the per-slot, per-leaf slice of state that a token
    # prefix fully determines: for leaves WITH a length axis that is rows
    # [lo:hi) (attention KV written by per-row dynamic_update_slice -- a
    # pure function of the token prefix, see models/attention.py); for
    # constant-size leaves (SSM recurrent state, conv windows, cross-KV)
    # it is the whole leaf -- a state SNAPSHOT, cheap precisely because
    # length_axis=None pages are fixed-size.  Pages are extracted to host
    # numpy (mesh-free: they survive elastic degrade and are re-placed
    # under whatever PartitionSpecs the current mesh plan dictates when
    # written back) and carried bit-exactly.

    @functools.lru_cache(maxsize=None)
    def _extract_prog(self, size: int, with_const: bool):
        """One jitted program slicing EVERY leaf's [lo:lo+size) page in a
        single dispatch.  `row`/`lo` are traced scalars, so one compiled
        program (per leaf-aval signature, handled by jit) serves every
        row and chunk offset -- python slices would bake each offset into
        its own XLA program, and per-leaf eager dynamic_slice calls would
        pay a host->device transfer per start index per leaf."""
        axes = tuple(zip(self.batch_axes, self.length_axes))

        @jax.jit
        def prog(leaves, row, lo):
            out = []
            for (ba, la), leaf in zip(axes, leaves):
                if la is None and not with_const:
                    out.append(None)
                    continue
                sizes = list(leaf.shape)
                sizes[ba] = 1
                starts = [0] * leaf.ndim
                starts[ba] = row
                if la is not None:
                    sizes[la] = size
                    starts[la] = lo
                out.append(jax.lax.dynamic_slice(leaf, tuple(starts),
                                                 tuple(sizes)))
            return out
        return prog

    @functools.lru_cache(maxsize=None)
    def _write_prog(self):
        """Jitted counterpart of _extract_prog: every page written in one
        dispatch (None pages pass their leaf through untouched -- they
        are empty pytree subtrees, so jit specializes on the pattern)."""
        axes = tuple(zip(self.batch_axes, self.length_axes))

        @jax.jit
        def prog(leaves, pages, row, lo):
            out = []
            for (ba, la), leaf, page in zip(axes, leaves, pages):
                if page is None:
                    out.append(leaf)
                    continue
                starts = [0] * leaf.ndim
                starts[ba] = row
                if la is not None:
                    starts[la] = lo
                out.append(jax.lax.dynamic_update_slice(
                    leaf, page, tuple(starts)))
            return out
        return prog

    def extract_row_pages(self, state, row: int, lo: int, hi: int,
                          with_const: bool = True) -> list:
        """Per-leaf host pages for ONE slot row (tree_flatten order).

        Length-axis leaves are sliced [lo:hi) along the length axis; the
        slot axis is kept as a singleton slice so axis numbering is
        position-stable for write_row_pages.  Leaves without a length axis
        are taken whole when `with_const`, else None (mid-prompt chunks of
        a chunked prefill carry no constant-size state)."""
        leaves, td = jax.tree_util.tree_flatten(state)
        if td != self.treedef:
            raise ValueError(
                f"state tree mismatch for family {self.family!r}: "
                f"got {td}, spec has {self.treedef}")
        out = self._extract_prog(hi - lo, with_const)(
            tuple(leaves), np.int32(row), np.int32(lo))
        # one batched transfer for all leaves (device_get keeps None
        # subtrees), not a blocking sync per leaf
        return jax.device_get(out)

    def write_row_pages(self, state, row: int, lo: int, pages: list):
        """Write extract_row_pages output into slot `row` of `state`:
        length-axis leaves at [lo:lo+page_len), constant-size leaves
        replaced whole.  None pages leave their leaf untouched.  The
        written bits are exactly the extracted bits, which is what makes
        a prefix-cache hit reproduce the cold-prefill stream."""
        leaves, td = jax.tree_util.tree_flatten(state)
        if td != self.treedef:
            raise ValueError(
                f"state tree mismatch for family {self.family!r}: "
                f"got {td}, spec has {self.treedef}")
        out = self._write_prog()(tuple(leaves), tuple(pages),
                                 np.int32(row), np.int32(lo))
        return jax.tree_util.tree_unflatten(self.treedef, out)


def _leaf_axis_diff(base, other, what: str, family: str):
    diff = [i for i, (x, y) in enumerate(zip(base, other)) if x != y]
    if len(diff) > 1:
        raise ValueError(
            f"slot-state probe for family {family!r}: leaf {base} has "
            f"{len(diff)} {what} axes {diff}; exactly one slot axis and at "
            f"most one length axis per leaf are supported")
    return diff


@functools.lru_cache(maxsize=64)
def _spec_cached(family: str, cfg,
                 kw_items: Tuple[Tuple[str, Any], ...]) -> SlotStateSpec:
    fam = get_family(family)
    kwargs = dict(kw_items)

    def shapes(n, t):
        tree = jax.eval_shape(lambda: fam.init(cfg, n, t, **kwargs))
        leaves, td = jax.tree_util.tree_flatten(tree)
        return [leaf.shape for leaf in leaves], td

    # prime-ish probe sizes: only dims derived from the varied argument
    # change between probes, so fixed dims can never alias
    s0, td0 = shapes(2, 16)
    sb, tdb = shapes(3, 16)
    sl, tdl = shapes(2, 48)
    if not (td0 == tdb == tdl):
        raise ValueError(
            f"slot-state init for family {family!r} changes tree structure "
            f"with n_slots/max_cache_len; it must be shape-polymorphic")
    batch_axes, length_axes = [], []
    for base, b_sh, l_sh in zip(s0, sb, sl):
        bd = _leaf_axis_diff(base, b_sh, "slot", family)
        if len(bd) != 1:
            raise ValueError(
                f"slot-state probe for family {family!r}: leaf {base} does "
                f"not scale with n_slots; every leaf needs a slot axis")
        ld = _leaf_axis_diff(base, l_sh, "length", family)
        batch_axes.append(bd[0])
        length_axes.append(ld[0] if ld else None)
    return SlotStateSpec(
        family=family, cfg=cfg, init_kwargs=kw_items, treedef=td0,
        batch_axes=tuple(batch_axes), length_axes=tuple(length_axes),
        prefill_chunkable=fam.prefill_chunkable)


def spec_for(cfg, **init_kwargs) -> SlotStateSpec:
    """The (cached) SlotStateSpec for cfg's family.  Raises with registry
    guidance when the family has no registered slot-state impl."""
    return _spec_cached(cfg.family, cfg, tuple(sorted(init_kwargs.items())))


# ---------------------------------------------------------------------------
# tensor-parallel probing (sharded serve)
# ---------------------------------------------------------------------------
#
# The sharded engine (launch/engine.py) shards slot-state leaves over the
# mesh "model" axis on their head/state dims.  Which axis that is per leaf
# is PROBED the same way the slot/length axes are: the family init is
# evaluated under jax.eval_shape with a head-localized config (head counts
# divided by the shard count, head_dim pinned so nothing else moves), and
# the axis that shrank by exactly the shard count is the tp axis.  Leaves
# that change by any other ratio (the SSD conv window, whose channel count
# mixes per-head x channels with shared B/C channels) or not at all stay
# replicated over the model axis -- exactly matching what the compute side
# (attention.py / ssm.py `tp_current()` paths) keeps local vs replicated.

_ATTN_FAMILIES = ("dense", "vlm", "moe", "hybrid", "encdec")


def _ssm_heads(cfg) -> int:
    if cfg.ssm is None:
        return 0
    from repro.models import ssm as ssm_mod
    return ssm_mod.dims(cfg)[2]


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """Which mixers a serve engine can tensor-parallelize over `size`
    model shards for this config (bit-exactness-preserving only: local
    heads + all_gather, never a partitioned float contraction)."""
    size: int
    attn: bool
    ssm: bool

    @property
    def active(self) -> bool:
        return self.size > 1 and (self.attn or self.ssm)


def tp_plan(cfg, size: int) -> TPPlan:
    """What can shard over a model axis of `size` for cfg.  Attention
    needs head counts divisible by the axis; SSD needs its derived head
    count divisible (and d_model, for the shape probe).  Anything that
    does not divide stays replicated -- graceful, never an error."""
    if size <= 1:
        return TPPlan(size, False, False)
    attn = (cfg.family in _ATTN_FAMILIES
            and cfg.n_heads % size == 0 and cfg.n_kv % size == 0)
    hs = _ssm_heads(cfg)
    ssm = (cfg.family in ("ssm", "hybrid") and hs > 0 and hs % size == 0
           and cfg.d_model % size == 0)
    return TPPlan(size, attn, ssm)


def tp_viable_sizes(cfg, limit: int) -> tuple:
    """Model-axis sizes in [1, limit] whose tp_plan is ACTIVE for cfg
    (shards something instead of replicating everything).  The degraded-
    mesh planner (distributed/elastic.py) prefers shrinking onto one of
    these, so losing devices narrows tensor parallelism instead of
    silently turning it off when a TP-capable extent still fits."""
    return tuple(m for m in range(2, max(1, limit) + 1)
                 if tp_plan(cfg, m).active)


def _tp_probe_cfg(cfg, plan: TPPlan):
    kw: Dict[str, Any] = {}
    if plan.attn:
        kw.update(n_heads=cfg.n_heads // plan.size,
                  n_kv=cfg.n_kv // plan.size)
    if plan.ssm:
        kw["d_model"] = cfg.d_model // plan.size
    if kw:
        # pin head_dim: it is otherwise derived from d_model / n_heads and
        # would drag unrelated axes along with the probe
        kw["d_head"] = cfg.head_dim
    return dataclasses.replace(cfg, **kw)


@functools.lru_cache(maxsize=64)
def _tp_axes_cached(family: str, cfg, size: int,
                    kw_items: Tuple[Tuple[str, Any], ...]) -> tuple:
    fam = get_family(family)
    kwargs = dict(kw_items)
    plan = tp_plan(cfg, size)
    if not plan.active:
        base = jax.eval_shape(lambda: fam.init(cfg, 2, 16, **kwargs))
        return (None,) * len(jax.tree_util.tree_leaves(base))
    probe_cfg = _tp_probe_cfg(cfg, plan)
    base = jax.eval_shape(lambda: fam.init(cfg, 2, 16, **kwargs))
    probe = jax.eval_shape(lambda: fam.init(probe_cfg, 2, 16, **kwargs))
    b_leaves, b_td = jax.tree_util.tree_flatten(base)
    p_leaves, p_td = jax.tree_util.tree_flatten(probe)
    if b_td != p_td:
        raise ValueError(
            f"tp probe for family {family!r}: init changes tree structure "
            f"under head localization; it must be shape-polymorphic")
    axes = []
    for bl, pl in zip(b_leaves, p_leaves):
        exact = [i for i, (b, p) in enumerate(zip(bl.shape, pl.shape))
                 if b != p and p * size == b]
        if len(exact) > 1:
            raise ValueError(
                f"tp probe for family {family!r}: leaf {bl.shape} has "
                f"{len(exact)} head-localized axes {exact}; at most one "
                f"tp axis per leaf is supported")
        axes.append(exact[0] if exact else None)
    return tuple(axes)


def tp_axes_for(cfg, size: int, **init_kwargs) -> tuple:
    """Per-leaf model-shard axis (tree_flatten order, matching
    SlotStateSpec.batch_axes); None = replicated over the model axis."""
    return _tp_axes_cached(cfg.family, cfg, size,
                           tuple(sorted(init_kwargs.items())))
