"""Family-agnostic slot-state layer for the continuous-batching engine.

SILVIA's packing transformation covers heterogeneous op shapes (add2, mul4,
muladd2) behind one uniform DSP-slot interface; this module is the
request-level analogue: every model family's decode-time state -- KV pages,
SSM recurrent state, conv windows, hybrid mixes, encoder-decoder
self+cross caches -- is served through ONE abstract "slot state" interface,
so the engine (launch/engine.py) never special-cases a family.

A family registers an `init(cfg, n_slots, max_cache_len, **kw)` builder
(see the registrations at the bottom of models/lm.py).  From that builder a
`SlotStateSpec` is derived by **shape probing**: the builder is evaluated
under `jax.eval_shape` at two slot counts and two cache lengths, and each
pytree leaf's

* **slot axis** -- the axis that scales with `n_slots` (exactly one per
  leaf), and
* **length axis** -- the axis that scales with `max_cache_len`
  (`None` for constant-size pages: SSM state, conv windows, cross-KV)

are read off the shape diffs.  Probing instead of hand-written descriptors
means a new family only supplies its init fn and the engine's slicing,
scatter, and compaction work unchanged -- and cannot drift out of sync
with the real state layout.

The spec then exposes the four state operations the engine needs:

  init_state(n_slots, t)        fresh slot pages
  slice_live(state, n, t_b)     the bucketed live prefix for one segment
  merge_live(big, sub, n, t_b)  write a segment's result back
  admit(big, rows, slots, g, t) scatter freshly prefilled requests into
                                free slots -- leaves WITHOUT a length axis
                                are overwritten whole (reset-on-admit for
                                constant-size pages); leaves with one are
                                written up to the prefill bucket, the rest
                                being stale-but-masked (engine docstring)
  permute_slots(state, perm)    slot compaction (gather along slot axes)

Masked per-step updates (inactive slots bit-identical) live with the
models themselves -- `attn_decode`, `ssm.ssd_decode`, `blocks.dec_block`
all take an `active` mask -- and are property-tested across every
registered family in tests/test_slot_state.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FamilyState:
    """Registry entry: how to build one family's slot state.

    prefill_chunkable: whether prompts may be fed through the decode path
    C tokens at a time (engine `prefill_chunk`).  True only for families
    whose decode step consumes multi-token chunks with the same summation
    order as full prefill (attention KV); sequential-state families (SSM,
    hybrid) and encdec would change the floating-point reduction order and
    lose bit-exactness vs the static path."""
    family: str
    init: Callable[..., Any]
    prefill_chunkable: bool = True


_REGISTRY: Dict[str, FamilyState] = {}


def register(family: str, init: Callable[..., Any], *,
             prefill_chunkable: bool = True) -> None:
    """Register `init(cfg, n_slots, max_cache_len, **kw) -> state pytree`
    for a family.  Axis layout is probed, not declared (module docstring)."""
    _REGISTRY[family] = FamilyState(family, init, prefill_chunkable)


def families() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_family(family: str) -> FamilyState:
    try:
        return _REGISTRY[family]
    except KeyError:
        raise ValueError(
            f"no slot-state implementation registered for family "
            f"{family!r} (registered: {list(families())}).  Add one with "
            f"repro.models.slot_state.register({family!r}, init_fn) -- "
            f"init_fn(cfg, n_slots, max_cache_len, **kw) must return the "
            f"family's stacked decode cache; see models/slot_state.py and "
            f"the registrations at the bottom of models/lm.py.") from None


@dataclasses.dataclass(frozen=True)
class SlotStateSpec:
    """Probed per-leaf axis layout + the engine's state operations."""
    family: str
    cfg: Any
    init_kwargs: Tuple[Tuple[str, Any], ...]
    treedef: Any
    batch_axes: Tuple[int, ...]
    length_axes: Tuple[Optional[int], ...]
    prefill_chunkable: bool

    @property
    def has_length_axis(self) -> bool:
        """False => constant-size pages: the engine skips cache-length
        bucketing entirely (batch-bucket-only graph growth)."""
        return any(a is not None for a in self.length_axes)

    # -- construction -------------------------------------------------------

    def init_state(self, n_slots: int, max_cache_len: int):
        fam = get_family(self.family)
        return fam.init(self.cfg, n_slots, max_cache_len,
                        **dict(self.init_kwargs))

    # -- leaf-wise application ---------------------------------------------

    def _apply(self, fn, *states):
        flats = []
        for st in states:
            leaves, td = jax.tree_util.tree_flatten(st)
            if td != self.treedef:
                raise ValueError(
                    f"state tree mismatch for family {self.family!r}: "
                    f"got {td}, spec has {self.treedef}")
            flats.append(leaves)
        out = [fn(ba, la, *ls)
               for ba, la, *ls in zip(self.batch_axes, self.length_axes,
                                      *flats)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -- engine operations --------------------------------------------------

    def slice_live(self, state, n_live: int, t_b: Optional[int] = None):
        """The [.., :n_live, (:t_b)] live prefix a decode segment runs on."""
        def f(ba, la, leaf):
            idx = [slice(None)] * leaf.ndim
            idx[ba] = slice(0, n_live)
            if la is not None and t_b is not None:
                idx[la] = slice(0, t_b)
            return leaf[tuple(idx)]
        return self._apply(f, state)

    def merge_live(self, big, sub, n_live: int, t_b: Optional[int] = None):
        """Write a segment's updated prefix back into the full slot state.

        A leaf whose prefix covers it entirely is REPLACED by the updated
        leaf rather than scattered into: slice_live's no-op slice aliases
        the original buffer, which a donating segment dispatch then
        deletes -- the old leaf must not be read, and the replacement also
        skips a same-shape copy."""
        def f(ba, la, bleaf, sleaf):
            covers_b = n_live == bleaf.shape[ba]
            covers_l = (la is None or t_b is None
                        or t_b == bleaf.shape[la])
            if covers_b and covers_l:
                return sleaf
            idx = [slice(None)] * bleaf.ndim
            idx[ba] = slice(0, n_live)
            if la is not None and t_b is not None:
                idx[la] = slice(0, t_b)
            return bleaf.at[tuple(idx)].set(sleaf)
        return self._apply(f, big, sub)

    def admit(self, big, rows, slots, n_new: int,
              t_pre: Optional[int] = None):
        """Scatter the first n_new prefilled rows into slot indices `slots`
        ([n_new] int array).  Constant-size leaves are replaced whole."""
        slots = jnp.asarray(slots)
        def f(ba, la, bleaf, rleaf):
            dst = [slice(None)] * bleaf.ndim
            dst[ba] = slots
            src = [slice(None)] * rleaf.ndim
            src[ba] = slice(0, n_new)
            if la is not None and t_pre is not None:
                dst[la] = slice(0, t_pre)
                src[la] = slice(0, t_pre)
            return bleaf.at[tuple(dst)].set(rleaf[tuple(src)])
        return self._apply(f, big, rows)

    def permute_slots(self, state, perm):
        """Reorder slots (compaction): gather `perm` along each slot axis."""
        perm = jnp.asarray(perm)
        def f(ba, la, leaf):
            return jnp.take(leaf, perm, axis=ba)
        return self._apply(f, state)


def _leaf_axis_diff(base, other, what: str, family: str):
    diff = [i for i, (x, y) in enumerate(zip(base, other)) if x != y]
    if len(diff) > 1:
        raise ValueError(
            f"slot-state probe for family {family!r}: leaf {base} has "
            f"{len(diff)} {what} axes {diff}; exactly one slot axis and at "
            f"most one length axis per leaf are supported")
    return diff


@functools.lru_cache(maxsize=64)
def _spec_cached(family: str, cfg,
                 kw_items: Tuple[Tuple[str, Any], ...]) -> SlotStateSpec:
    fam = get_family(family)
    kwargs = dict(kw_items)

    def shapes(n, t):
        tree = jax.eval_shape(lambda: fam.init(cfg, n, t, **kwargs))
        leaves, td = jax.tree_util.tree_flatten(tree)
        return [leaf.shape for leaf in leaves], td

    # prime-ish probe sizes: only dims derived from the varied argument
    # change between probes, so fixed dims can never alias
    s0, td0 = shapes(2, 16)
    sb, tdb = shapes(3, 16)
    sl, tdl = shapes(2, 48)
    if not (td0 == tdb == tdl):
        raise ValueError(
            f"slot-state init for family {family!r} changes tree structure "
            f"with n_slots/max_cache_len; it must be shape-polymorphic")
    batch_axes, length_axes = [], []
    for base, b_sh, l_sh in zip(s0, sb, sl):
        bd = _leaf_axis_diff(base, b_sh, "slot", family)
        if len(bd) != 1:
            raise ValueError(
                f"slot-state probe for family {family!r}: leaf {base} does "
                f"not scale with n_slots; every leaf needs a slot axis")
        ld = _leaf_axis_diff(base, l_sh, "length", family)
        batch_axes.append(bd[0])
        length_axes.append(ld[0] if ld else None)
    return SlotStateSpec(
        family=family, cfg=cfg, init_kwargs=kw_items, treedef=td0,
        batch_axes=tuple(batch_axes), length_axes=tuple(length_axes),
        prefill_chunkable=fam.prefill_chunkable)


def spec_for(cfg, **init_kwargs) -> SlotStateSpec:
    """The (cached) SlotStateSpec for cfg's family.  Raises with registry
    guidance when the family has no registered slot-state impl."""
    return _spec_cached(cfg.family, cfg, tuple(sorted(init_kwargs.items())))
