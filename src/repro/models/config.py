"""Model configuration schema for every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # arctic: a dense FFN runs in parallel with the MoE ("dense residual")
    dense_residual: bool = False
    # jamba: MoE only on every `interleave`-th layer (1 = every layer)
    interleave: int = 1
    # token dispatch: "global" sorts all tokens at once (simple but the
    # sort crosses shards -> collective-heavy); "grouped" dispatches within
    # fixed token groups aligned to data shards (GShard-style, local sort)
    dispatch: str = "global"
    dispatch_groups: int = 32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """jamba-style: period-layer super-blocks with one attention layer."""
    period: int = 8            # layers per super-block
    attn_index: int = 4        # which layer in the block is attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # m_rope: 3-section multimodal rotary (qwen2-vl); None = standard RoPE
    m_rope_sections: Optional[Tuple[int, int, int]] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encdec (whisper): decoder layer count; encoder uses n_layers
    n_decoder_layers: Optional[int] = None
    learned_pos: bool = False          # whisper: learned positional embeds
    activation: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None     # None | "audio" | "vision"
    dtype: str = "bfloat16"
    # serving quantization format for decode/prefill cells
    serve_fmt: str = "w8a8"            # bf16 | w8a8 | w4a8
    serve_kv_dtype: str = "bfloat16"   # bfloat16 | int8 (quantized KV cache)
    # chunk the query dim of causal self-attention (scan over q-blocks);
    # bounds the materialized score block to [B, H, chunk, T] -- the
    # XLA-level equivalent of flash attention's memory behaviour
    attn_q_chunk: Optional[int] = None
    # long-context support marker (sub-quadratic token mixing)
    subquadratic: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and roofline)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        attn = d * self.q_dim * 2 + d * self.kv_dim * 2
        if self.family == "ssm":
            total += self.n_layers * self._ssm_layer_params() + d  # final norm
            return total
        if self.family == "hybrid":
            hp = self.hybrid or HybridConfig()
            n_attn = self.n_layers // hp.period
            n_mamba = self.n_layers - n_attn
            total += n_attn * attn + n_mamba * self._ssm_layer_params()
            total += self._mlp_params_all()
            return total
        if self.family == "encdec":
            nd = self.n_decoder_layers or self.n_layers
            mlp = 2 * d * self.d_ff  # gelu mlp: up + down
            total += self.n_layers * (attn + mlp)          # encoder
            total += nd * (2 * attn + mlp)                 # decoder + cross
            return total
        total += self.n_layers * attn + self._mlp_params_all()
        return total

    def _ssm_layer_params(self) -> int:
        s = self.ssm or SSMConfig()
        d = self.d_model
        d_inner = s.expand * d
        n_heads = d_inner // s.headdim
        d_conv_ch = d_inner + 2 * s.n_groups * s.d_state
        in_proj = d * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)
        out_proj = d_inner * d
        conv = s.conv_width * d_conv_ch + d_conv_ch
        extras = 3 * n_heads + d_inner  # A, D, dt_bias, gated norm
        return in_proj + out_proj + conv + extras

    def _mlp_params_all(self) -> int:
        d = self.d_model
        n_mlp = 3 if self.activation == "swiglu" else 2
        dense = n_mlp * d * self.d_ff
        if self.moe is None:
            return self.n_layers * dense
        m = self.moe
        expert = n_mlp * d * m.d_ff_expert
        n_moe_layers = self.n_layers // m.interleave
        n_dense_layers = self.n_layers - n_moe_layers
        total = n_moe_layers * (m.n_experts * expert + d * m.n_experts)
        if m.dense_residual:
            total += self.n_layers * dense
        else:
            total += n_dense_layers * dense
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        n_mlp = 3 if self.activation == "swiglu" else 2
        expert = n_mlp * self.d_model * m.d_ff_expert
        n_moe_layers = self.n_layers // m.interleave
        inactive = n_moe_layers * (m.n_experts - m.top_k) * expert
        return self.param_count() - inactive
