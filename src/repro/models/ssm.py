"""Mamba2 SSD (state-space duality) layer -- chunked training/prefill form
plus constant-memory single-token decode (arXiv:2405.21060).

Chunked SSD: the sequence is split into chunks of Q tokens processed by a
lax.scan (so only ONE chunk's quadratic term is live at a time -- essential
at prefill_32k scale); each chunk computes a quadratic intra-chunk term
(attention-like, MXU-friendly) plus the contribution of the carried state:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t

State per layer: [B, H, P, N] -- constant in sequence length, which is what
makes the long_500k decode cell runnable for ssm/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import context as dctx
from repro.models import common
from repro.quant.qtensor import qmatmul
from repro.models.config import ModelConfig, SSMConfig


def _ssm_tp():
    """Active serve-time tensor-parallel context for SSD mixers (set
    inside the engine's shard_map body).  When active, the [B, H, P, N]
    recurrent state stays local to this shard's head block and the
    per-head outputs are all_gathered before the gated norm; the in/out
    projections and the depthwise conv stay replicated (the conv window
    mixes per-head x channels with the group-shared B/C channels, so its
    state cannot partition over heads).  The only collective is an exact
    concat -- bit-identical to the single-device path."""
    tp = dctx.tp_current()
    return tp if tp is not None and tp.ssm else None


def dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, conv_ch


def init_ssm(rng, cfg: ModelConfig):
    s, d_inner, n_heads, conv_ch = dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    r = common.split_rngs(rng, 4)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": common.dense_init(r[0], d, d_in_proj, dt),
        "conv_w": (jax.random.normal(r[1], (s.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": common.dense_init(r[3], d_inner, d, dt),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d_inner, n_heads, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, width: int):
    """Depthwise causal conv via explicit shifts (width is small)."""
    out = xbc * conv_w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        out = out + shifted * conv_w[-1 - i]
    return jax.nn.silu(out + conv_b)


def _segsum_decay(da_cs):
    """L[i, j] = exp(da_cs[i] - da_cs[j]) for i >= j else 0.
    da_cs: [B, Q, H] -> [B, H, Q, Q]."""
    q = da_cs.shape[-2]
    diff = da_cs[:, :, None, :] - da_cs[:, None, :, :]       # [B,i,j,H]
    diff = jnp.moveaxis(diff, -1, 1)                         # [B,H,i,j]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_forward(p, x_in, cfg: ModelConfig, initial_state=None,
                return_state: bool = False, lengths=None):
    """x_in: [B, L, d_model] -> [B, L, d_model] (+ final {ssm, conv} state).

    lengths: optional [B] int32 per-row count of REAL tokens (ragged
    right-padded batches; lm.prefill always passes it).  Padded positions
    become identity steps (decay 1, zero update), so the final state is
    the state after each row's real prompt -- bit-identical to running
    that row unpadded, because with lengths the chunk grid is FIXED at
    s.chunk (absolute chunk boundaries do not move with the padded
    length; extra padded chunks multiply the state by exp(0) == 1 and add
    exact zeros).  Training (lengths=None) keeps the adaptive grid: short
    sequences would otherwise pay the full [B,H,chunk,chunk] intra-chunk
    cost on pure identity steps."""
    s, d_inner, n_heads, conv_ch = dims(cfg)
    b, l_real, _ = x_in.shape
    q = s.chunk if lengths is not None else min(s.chunk, l_real)
    l = -(-l_real // q) * q           # pad to a chunk multiple
    if l != l_real:
        x_in = jnp.pad(x_in, ((0, 0), (0, l - l_real), (0, 0)))
    nc = l // q
    g, n, pd = s.n_groups, s.d_state, s.headdim

    zxbcdt = qmatmul(x_in, p["in_proj"])
    z, xbc_pre, dtr = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"], s.conv_width)
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)

    rep = n_heads // g
    a = -jnp.exp(p["A_log"])                                 # [H]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # [B,L,H]
    if lengths is not None or l != l_real:
        # identity steps beyond each row's real length (uniform l_real
        # when only the chunk grid padded the sequence)
        lens = (jnp.full((b,), l_real, jnp.int32) if lengths is None
                else lengths.astype(jnp.int32))
        valid = jnp.arange(l)[None, :, None] < lens[:, None, None]
        dt = jnp.where(valid, dt, 0.0)

    # chunk the streams: [nc, B, Q, ...] for lax.scan
    def chunked(t, shape):
        return jnp.moveaxis(t.reshape(b, nc, q, *shape), 1, 0)

    xs = dict(
        x=chunked(x.astype(jnp.float32), (n_heads, pd)),
        bm=chunked(bmat.astype(jnp.float32), (g, n)),
        cm=chunked(cmat.astype(jnp.float32), (g, n)),
        dt=chunked(dt, (n_heads,)),
    )

    tp = _ssm_tp()
    h_loc = n_heads if tp is None else n_heads // tp.size
    j_tp = None if tp is None else jax.lax.axis_index(tp.axis)
    if tp is not None:
        a = jax.lax.dynamic_slice_in_dim(a, j_tp * h_loc, h_loc, axis=0)

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h_loc, pd, n), jnp.float32))

    def chunk_step(state, inp):
        xq, bq, cq, dtq = inp["x"], inp["bm"], inp["cm"], inp["dt"]
        bh = jnp.repeat(bq, rep, axis=2)                     # [B,Q,H,n]
        chh = jnp.repeat(cq, rep, axis=2)
        if tp is not None:
            # this shard's head block (projections/conv ran replicated)
            sl = lambda t: jax.lax.dynamic_slice_in_dim(
                t, j_tp * h_loc, h_loc, axis=2)
            xq, bh, chh, dtq = sl(xq), sl(bh), sl(chh), sl(dtq)
        da = dtq * a                                          # [B,Q,H]
        da_cs = jnp.cumsum(da, axis=1)
        lmat = _segsum_decay(da_cs)                           # [B,H,Q,Q]
        cb = jnp.einsum("bihn,bjhn->bhij", chh, bh)
        y_diag = jnp.einsum("bhij,bjh,bjhp->bihp", cb * lmat, dtq, xq)
        decay_in = jnp.exp(da_cs)                             # [B,Q,H]
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", chh, state, decay_in)
        decay_states = jnp.exp(da_cs[:, -1:, :] - da_cs)
        states = jnp.einsum("bqhn,bqh,bqh,bqhp->bhpn",
                            bh, decay_states, dtq, xq)
        chunk_decay = jnp.exp(da_cs[:, -1, :])                # [B,H]
        new_state = chunk_decay[:, :, None, None] * state + states
        return new_state, y_diag + y_off                      # y: [B,Q,H,pd]

    final_state, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h_loc, pd)
    xf = x.astype(jnp.float32).reshape(b, l, n_heads, pd)
    dcoef = p["D"]
    if tp is not None:
        xf = jax.lax.dynamic_slice_in_dim(xf, j_tp * h_loc, h_loc, axis=2)
        dcoef = jax.lax.dynamic_slice_in_dim(dcoef, j_tp * h_loc, h_loc,
                                             axis=0)
    y = y + dcoef[None, None, :, None] * xf
    if tp is not None:
        y = jax.lax.all_gather(y, tp.axis, axis=2, tiled=True)
    y = y.reshape(b, l, d_inner)
    # gated rmsnorm then out projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = common.rms_norm(y, p["norm_w"], cfg.norm_eps).astype(x_in.dtype)
    out = qmatmul(y, p["out_proj"])
    if l != l_real:
        out = out[:, :l_real, :]
    if return_state:
        # last (conv_width-1) REAL inputs per row; left-pad so rows shorter
        # than the window get the leading zeros a fresh stream would have
        w = s.conv_width - 1
        lens = (jnp.full((b,), l_real, jnp.int32) if lengths is None
                else lengths.astype(jnp.int32))
        padded = jnp.pad(xbc_pre, ((0, 0), (w, 0), (0, 0)))
        conv_state = jax.vmap(
            lambda t, i: jax.lax.dynamic_slice(t, (i, 0), (w, conv_ch))
        )(padded, lens)
        return out, {"ssm": final_state, "conv": conv_state}
    return out


def init_ssm_state(cfg: ModelConfig, batch: int):
    s, d_inner, n_heads, conv_ch = dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, s.headdim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch),
                          jnp.dtype(cfg.dtype)),
    }


def ssd_decode(p, x_t, state, cfg: ModelConfig, active=None):
    """Single-token decode.  x_t: [B, 1, d_model]; state dict from
    init_ssm_state / prior steps.  active: optional [B] bool slot mask --
    inactive rows compute but keep their {ssm, conv} state bit-identical
    (the SSM analogue of the masked KV-cache write: state pages are
    constant-size, so masking the whole update is exact).
    Returns (y_t, new_state)."""
    s, d_inner, n_heads, conv_ch = dims(cfg)
    b = x_t.shape[0]
    g, n, pd = s.n_groups, s.d_state, s.headdim

    zxbcdt = qmatmul(x_t, p["in_proj"])                     # [B,1,*]
    z, xbc_new, dtr = _split_proj(zxbcdt, cfg)
    # conv over [cached, new]
    buf = jnp.concatenate([state["conv"], xbc_new], axis=1)  # [B,W,ch]
    conv_out = jnp.einsum("bwc,wc->bc", buf, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :]                 # [B,1,ch]
    new_conv = buf[:, 1:, :]

    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xf = x.astype(jnp.float32).reshape(b, n_heads, pd)
    bh = jnp.repeat(bmat.astype(jnp.float32).reshape(b, g, n),
                    n_heads // g, axis=1)                   # [B,H,n]
    chh = jnp.repeat(cmat.astype(jnp.float32).reshape(b, g, n),
                     n_heads // g, axis=1)

    dt = jax.nn.softplus(dtr.astype(jnp.float32).reshape(b, n_heads)
                         + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    dcoef = p["D"]
    tp = _ssm_tp()
    if tp is not None:
        # local head block: the projections/conv above ran replicated;
        # only the state update + per-head output are sharded
        hl = n_heads // tp.size
        j = jax.lax.axis_index(tp.axis)
        sl = lambda t, ax: jax.lax.dynamic_slice_in_dim(t, j * hl, hl,
                                                        axis=ax)
        xf, bh, chh, dt = sl(xf, 1), sl(bh, 1), sl(chh, 1), sl(dt, 1)
        a, dcoef = sl(a, 0), sl(dcoef, 0)
    da = jnp.exp(dt * a)                                    # [B,H]
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bh, xf)
    new_ssm = da[:, :, None, None] * state["ssm"] + upd
    if active is not None:
        new_ssm = jnp.where(active[:, None, None, None], new_ssm,
                            state["ssm"])
        new_conv = jnp.where(active[:, None, None], new_conv, state["conv"])
    y = jnp.einsum("bhn,bhpn->bhp", chh, new_ssm)
    y = y + dcoef[None, :, None] * xf
    if tp is not None:
        y = jax.lax.all_gather(y, tp.axis, axis=1, tiled=True)  # [B,H,pd]
    y = y.reshape(b, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = common.rms_norm(y, p["norm_w"], cfg.norm_eps).astype(x_t.dtype)
    out = qmatmul(y, p["out_proj"])
    return out, {"ssm": new_ssm, "conv": new_conv}
