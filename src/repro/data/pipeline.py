"""Deterministic sharded token pipeline.

Design constraints for large-scale runs:

* **Exact resume**: batch contents are a pure function of (seed, step,
  shard), so a restarted job skips to `start_step` and reproduces the
  stream without replaying data (checkpoint stores only the step).
* **Sharding**: each data-parallel shard draws its own slice of the global
  batch; host h of H hosts materializes rows [h*B/H, (h+1)*B/H).
* **Sources**: `synthetic` (seeded LCG tokens, always available -- used by
  smoke tests and the dry-run) and `mmap` (memory-mapped token file,
  production-style, zero-copy reads).
* **Prefetch**: a small lookahead buffer computed on the host thread;
  device transfer overlaps with compute under jit's async dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | mmap
    path: Optional[str] = None         # token file for mmap
    n_hosts: int = 1
    host_id: int = 0


class TokenStream:
    """Iterator of {tokens: [b, S+1] int32} host-local batches by step."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._mm = None
        if cfg.source == "mmap":
            assert cfg.path, "mmap source needs a token file path"
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> np.ndarray:
        """[local_batch, seq_len + 1] int32 tokens for `step` (pure fn)."""
        cfg = self.cfg
        if cfg.source == "synthetic":
            # counter-based RNG: one Philox-seeded generator per (step, host)
            rng = np.random.Generator(np.random.Philox(
                key=cfg.seed, counter=[step, cfg.host_id, 0, 0]))
            return rng.integers(0, cfg.vocab,
                                (self.local_batch, cfg.seq_len + 1),
                                dtype=np.int32)
        # mmap: strided contiguous windows, deterministic per (step, host)
        n_tok = self._mm.shape[0]
        span = cfg.seq_len + 1
        windows = max(1, (n_tok - span) // span)
        rows = []
        for r in range(self.local_batch):
            gidx = (step * cfg.global_batch
                    + cfg.host_id * self.local_batch + r)
            off = (gidx * 2654435761 % windows) * span
            rows.append(np.asarray(self._mm[off:off + span], np.int32))
        return np.stack(rows)

    def iterate(self, start_step: int = 0,
                prefetch: int = 2) -> Iterator[np.ndarray]:
        """Prefetching iterator starting at `start_step` (exact resume)."""
        import collections
        buf: collections.deque = collections.deque()
        step = start_step
        while True:
            while len(buf) < prefetch:
                buf.append(self.batch_at(step))
                step += 1
            yield buf.popleft()


def synthetic_stream(seq_len, global_batch, vocab, seed=0, **kw):
    return TokenStream(DataConfig(seq_len, global_batch, vocab, seed,
                                  "synthetic", **kw))


def mmap_stream(path, seq_len, global_batch, vocab, **kw):
    return TokenStream(DataConfig(seq_len, global_batch, vocab,
                                  source="mmap", path=path, **kw))


def make_stream(cfg: DataConfig) -> TokenStream:
    return TokenStream(cfg)
