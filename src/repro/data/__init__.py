"""Data pipeline: deterministic, shardable token streams."""
from repro.data.pipeline import (DataConfig, TokenStream, make_stream,
                                 mmap_stream, synthetic_stream)

__all__ = ["DataConfig", "TokenStream", "make_stream", "mmap_stream",
           "synthetic_stream"]
