"""Training substrate: loss, train step, microbatched accumulation."""
from repro.training.step import (TrainConfig, loss_fn, make_train_step,
                                 make_serve_fns)

__all__ = ["TrainConfig", "loss_fn", "make_serve_fns", "make_train_step"]
