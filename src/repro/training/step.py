"""Train / serve step construction for every architecture family.

`make_train_step(cfg, train_cfg)` returns a pure function
    step(params, opt_state, batch) -> (params, opt_state, metrics)
with optional microbatched gradient accumulation (a lax.scan over
microbatches -- activation memory / collective-size lever) and AdamW.

`make_serve_fns(cfg)` returns (prefill_fn, decode_fn) for the serving cells.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # gradient accumulation factor
    z_loss: float = 1e-4           # logit normalizer regularization
    aux_loss_weight: float = 0.01  # MoE load balancing
    remat: bool = True
    schedule_total: int = 10000
    schedule_warmup: int = 200


def loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    """batch: {tokens: [B, S+1]} (decoder-only) or
    {audio: [B,Se,d], tokens: [B, Sd+1]} (encdec) or
    {embeds: [B,S,d], labels: [B,S]} (vlm stub)."""
    if cfg.family == "encdec":
        inputs = (batch["audio"], batch["tokens"][:, :-1])
        labels = batch["tokens"][:, 1:]
        logits, aux = lm.forward(params, inputs, cfg, remat=tcfg.remat)
    elif "embeds" in batch:
        logits, aux = lm.forward(params, batch["embeds"], cfg,
                                 remat=tcfg.remat)
        labels = batch["labels"]
    else:
        tokens = batch["tokens"]
        logits, aux = lm.forward(params, tokens[:, :-1], cfg,
                                 remat=tcfg.remat)
        labels = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    zl = tcfg.z_loss * jnp.mean(jnp.square(lse))
    total = nll + zl + tcfg.aux_loss_weight * aux
    return total, {"loss": nll, "z_loss": zl, "aux_loss": aux}


def _split_microbatches(batch, n: int):
    return jax.tree_util.tree_map(
        lambda t: t.reshape(n, t.shape[0] // n, *t.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, tcfg=tcfg), has_aux=True)

    def step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = _split_microbatches(batch, tcfg.microbatches)

            def accum(carry, mb_batch):
                gsum, msum = carry
                (_, metrics), grads = grad_fn(params, mb_batch)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                msum = jax.tree_util.tree_map(jnp.add, msum, metrics)
                return (gsum, msum), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mzero = {"loss": 0.0, "z_loss": 0.0, "aux_loss": 0.0}
            mzero = jax.tree_util.tree_map(jnp.float32, mzero)
            (gsum, msum), _ = jax.lax.scan(accum, (zeros, mzero), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, gsum)
            metrics = jax.tree_util.tree_map(
                lambda m: m / tcfg.microbatches, msum)
        else:
            (_, metrics), grads = grad_fn(params, batch)
        lr_scale = warmup_cosine(opt_state["step"],
                                 warmup=tcfg.schedule_warmup,
                                 total=tcfg.schedule_total)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer, lr_scale)
        metrics = dict(metrics, **opt_metrics, lr_scale=lr_scale)
        return params, opt_state, metrics

    return step


def make_serve_fns(cfg: ModelConfig):
    """(prefill_fn, decode_fn) with signatures matching the shape cells."""

    def prefill_fn(params, inputs, cache_len):
        return lm.prefill(params, inputs, cfg, cache_len=cache_len)

    def decode_fn(params, token_t, cache, pos):
        return lm.decode_step(params, token_t, cache, pos, cfg)

    return prefill_fn, decode_fn
