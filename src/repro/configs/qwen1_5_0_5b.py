"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936 -- QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=2816, vocab=151936,
    qkv_bias=True, tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="qwen1.5-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=256)
