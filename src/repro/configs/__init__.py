"""Assigned-architecture registry: one module per arch, exact public configs.

Use `get_config(name)` / `get_reduced_config(name)` (smoke-test scale) and
`ARCHS` for the full list.  Input-shape cells live in `shapes.py`.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "arctic-480b",
    "granite-moe-1b-a400m",
    "mamba2-2.7b",
    "command-r-35b",
    "yi-6b",
    "smollm-135m",
    "qwen1.5-0.5b",
    "jamba-v0.1-52b",
    "whisper-small",
    "qwen2-vl-72b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_reduced_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.reduced()


from repro.configs.shapes import SHAPES, cells_for_arch  # noqa: E402

__all__ = ["ARCHS", "SHAPES", "cells_for_arch", "get_config",
           "get_reduced_config"]
