"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution (vision frontend STUB:
input_specs provides precomputed patch embeddings).
[arXiv:2409.12191; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568, vocab=152064,
    m_rope_sections=(16, 24, 24), rope_theta=1_000_000.0, qkv_bias=True,
    frontend="vision",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256,
        m_rope_sections=(2, 3, 3))
