"""mamba2-2.7b [ssm]: 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
import dataclasses

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv=1, d_ff=0, vocab=50280,
    d_head=64,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=256),
    subquadratic=True, tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="mamba2-reduced", n_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(d_state=16, headdim=16, expand=2, conv_width=4,
                      n_groups=1, chunk=16))
