"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865
-- enc-dec, conv frontend (STUB: input_specs provides precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
    n_decoder_layers=12, learned_pos=True, activation="gelu",
    norm="layernorm", frontend="audio",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="whisper-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=256, n_decoder_layers=2)
