"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 -- Mamba+attn 1:7 interleave, MoE every
other layer.  [arXiv:2403.19887; hf]"""
import dataclasses

from repro.models.config import HybridConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, interleave=2),
    ssm=SSMConfig(d_state=16, headdim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=256),
    hybrid=HybridConfig(period=8, attn_index=4),
    subquadratic=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="jamba-reduced", n_layers=8, d_model=64,
        n_heads=4, n_kv=2, d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, interleave=2),
        ssm=SSMConfig(d_state=16, headdim=16, expand=2, conv_width=4,
                      n_groups=1, chunk=16),
        hybrid=HybridConfig(period=8, attn_index=4))
