"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
-- llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536, vocab=49152,
    tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="smollm-reduced", n_layers=2, d_model=48,
        n_heads=3, n_kv=1, d_ff=128, vocab=256)
