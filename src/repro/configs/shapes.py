"""Assigned input-shape cells (same 4 shapes for every LM arch).

  train_4k     seq 4096,   global batch 256   -> train_step
  prefill_32k  seq 32768,  global batch 32    -> prefill
  decode_32k   KV 32768,   global batch 128   -> serve_step (1 new token)
  long_500k    KV 524288,  global batch 1     -> serve_step; ONLY for
               sub-quadratic archs (ssm/hybrid); skipped otherwise with the
               reason recorded (see DESIGN.md sec. 5)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cells_for_arch(cfg) -> dict[str, str]:
    """Return {shape_name: 'run' | skip-reason} for an arch config."""
    out = {}
    for name, cell in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            if cfg.family == "encdec":
                out[name] = ("skip: encoder-decoder; 500k tokens outside the "
                             "model's positional domain")
            else:
                out[name] = "skip: full quadratic attention (per brief)"
        else:
            out[name] = "run"
    return out
