"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 -- GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv=8, d_ff=22528, vocab=256000,
    rope_theta=8_000_000.0,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="command-r-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256)
