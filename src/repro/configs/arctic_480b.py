"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
import dataclasses

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864, vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="arctic-480b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      dense_residual=True))
