"""Symmetric integer quantization + int4 packing.

Conventions:
* int8 tensors store int8 values; int4 tensors store values in [-8, 7]
  inside int8 words, tagged with `silvia.width_hint(x, 4)` so the SILVIA
  width analysis (the analogue of HLS frontend width minimization) sees the
  true 4-bit range.
* scales are float32, shaped for broadcast against the quantized axis.
* pack_int4/unpack_int4 store two int4 values per int8 word (the offline
  "free wiring" packing; see kernels/packed_matmul.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.prims import width_hint
from repro.kernels import ref as kref


def quantize(x, bits: int = 8, axis=None, eps: float = 1e-8):
    """Symmetric quantization: returns (q int8, scale f32).

    axis=None -> per-tensor scale; axis=k -> per-slice scales along k
    (scale shape keeps that axis, 1 elsewhere)."""
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
        scale = (amax / qmax + eps).astype(jnp.float32)
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
        scale = (amax / qmax + eps).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    if bits < 8:
        q = width_hint(q, bits)
    return q, scale


def quantize_int4(x, axis=None):
    return quantize(x, bits=4, axis=axis)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def pack_int4(q4):
    """[..., N] int4-valued int8 -> [..., N//2] packed int8 words."""
    return kref.pack_w4(q4)


def unpack_int4(packed):
    """[..., N//2] packed int8 words -> [..., N] int4-valued int8, width-
    hinted for the SILVIA passes."""
    w32 = packed.astype(jnp.int32)
    even = (w32 & 0xF) - 8
    odd = w32 >> 4
    out = jnp.stack([even, odd], axis=-1).reshape(
        *packed.shape[:-1], 2 * packed.shape[-1]).astype(jnp.int8)
    return width_hint(out, 4)
