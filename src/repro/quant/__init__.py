"""Quantization substrate: symmetric int8/int4 quantization, packed int4
storage, and quantized linear layers whose naive form exposes exactly the
narrow-integer patterns the SILVIA passes pack."""
from repro.quant.quantize import (dequantize, pack_int4, quantize,
                                  quantize_int4, unpack_int4)
from repro.quant.linear import (QuantLinearParams, quant_linear,
                                quantize_linear_params)

__all__ = ["QuantLinearParams", "dequantize", "pack_int4", "quant_linear",
           "quantize", "quantize_int4", "quantize_linear_params",
           "unpack_int4"]
