"""QTensor: quantized weight leaves + the pluggable matmul they dispatch to.

`common.matmul(x, w)` (re-exported as `quant.qmatmul`) accepts either a plain
array (bf16 training path) or a QTensor (serving path).  QTensor is a pytree,
so quantized params flow through jit / shardings / eval_shape unchanged.

Formats:
  w8a8  q: int8 [..., K, N],    scale: f32 [..., 1, N]
  w4a8  q: int8 [..., K, N//2] (two int4/word), scale: f32 [..., 1, N]

The w4a8 storage halves weight HBM bytes -- the packing insight applied to
the memory-bound decode path (see kernels/packed_matmul.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.quant.quantize import pack_int4, quantize


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    q: Any
    scale: Any
    fmt: str

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("scale"), self.scale)), self.fmt)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def logical_shape(self):
        s = tuple(self.q.shape)
        if self.fmt == "w4a8":
            return s[:-1] + (2 * s[-1],)
        return s


def quantize_weight(w, fmt: str) -> QTensor:
    """w: [..., K, N] float -> QTensor (per-output-channel scales; leading
    axes, e.g. stacked layers or experts, keep independent scales)."""
    bits = 4 if fmt == "w4a8" else 8
    qmax = 2 ** (bits - 1) - 1
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)       # [..., 1, N]
    scale = (amax / qmax + 1e-8).astype(jnp.float32)
    q = jnp.clip(jnp.round(wf / scale), -qmax - 1, qmax).astype(jnp.int8)
    if fmt == "w4a8":
        q = pack_int4(q)
    return QTensor(q, scale, fmt)


def _q2d(x2, w: QTensor):
    x_q, x_s = quantize(x2, bits=8, axis=0)
    if w.fmt == "w8a8":
        return registry.dispatch("quant_matmul", x_q, w.q, x_s, w.scale)
    return registry.dispatch("packed_w4_matmul", x_q, w.q, x_s, w.scale)


def qmatmul(x, w):
    """x: [..., K]; w: array [K, N] | QTensor [K, N] | QTensor [E, K, N]
    (batched expert weights, x then [E, ..., K])."""
    if not isinstance(w, QTensor):
        return x @ w
    if w.q.ndim == 2:
        lead = x.shape[:-1]
        y = _q2d(x.reshape(-1, x.shape[-1]), w)
        return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
    # batched experts: map over the leading axis
    assert w.q.ndim == 3 and x.ndim >= 3 and x.shape[0] == w.q.shape[0]
    lead = x.shape[1:-1]
    xe = x.reshape(x.shape[0], -1, x.shape[-1])
    ye = jax.vmap(_q2d)(xe, w)
    return ye.reshape(x.shape[0], *lead, ye.shape[-1]).astype(x.dtype)


def quantize_tree_for_serving(params, fmt: str, min_size: int = 1 << 16,
                              skip_keys=("router", "embed", "pos", "conv",
                                         "ln", "norm", "A_log", "dt_bias",
                                         "D"),
                              force: bool = False):
    """Replace every large >=2D float weight leaf with a QTensor.

    Walks the param pytree by path; leaves whose key path contains any of
    `skip_keys`, 1-D leaves (norms/biases/A_log/...) and small leaves stay
    in bf16/f32.

    force=True drops the SIZE floors (`min_size` and the min(shape[-2:])
    >= 64 width check) while keeping the structural rules (skip_keys,
    the stacked-2-D-vector exclusion).  The floors are production
    heuristics -- quantizing tiny weights saves nothing -- but every
    weight of the REDUCED test configs sits under them, so "quantized"
    smoke benchmarks and CI rows would otherwise serve pure-bf16 graphs
    with zero packed-matmul dispatches (ROADMAP: reduced-config
    quantization no-op).  Smoke/CI paths pass force=True and assert a
    nonzero packed-dispatch census (kernels.registry.dispatch_counts)."""
    if fmt == "bf16":
        return params

    def visit(path, leaf):
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        is_float = hasattr(leaf, "dtype") and leaf.dtype in (
            jnp.float32, jnp.bfloat16, jnp.float16)
        if (not hasattr(leaf, "ndim") or leaf.ndim < 2 or not is_float
                or any(k in keys for k in skip_keys)):
            return leaf
        if not force and (leaf.size < min_size
                          or min(leaf.shape[-2:]) < 64):
            return leaf   # stacked vectors / conv taps / tiny weights
        if leaf.ndim == 2 and "lm_head" not in keys:
            # 2-D leaves inside the stacked block tree are per-layer
            # vectors (norms etc.) -- only the unstacked lm_head matmul
            # weight is a real 2-D GEMM operand
            return leaf
        if leaf.shape[-1] % 2 and fmt == "w4a8":
            return quantize_weight(leaf, "w8a8")
        return quantize_weight(leaf, fmt)

    return jax.tree_util.tree_map_with_path(visit, params)
