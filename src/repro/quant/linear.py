"""Quantized linear layers for the serving path.

Three weight formats, selected by `fmt`:

  "w8a8"        int8 weights [K, N] + per-col scales; int8 dynamic act quant;
                MXU int8 GEMM (kernels/quant_matmul).
  "w4a8"        int4 weights packed two-per-int8-word [K, N//2]; the SILVIA
                packing insight applied to the HBM-bound decode path
                (kernels/packed_matmul): halves weight bytes.
  "bf16"        no quantization (training / baseline).

`quant_linear` is shape-polymorphic over leading batch dims.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.kernels import registry
from repro.quant.quantize import pack_int4, quantize


@dataclasses.dataclass
class QuantLinearParams:
    fmt: str
    w: Any              # bf16 [K,N] | int8 [K,N] | packed int8 [K,N//2]
    w_scale: Any        # f32 [1,N] (quantized formats)
    bias: Any = None


def quantize_linear_params(w, fmt: str, bias=None) -> QuantLinearParams:
    """Offline weight quantization (per-output-channel scales)."""
    if fmt == "bf16":
        return QuantLinearParams(fmt, w.astype(jnp.bfloat16), None, bias)
    if fmt == "w8a8":
        q, s = quantize(w, bits=8, axis=1)
        return QuantLinearParams(fmt, q, s.reshape(1, -1), bias)
    if fmt == "w4a8":
        q, s = quantize(w, bits=4, axis=1)
        return QuantLinearParams(fmt, pack_int4(q), s.reshape(1, -1), bias)
    raise ValueError(fmt)


def quant_linear(x, p: QuantLinearParams):
    """x: [..., K] float -> [..., N] float32 (bf16 passthrough for fmt=bf16)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if p.fmt == "bf16":
        y = jnp.dot(x2.astype(jnp.bfloat16), p.w,
                    preferred_element_type=jnp.float32)
    else:
        x_q, x_s = quantize(x2, bits=8, axis=0)
        if p.fmt == "w8a8":
            y = registry.dispatch("quant_matmul", x_q, p.w, x_s, p.w_scale)
        else:
            y = registry.dispatch("packed_w4_matmul", x_q, p.w, x_s,
                                  p.w_scale)
    if p.bias is not None:
        y = y + p.bias
    n = y.shape[-1]
    return y.reshape(*lead, n)
