"""AdamW with configurable state dtype (bf16 moments halve optimizer HBM --
the lever that lets arctic-480b fit the single-pod mesh) and global-norm
clipping.  Pure pytree-functional: states shard exactly like params.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" halves m/v bytes


def _is_float(x):
    return hasattr(x, "dtype") and x.dtype in (jnp.float32, jnp.bfloat16,
                                               jnp.float16)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)

    def zeros_like(p):
        return jnp.zeros(p.shape, dt) if _is_float(p) else None

    return {
        "m": jax.tree_util.tree_map(zeros_like, params),
        "v": jax.tree_util.tree_map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree) if _is_float(x)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        if not _is_float(p) or g is None:
            return p, m, v
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "clip_scale": scale}
