"""int8 gradient compression with error feedback for data-parallel
all-reduce (a distributed-optimization trick for bandwidth-bound DP).

Usage inside a shard_map'd train step over the `data` axis:

    q, scales, new_err = compress_grads(grads, err_buf)
    g_mean = compressed_psum(q, scales, axis_name="data")

Each float leaf is quantized symmetrically per-leaf to int8
(scale = amax/127); the reduction sums int32 (int8 would overflow at >= 2
participants; the wire format stays 1 byte under a quantized-collective
transport) plus a tiny f32 reduce of scales.  Error feedback accumulates the
quantization residual into the next step's gradients, making the compression
unbiased over time (Seide et al. / EF-SGD style).

Wire cost: ~1 byte/param instead of 4 -- a ~4x reduction of the DP gradient
all-reduce term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_float(x):
    return hasattr(x, "dtype") and x.dtype in (jnp.float32, jnp.bfloat16,
                                               jnp.float16)


def compress_grads(grads, err=None):
    """Returns (q_tree, scale_tree, new_err_tree); float leaves become int8
    + f32 scalar scale, other leaves pass through with scale 1."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = (tdef.flatten_up_to(err) if err is not None
              else [jnp.float32(0.0)] * len(flat_g))
    qs, ss, es = [], [], []
    for g, e in zip(flat_g, flat_e):
        if not _is_float(g):
            qs.append(g)
            ss.append(jnp.float32(1.0))
            es.append(jnp.float32(0.0))
            continue
        gf = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(gf))
        scale = amax / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -128, 127).astype(jnp.int8)
        qs.append(q)
        ss.append(scale)
        es.append(gf - q.astype(jnp.float32) * scale)    # error feedback
    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(qs), unf(ss), unf(es)


def decompress_grads(q_tree, scale_tree):
    flat_q, tdef = jax.tree_util.tree_flatten(q_tree)
    flat_s = tdef.flatten_up_to(scale_tree)
    out = [q.astype(jnp.float32) * s if q.dtype == jnp.int8 else q
           for q, s in zip(flat_q, flat_s)]
    return jax.tree_util.tree_unflatten(tdef, out)


def compressed_psum(q_tree, scale_tree, axis_name: str):
    """Mean-reduce compressed gradients across `axis_name`."""
    flat_q, tdef = jax.tree_util.tree_flatten(q_tree)
    flat_s = tdef.flatten_up_to(scale_tree)
    n = jax.lax.psum(1, axis_name)
    out = []
    for q, s in zip(flat_q, flat_s):
        if q.dtype == jnp.int8:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            smean = jax.lax.pmean(s, axis_name)
            out.append(qsum.astype(jnp.float32) * smean / n)
        elif _is_float(q):
            out.append(jax.lax.pmean(q, axis_name))
        else:
            out.append(q)
    return jax.tree_util.tree_unflatten(tdef, out)
