"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 200, total: int = 10000,
                  min_ratio: float = 0.1):
    """Scale factor in [min_ratio, 1]: linear warmup then cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
