"""Optimizer substrate: AdamW with sharded states, schedules, clipping,
and int8 gradient compression with error feedback."""
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm)
from repro.optim.schedule import warmup_cosine
from repro.optim.compression import (compress_grads, decompress_grads,
                                     compressed_psum)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "compress_grads",
           "compressed_psum", "decompress_grads", "global_norm",
           "warmup_cosine"]
