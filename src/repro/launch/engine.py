"""Continuous-batching serve engine over the cached fused decode loop.

SILVIA packs independent narrow ops into one wide DSP; this engine packs
independent requests into one compiled decode dispatch.  Decode runs in
fixed-length **scan segments** (one dispatch for `segment_len` tokens across
all slots); between segments the scheduler admits queued requests into free
slots and evicts finished ones, so ONE compiled graph serves an
ever-changing request mix:

* **family-agnostic slot state** -- the engine never touches a concrete
  cache layout.  Each model family (dense/vlm/moe KV pages, pure-SSM
  recurrent state, jamba-style hybrid mixes, whisper encdec self+cross
  caches) registers its state builder in `models/slot_state.py`; the
  engine slices, scatters and compacts through the probed
  `SlotStateSpec`, exactly as SILVIA's one packing transformation covers
  add2/mul4/muladd2 behind one DSP-slot interface.
* **bucketed shape cache** -- segment batch size and (for families with a
  sliceable cache-length axis) attended cache length are rounded up to
  power-of-two buckets (launch/scheduler.py), so the SILVIA trace cache
  and `jax.jit` compile a handful of graphs, bounded by the bucket-set
  product (`cache_info()["graphs"]`); `warmup()` pre-compiles the grid.
  Constant-size-state families (SSM) skip length bucketing entirely:
  their graph census grows with batch buckets only.
* **slot-based paged state** -- state buffers carry a slot axis; each slot
  is a page with its own position and active flag, threaded through
  `lm.decode_step` so inactive slots neither mutate their page nor
  contribute sampled tokens.  KV pages are reused WITHOUT scrubbing (the
  causal mask zeroes stale positions exactly); constant-size pages (SSM
  state) are reset-on-admit, because admission overwrites them whole.
* **chunked prefill** -- with `prefill_chunk=C`, prompts are fed through the
  same decode path C tokens at a time (same bucket shapes, same compiled
  family).  KV-cache families only: sequential-state families would change
  the floating-point reduction order (see slot_state.FamilyState).
* **cross-request prefix caching** -- with `prefix_cache=N`, prompt chunks
  are hashed into a content-addressed pool of immutable host-resident
  prefix pages (launch/prefix_cache.py) shared copy-on-write across
  requests: admission copies the longest cached prefix into the slot's
  private pages and prefills only the uncached tail, skipping whole chunk
  dispatches (the TTFT win).  Eviction is LRU-by-refcount -- a page is
  pinned while a live slot was admitted from it.  KV rows are a pure
  function of the token prefix and masking hides everything beyond them,
  so warm streams stay BIT-IDENTICAL to cold ones
  (tests/test_prefix_cache.py) -- including under chaos replay and
  elastic degrade (host pages are mesh-free and re-enter device state
  through the CURRENT plan's PartitionSpecs; DESIGN.md sec. 10).
* **stop tokens** -- a request carrying `stop_tokens` is harvested the
  segment it emits one (the stop token ends the output), instead of
  always running to max_new_tokens.
* **slot compaction** -- when evictions leave holes that inflate the live
  batch bucket, surviving slots are remapped downward on admission
  (`permute_slots`), shrinking the next segment's compiled shape.
* **mesh-aware serving** -- constructed under a `distributed.context.
  mesh_scope`, the engine shard_maps its segment/prefill/chunk fns over
  the mesh (DESIGN.md sec. 7): slot axes shard over the dp axes (request
  packing over devices), probed head/state axes shard over the model
  axis when the config's head counts divide it (slot_state.tp_plan),
  and weights enter under the distributed/sharding.py suffix rules and
  are all_gathered whole at dispatch entry (explicit ZeRO-3 gather).
  Every collective is an exact concat -- no partitioned float
  contraction -- so sharded outputs stay BIT-IDENTICAL to the
  single-device engine (tests/test_sharded_serve.py).  The bucket grid
  is unchanged (the dp size only becomes the batch-bucket floor), so
  the compiled-graph census bound carries over per shard.
* decode bundles live in launch/serve.py's LRU decode cache, keyed
  (cfg, pass set, "engine"); greedy outputs are token-identical to the
  static `serve.generate()` path, including with SILVIA passes on
  (tests/test_engine.py, tests/test_slot_state.py assert bitwise equality
  for dense, ssm, hybrid, and encdec families).
* **resilience** -- admission control (bounded queue + load shedding,
  per-request deadlines/TTL), chaos-testable fault recovery, a
  non-finite-logit quarantine and drain/snapshot hooks, all defined in
  launch/resilience.py and wired through `submit()`/`step()`.  Every
  device dispatch funnels through `_guarded` (the fault-injection site),
  every dispatch failure unwinds to `_recover`, and recovered requests
  REPLAY their recorded tokens through the same compiled decode path, so
  surviving streams are bit-identical to a fault-free run -- SILVIA's
  behavior-preservation obligation carried into failure handling
  (DESIGN.md sec. 8; tests/test_resilience.py).
* **elastic degraded-mesh serving** -- a mesh-aware engine survives losing
  devices (distributed/elastic.py; DESIGN.md sec. 9): a `DeviceLoss`
  fault marks devices dead in the health registry, `_degrade` re-plans
  onto the largest valid healthy sub-mesh (dp floor + tp divisibility),
  rebuilds the compiled bundle (the mesh fingerprint keys the LRU),
  re-shards the weights, and the ordinary recovery path then replays
  in-flight requests on the shrunken mesh -- surviving streams stay
  bit-identical to the fault-free run (tests/test_elastic.py).

Exactness invariants (why masking is exact, not approximate): an attention
row only attends cache positions `<= pos`, every such position was written
by the CURRENT request, and masked score entries become exact float zeros
after softmax.  SSM/conv state is constant-size and masked wholesale
(`jnp.where` on the full page), and serving-mode MoE routes per token
(mlp.moe per_token), so neither stale pages, batch padding, length padding,
nor batch COMPOSITION can perturb an active row by even one ULP.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import core as silvia
from repro.distributed import context as dctx
from repro.distributed import elastic as delastic
from repro.distributed import fault as dfault
from repro.distributed import sharding as dshard
from repro.distributed.fault import SimulatedFailure
from repro.kernels import registry
from repro.launch import methods as smethods
from repro.launch import prefix_cache as pfx
from repro.launch import resilience as res
from repro.launch import sampling
from repro.launch import scheduler
from repro.launch import serve
from repro.models import lm
from repro.models import slot_state


@dataclasses.dataclass(frozen=True)
class _EngineBundle:
    """Compiled callables shared by every engine with the same (cfg, pass
    set); stored in serve.py's LRU decode cache."""
    decode_fn: object      # (params, tok [B,C], cache, pos, active) -> ...
    segment: object        # jitted segment loop (static n_steps)
    chunk_step: object     # jitted single chunk-decode dispatch
    prefill: object        # jitted bucketed full prefill (static cache_len)
    embed: object          # jitted pooled-embedding dispatch (no cache out)


@dataclasses.dataclass(frozen=True)
class _MeshPlan:
    """How a mesh-aware engine lays the serve state over the device mesh
    (built at engine construction from the ambient `mesh_scope`):

    * slot axes of every state leaf, tokens, positions and active masks
      shard over the dp axes -- request packing over devices, the direct
      analogue of SILVIA packing independent narrow ops onto one wide DSP;
    * head/state axes (the probed `tp_axes`) shard over `model_axis` when
      the config's head counts divide it (slot_state.tp_plan);
    * weights enter the shard_map body under the `param_pspecs` suffix
      rules and are all_gathered back whole at segment entry (explicit
      ZeRO-3 gather -- pure data movement, bitwise-exact), then
      attention/SSM re-slice their local head columns.

    Every collective is a gather (exact concat); no float contraction is
    ever partitioned, which is what keeps sharded decode BIT-IDENTICAL to
    the single-device engine.
    """
    mesh: object
    dp_axes: tuple
    model_axis: str
    tp: slot_state.TPPlan
    slot_axes: tuple           # per-leaf, tree_flatten order
    tp_axes: tuple
    state_treedef: object

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp(self):
        return dshard.dp_spec_entry(self.dp_axes)

    def state_specs(self):
        return dshard.slot_state_pspecs(
            self.state_treedef, self.slot_axes, self.tp_axes, self.dp_axes,
            self.model_axis if self.tp.active else None)

    @property
    def key(self) -> tuple:
        """Hashable mesh-topology fingerprint for the decode-bundle LRU:
        two engines may share a compiled bundle only when mesh shape,
        axis roles, device assignment and the tp plan all agree."""
        m = self.mesh
        return (tuple((n, m.shape[n]) for n in m.axis_names),
                tuple(int(d.id) for d in m.devices.flat),
                self.dp_axes, self.model_axis,
                self.tp.size, self.tp.attn, self.tp.ssm,
                self.slot_axes, self.tp_axes)


def _mesh_plan(cfg, spec: slot_state.SlotStateSpec,
               init_kwargs: dict) -> Optional[_MeshPlan]:
    ctx = dctx.current()
    if ctx is None:
        return None
    mesh, dp_axes, model_axis = ctx
    m = mesh.shape[model_axis] if model_axis in mesh.axis_names else 1
    plan = slot_state.tp_plan(cfg, m)
    tp_axes = slot_state.tp_axes_for(cfg, m, **init_kwargs) if plan.active \
        else (None,) * len(spec.batch_axes)
    return _MeshPlan(mesh=mesh, dp_axes=tuple(dp_axes),
                     model_axis=model_axis, tp=plan,
                     slot_axes=spec.batch_axes, tp_axes=tp_axes,
                     state_treedef=spec.treedef)


def _build_bundle(cfg, silvia_passes: str, census: dict,
                  plan: Optional[_MeshPlan] = None) -> _EngineBundle:
    # census is REQUIRED and must be the one the caller keys the bundle
    # LRU with -- computing it here instead would let key and pinned
    # trace diverge
    passes = serve.SILVIA_PASS_SETS[silvia_passes]

    def decode_fn(p, tok, state, pos, active):
        return lm.decode_step(p, tok, state, pos, cfg, active=active)

    if passes:
        decode_fn = silvia.optimize(decode_fn, passes)

    def decode_scan(params, tok, cache, pos, active, samp, n_steps):
        key, temp, top_k, top_p, plen = samp

        def step(carry, _):
            tok, st, pos, bad = carry
            logits, st = decode_fn(params, tok, st, pos, active)
            # per-request sampling (launch/sampling.py): greedy rows take
            # the literal argmax path the pre-sampling engine ran; sampled
            # rows draw under the counter-based key folded with the
            # generated-token index pos - plen + 1, so a slot's stream is
            # a pure function of (seed, rid, logits) -- batch composition,
            # compaction and replay cannot move its bits
            nxt = sampling.sample(logits[:, -1, :], key, temp, top_k,
                                  top_p, pos - plen + 1)
            nxt = nxt[:, None]
            nxt = jnp.where(active[:, None], nxt, 0)
            # output-validation guard: flag slots whose sampled-from logits
            # row went non-finite, so the host can quarantine THAT request
            # (per-slot state is independent, so a poisoned row never
            # perturbs a healthy row's tokens -- the flag is observability,
            # not a numerical change)
            bad = bad | (active & ~jnp.all(
                jnp.isfinite(logits[:, -1, :]), axis=-1))
            # unclamped advance, exactly matching the static loop's pos0+i:
            # every write this segment lands below t_b (the engine sizes
            # t_b >= max(pos)+n_steps), and a slot that finished
            # mid-segment only overruns into its own discarded row (XLA
            # clamps the slice start) before eviction at harvest
            pos = jnp.where(active, pos + 1, pos)
            return (nxt, st, pos, bad), nxt

        carry0 = (tok, cache, pos, jnp.zeros(active.shape, bool))
        (tok, cache, pos, bad), seq = jax.lax.scan(step, carry0,
                                                   None, length=n_steps)
        return seq[:, :, 0], tok, cache, pos, bad

    def prefill_fn(params, prompts, last_positions, cache_len, enc_pad):
        # prompts: [B,S] tokens, or (features, [B,S], enc_lens) for encdec
        # (ragged encoder lengths; enc_pad is the static cross-page width
        # every enc bucket pads up to -- zero-extension is exact, see
        # models/attention.py).  `last` -- each row's final logits row --
        # rides along so score admissions get their first logprob from
        # the SAME dispatch that sampled tok0.
        if isinstance(prompts, tuple) and len(prompts) == 3:
            audio, dec, enc_lens = prompts
            logits, cache = lm.prefill(params, (audio, dec), cfg,
                                       cache_len=cache_len,
                                       last_positions=last_positions,
                                       enc_lengths=enc_lens,
                                       enc_pad=enc_pad)
        else:
            logits, cache = lm.prefill(params, prompts, cfg,
                                       cache_len=cache_len,
                                       last_positions=last_positions)
        last = logits[:, -1, :]
        tok0 = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        bad0 = ~jnp.all(jnp.isfinite(last), axis=-1)
        return tok0, last, cache, bad0

    def embed_fn(params, prompts, last_positions):
        # pooled final-hidden-state embedding (lm.embed_pool): one
        # prefill-shaped dispatch, caches never materialize (DCE'd)
        if isinstance(prompts, tuple) and len(prompts) == 3:
            audio, dec, enc_lens = prompts
            emb = lm.embed_pool(params, (audio, dec), cfg,
                                last_positions=last_positions,
                                enc_lengths=enc_lens)
        else:
            emb = lm.embed_pool(params, prompts, cfg,
                                last_positions=last_positions)
        bad = ~jnp.all(jnp.isfinite(emb), axis=-1)
        return emb, bad

    if plan is None:
        @functools.partial(jax.jit, static_argnums=(6,), donate_argnums=(2,))
        def segment(params, tok, cache, pos, active, samp, n_steps):
            return decode_scan(params, tok, cache, pos, active, samp,
                               n_steps)

        chunk_step = jax.jit(decode_fn, donate_argnums=(2,))
        prefill = functools.partial(jax.jit,
                                    static_argnums=(3, 4))(prefill_fn)
        embed = jax.jit(embed_fn)
    else:
        segment, chunk_step, prefill, embed = _shard_bundle_fns(
            plan, decode_scan, decode_fn, prefill_fn, embed_fn)

    pin = lambda fn: serve._pin_lowerings(fn, census)
    return _EngineBundle(pin(decode_fn), pin(segment), pin(chunk_step),
                         pin(prefill), pin(embed))


def _shard_bundle_fns(plan: _MeshPlan, decode_scan, decode_fn, prefill_fn,
                      embed_fn):
    """shard_map'd segment / chunk-step / prefill over plan.mesh.

    Inside each body the single-device functions run UNMODIFIED on this
    shard's slot slice; the tp scope makes attention/SSM mixers keep only
    their local head block (distributed/context.py).  Weights arrive
    sharded per the param_pspecs suffix rules and are gathered whole
    first -- the explicit FSDP gather, after which every contraction sees
    bitwise the single-device operands."""
    mesh, dp = plan.mesh, plan.dp
    sspecs = plan.state_specs()

    def tp_ctx():
        if plan.tp.active:
            return dctx.tp_scope(plan.model_axis, plan.tp.size,
                                 attn=plan.tp.attn, ssm=plan.tp.ssm)
        return contextlib.nullcontext()

    def pspecs_for(params):
        # at trace time, from the traced arg tree: the bundle stays lazy
        # over params structure (plain vs QTensor leaves), like jit
        return dshard.param_pspecs(params, mesh, None)

    @functools.partial(jax.jit, static_argnums=(6,), donate_argnums=(2,))
    def segment(params, tok, cache, pos, active, samp, n_steps):
        pspecs = pspecs_for(params)

        def body(params, tok, cache, pos, active, samp):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return decode_scan(params, tok, cache, pos, active, samp,
                                   n_steps)

        # the sampling page shards like every other per-slot array: slot
        # axis over dp.  The sampler is per-row (no cross-row reduction),
        # so sharded sampled tokens stay bit-identical to single-device
        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, P(dp), sspecs, P(dp), P(dp),
                                 (P(dp),) * 5),
                       out_specs=(P(None, dp), P(dp), sspecs, P(dp),
                                  P(dp)),
                       check_rep=False)
        return fn(params, tok, cache, pos, active, samp)

    @functools.partial(jax.jit, donate_argnums=(2,))
    def chunk_step(params, tok, cache, pos, active):
        pspecs = pspecs_for(params)

        def body(params, tok, cache, pos, active):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return decode_fn(params, tok, cache, pos, active)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, P(dp), sspecs, P(dp), P(dp)),
                       out_specs=(P(dp), sspecs),
                       check_rep=False)
        return fn(params, tok, cache, pos, active)

    @functools.partial(jax.jit, static_argnums=(3, 4))
    def prefill(params, prompts, last_positions, cache_len, enc_pad):
        pspecs = pspecs_for(params)
        prspecs = jax.tree_util.tree_map(lambda _: P(dp), prompts)

        def body(params, prompts, last_positions):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return prefill_fn(params, prompts, last_positions,
                                  cache_len, enc_pad)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, prspecs, P(dp)),
                       out_specs=(P(dp), P(dp), sspecs, P(dp)),
                       check_rep=False)
        return fn(params, prompts, last_positions)

    @jax.jit
    def embed(params, prompts, last_positions):
        pspecs = pspecs_for(params)
        prspecs = jax.tree_util.tree_map(lambda _: P(dp), prompts)

        def body(params, prompts, last_positions):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return embed_fn(params, prompts, last_positions)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, prspecs, P(dp)),
                       out_specs=(P(dp), P(dp)),
                       check_rep=False)
        return fn(params, prompts, last_positions)

    return segment, chunk_step, prefill, embed


def _engine_bundle(cfg, silvia_passes: str, census: dict,
                   plan: Optional[_MeshPlan] = None) -> _EngineBundle:
    # the census keys out forced-lowering changes AND pins every (lazy)
    # trace of the bundle callables to the resolution the key records;
    # the mesh-plan key keys out topology changes -- a bundle compiled
    # for one mesh (or tp plan) is never served under another
    return serve._DECODE_CACHE.get_or_build(
        (cfg, silvia_passes, tuple(sorted(census.items())), "engine",
         None if plan is None else plan.key),
        lambda: _build_bundle(cfg, silvia_passes, census, plan))


@dataclasses.dataclass(frozen=True)
class SpecDecodeConfig:
    """Self-speculative decoding knobs (ServeEngine `spec_decode=`).

    A small-config draft model of the SAME family free-runs `k` tokens
    per slot, then the target verifies all k in one batched
    `chunk_step`-shaped dispatch -- SILVIA's pack-then-check rewrite at
    the serve-loop level (DESIGN.md sec. 12).  Emitted tokens are always
    the TARGET's tokens under a teacher-forced prefix, so streams are
    byte-identical to the non-speculative engine regardless of how often
    the draft is right; acceptance only changes how many target
    dispatches that takes."""
    draft_params: object
    draft_cfg: object
    k: int = 3

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("spec_decode.k must be >= 1")


@dataclasses.dataclass(frozen=True)
class _SpecFns:
    """Compiled speculative-decode callables (LRU-cached like the engine
    bundle, under a "spec" variant key)."""
    draft: object      # free-running sampled scan w/ const-leaf snapshots
    verify: object     # teacher-forced verify + in-graph accept/rollback
    rollback: object   # const-leaf snapshot-restore select


def _build_spec_fns(cfg, silvia_passes: str, census: dict,
                    spec: slot_state.SlotStateSpec,
                    plan: Optional[_MeshPlan] = None) -> _SpecFns:
    passes = serve.SILVIA_PASS_SETS[silvia_passes]

    def decode_fn(p, tok, state, pos, active):
        return lm.decode_step(p, tok, state, pos, cfg, active=active)

    if passes:
        decode_fn = silvia.optimize(decode_fn, passes)

    def one_step(params, tok, st, pos, active, samp):
        key, temp, top_k, top_p, plen = samp
        logits, st = decode_fn(params, tok, st, pos, active)
        last = logits[:, -1, :]
        g = sampling.sample(last, key, temp, top_k, top_p, pos - plen + 1)
        bad = active & ~jnp.all(jnp.isfinite(last), axis=-1)
        return g, st, bad

    def draft_scan(params, tok, cache, pos, active, samp, n_steps):
        # free-running sampled decode (the DRAFT side of a round): the
        # per-step snapshots of the constant-size leaves let the round
        # roll the draft back to exactly the accepted prefix afterwards
        # (rollback below); length-paged leaves need no snapshot --
        # overrun rows are stale-but-masked (engine docstring)
        def step(carry, _):
            tok, st, pos = carry
            g, st, _ = one_step(params, tok, st, pos, active, samp)
            nxt = jnp.where(active[:, None], g[:, None], 0)
            pos = jnp.where(active, pos + 1, pos)
            return (nxt, st, pos), (g, tuple(spec.const_leaves(st)))

        (_, cache, _), (seq, snaps) = jax.lax.scan(
            step, (tok, cache, pos), None, length=n_steps)
        return seq, cache, snaps

    def verify_scan(params, cache, pos, active, samp, xs):
        # teacher-forced verify of k drafted tokens in ONE batched
        # dispatch: xs is [k+1, B, 1] (the pending token, then the k
        # drafts).  The target's own token at each position rides out in
        # g_seq -- emitted streams are the target's stream by
        # construction -- and the accept count m plus the state rollback
        # happen in-graph, so accept/rollback is one masked slot_state
        # update per round
        def step(carry, tok):
            st, p = carry
            g, st, bad = one_step(params, tok, st, p, active, samp)
            return (st, jnp.where(active, p + 1, p)), \
                (g, bad, tuple(spec.const_leaves(st)))

        (cache, _), (g_seq, bad_seq, snaps) = jax.lax.scan(
            step, (cache, pos), xs)
        k = xs.shape[0] - 1
        drafts = xs[1:, :, 0]
        # m = longest accepted prefix: cumprod of the running equality
        eq = (drafts == g_seq[:k]).astype(jnp.int32)
        m = jnp.sum(jnp.cumprod(eq, axis=0), axis=0)
        cache = spec.rollback_select(cache, snaps, m)
        pos_out = jnp.where(active, pos + m + 1, pos)
        # only steps the round actually consumed (j <= m) can poison it
        used = jnp.arange(k + 1, dtype=jnp.int32)[:, None] <= m[None, :]
        bad = jnp.any(bad_seq & used, axis=0)
        return g_seq, m, cache, pos_out, bad

    def rollback_fn(cache, snaps, idx):
        return spec.rollback_select(cache, snaps, idx)

    if plan is None:
        draft = functools.partial(jax.jit, static_argnums=(6,),
                                  donate_argnums=(2,))(draft_scan)
        verify = functools.partial(jax.jit,
                                   donate_argnums=(1,))(verify_scan)
        rollback = functools.partial(jax.jit,
                                     donate_argnums=(0,))(rollback_fn)
    else:
        draft, verify, rollback = _shard_spec_fns(
            plan, spec, draft_scan, verify_scan, rollback_fn)

    pin = lambda fn: serve._pin_lowerings(fn, census)
    return _SpecFns(pin(draft), pin(verify), pin(rollback))


def _shard_spec_fns(plan: _MeshPlan, spec: slot_state.SlotStateSpec,
                    draft_scan, verify_scan, rollback_fn):
    """shard_map'd speculative-decode fns over plan.mesh -- the same
    layout rules as _shard_bundle_fns (slot axes over dp, samp page over
    dp, weights gathered whole), so sharded spec rounds emit bitwise the
    single-device tokens.  Snapshot stacks carry a LEADING step axis, so
    their specs are the state specs shifted right by one."""
    mesh, dp = plan.mesh, plan.dp
    sspecs = plan.state_specs()
    flat_specs = jax.tree_util.tree_leaves(
        sspecs, is_leaf=lambda x: isinstance(x, P))
    snap_specs = tuple(P(None, *tuple(s))
                       for s, la in zip(flat_specs, spec.length_axes)
                       if la is None)

    def tp_ctx():
        if plan.tp.active:
            return dctx.tp_scope(plan.model_axis, plan.tp.size,
                                 attn=plan.tp.attn, ssm=plan.tp.ssm)
        return contextlib.nullcontext()

    def pspecs_for(params):
        return dshard.param_pspecs(params, mesh, None)

    @functools.partial(jax.jit, static_argnums=(6,), donate_argnums=(2,))
    def draft(params, tok, cache, pos, active, samp, n_steps):
        pspecs = pspecs_for(params)

        def body(params, tok, cache, pos, active, samp):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return draft_scan(params, tok, cache, pos, active, samp,
                                  n_steps)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, P(dp), sspecs, P(dp), P(dp),
                                 (P(dp),) * 5),
                       out_specs=(P(None, dp), sspecs, snap_specs),
                       check_rep=False)
        return fn(params, tok, cache, pos, active, samp)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def verify(params, cache, pos, active, samp, xs):
        pspecs = pspecs_for(params)

        def body(params, cache, pos, active, samp, xs):
            with tp_ctx():
                params = dshard.gather_sharded(params, pspecs)
                return verify_scan(params, cache, pos, active, samp, xs)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, sspecs, P(dp), P(dp),
                                 (P(dp),) * 5, P(None, dp)),
                       out_specs=(P(None, dp), P(dp), sspecs, P(dp),
                                  P(dp)),
                       check_rep=False)
        return fn(params, cache, pos, active, samp, xs)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def rollback(cache, snaps, idx):
        fn = shard_map(rollback_fn, mesh=mesh,
                       in_specs=(sspecs, snap_specs, P(dp)),
                       out_specs=sspecs,
                       check_rep=False)
        return fn(cache, snaps, idx)

    return draft, verify, rollback


def _spec_fns(cfg, silvia_passes: str, census: dict,
              spec: slot_state.SlotStateSpec,
              plan: Optional[_MeshPlan] = None) -> _SpecFns:
    return serve._DECODE_CACHE.get_or_build(
        (cfg, silvia_passes, tuple(sorted(census.items())), "spec",
         None if plan is None else plan.key),
        lambda: _build_spec_fns(cfg, silvia_passes, census, spec, plan))


@dataclasses.dataclass
class _PendingSegment:
    """A dispatched-but-not-harvested decode segment (step_begin /
    step_finish).  The fields are DEVICE arrays still being computed --
    JAX's async dispatch returns futures -- which is what lets the host
    run admission planning and stream publishing while the device works
    (the double-buffered serve loop, launch/frontend.py)."""
    bb: int
    seq: object     # [n_steps, bb] token block
    tok: object     # [bb, 1] final tokens
    pos: object     # [bb] final positions
    bad: object     # [bb] non-finite quarantine flags


class ServeEngine:
    """Continuous-batching greedy-decode engine (see module docstring).

    Parameters
    ----------
    n_slots:        state pages / maximum in-flight requests.
    max_cache_len:  page length; every request needs
                    prompt_len + max_new_tokens <= max_cache_len.  For
                    families without a cache-length axis (SSM) it only
                    bounds request sizes and prompt buckets.
    segment_len:    decode steps per dispatch.  Longer segments amortize
                    dispatch overhead; shorter ones admit/evict sooner --
                    the classic continuous-batching latency/throughput dial.
    silvia_passes:  serve.SILVIA_PASS_SETS key ("off" | "add" | "muladd"
                    | "all").
    prefill_chunk:  if set (power of two), prompts are prefilled through
                    the chunked decode path this many tokens per dispatch;
                    None uses one bucketed full-prefill dispatch.  Only
                    for families whose slot state is prefill-chunkable.
    enc_len:        encdec families only: the fixed encoder length; every
                    request must carry `features` of [enc_len, d_model].
    min_len_bucket / min_batch_bucket: smallest cache-length / batch
                    buckets (both clamped up to the physical maxima).
    resilience:     launch/resilience.py ResilienceConfig -- admission
                    control (queue bound, shed policy, default TTL) and
                    the per-request recovery budget.  None = defaults
                    (unbounded queue, no TTL).
    chaos:          fault-injection schedule for the dispatch path.  The
                    default "env" arms resilience.chaos_from_env()
                    ($REPRO_CHAOS -- how the tier1-chaos CI job injects
                    faults under the whole suite); pass an explicit
                    resilience.ChaosSchedule to pin a schedule, or None
                    to disable injection regardless of the environment.
    prefix_cache:   if set, the page capacity of the cross-request prefix
                    cache (launch/prefix_cache.py): admission reuses
                    pooled prefix pages instead of re-prefilling cached
                    prompt prefixes, bit-identically.  None (the
                    default) disables the pool entirely -- admission is
                    byte-for-byte the pre-pool engine.
    admit_token_budget: admission-fairness cap: each admission round
                    prefills at most this many UNCACHED prompt tokens
                    (the head-of-queue request always proceeds, so big
                    prompts cannot starve); the overflow is deferred back
                    to the queue with arrival order preserved, counted in
                    cache_info()["admission"]["deferrals"].
    """

    def __init__(self, params, cfg, *, n_slots: int = 8,
                 max_cache_len: int = 256, segment_len: int = 16,
                 silvia_passes: str = "off",
                 prefill_chunk: Optional[int] = None,
                 enc_len: Optional[int] = None,
                 min_len_bucket: int = 32, min_batch_bucket: int = 1,
                 resilience: Optional[res.ResilienceConfig] = None,
                 chaos: object = "env",
                 prefix_cache: Optional[int] = None,
                 admit_token_budget: Optional[int] = None,
                 spec_decode: Optional[SpecDecodeConfig] = None):
        if cfg.family == "encdec" and enc_len is None:
            raise ValueError("encdec serving needs enc_len (the fixed "
                             "encoder length of every request's features)")
        if cfg.family != "encdec" and enc_len is not None:
            raise ValueError(f"enc_len is encdec-only, got family "
                             f"{cfg.family!r}")
        init_kwargs = {"s_enc": enc_len} if enc_len is not None else {}
        # raises with registry guidance for unregistered families
        self._spec = slot_state.spec_for(cfg, **init_kwargs)
        if segment_len < 1:
            raise ValueError("segment_len must be >= 1")
        if prefill_chunk is not None and not self._spec.prefill_chunkable:
            raise ValueError(
                f"family {cfg.family!r} slot state is not prefill-chunkable "
                "(models/slot_state.py): use full prefill (prefill_chunk="
                "None)")
        if prefill_chunk is not None and prefill_chunk & (prefill_chunk - 1):
            raise ValueError("prefill_chunk must be a power of two")
        if prefill_chunk is not None and max_cache_len % prefill_chunk:
            # a prompt bucket clamped to the cap must still split into
            # whole chunks, or the prompt tail would be silently dropped
            raise ValueError("max_cache_len must be a multiple of "
                             "prefill_chunk")
        if spec_decode is not None:
            if cfg.family == "encdec":
                raise ValueError("spec_decode does not support encdec "
                                 "serving (draft prefill has no ragged "
                                 "feature path)")
            if spec_decode.draft_cfg.family != cfg.family:
                raise ValueError(
                    f"spec_decode draft must be the SAME family as the "
                    f"target (self-speculation): draft is "
                    f"{spec_decode.draft_cfg.family!r}, target is "
                    f"{cfg.family!r}")
            if spec_decode.draft_cfg.vocab != cfg.vocab:
                raise ValueError("spec_decode draft/target vocab mismatch")
            if prefix_cache is not None or prefill_chunk is not None:
                raise ValueError("spec_decode composes with full-prefill "
                                 "engines only (prefill_chunk=None, "
                                 "prefix_cache=None)")
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_cache_len = max_cache_len
        self.segment_len = segment_len
        self.silvia_passes = silvia_passes
        self.prefill_chunk = prefill_chunk
        self.enc_len = enc_len
        self.min_len_bucket = min(min_len_bucket, max_cache_len)
        # the caller's floor, pre-dp: re-applied when a degraded mesh
        # shrinks the dp floor (_degrade re-buckets from this)
        self._user_min_batch = min(min_batch_bucket, n_slots)
        self.min_batch_bucket = self._user_min_batch
        # mesh-aware serving: an ambient mesh_scope at construction makes
        # the engine shard its decode/prefill bundles over the mesh
        # (module docstring; _MeshPlan).  The slot axis needs to split
        # evenly over the dp shards, so the dp size becomes the batch
        # bucket floor (admission included)
        self._init_kwargs = init_kwargs
        self._plan = _mesh_plan(cfg, self._spec, init_kwargs)
        self._adm_floor = 1
        self._health: Optional[delastic.DeviceHealthRegistry] = None
        self._reshard_s = 0.0
        self._degrade_at: List[float] = []   # serving-clock degrade times
        if self._plan is not None:
            dp = self._plan.dp_size
            scheduler.validate_slot_sharding(n_slots, dp)
            self.min_batch_bucket = min(max(self.min_batch_bucket, dp),
                                        n_slots)
            self._adm_floor = min(dp, n_slots)
            self._health = delastic.DeviceHealthRegistry(
                self._plan.mesh.devices)
        # smallest prompt bucket: chunked prefill needs chunk-aligned
        # buckets; full prefill just avoids degenerate tiny graphs
        self.min_prompt_bucket = min(prefill_chunk or 8, max_cache_len)
        self.batch_buckets = scheduler.bucket_set(self.min_batch_bucket,
                                                  n_slots)
        self.len_buckets = scheduler.bucket_set(self.min_len_bucket,
                                                max_cache_len) \
            if self._spec.has_length_axis else ()
        # encdec: encoder-length buckets for RAGGED features.  The encoder
        # runs at the request's bucket width; the cross-KV page is padded
        # to the full enc_len (slot pages have ONE constant shape) and the
        # padding is masked to exact softmax zeros -- so a short request
        # is bit-identical to itself zero-padded to enc_len
        # (models/attention.py zero-extension invariant).
        self.enc_buckets = scheduler.bucket_set(min(8, enc_len), enc_len) \
            if enc_len is not None else ()

        # pin the lowering census at construction: the bundle (and every
        # graph compiled from it) is traced under THIS resolution, even if
        # the process later mutates REPRO_LOWERING / uses registry.force
        self._lowerings = registry.active_lowerings()
        self._bundle = _engine_bundle(cfg, silvia_passes, self._lowerings,
                                      self._plan)
        self._queue = scheduler.RequestQueue()
        self._cache = self._spec.init_state(n_slots, max_cache_len)
        if self._plan is not None:
            # place weights HBM-sharded per the suffix rules and the slot
            # state per the plan up front; the bundle's out_specs keep
            # both layouts steady across segments
            mesh = self._plan.mesh
            self.params = jax.device_put(
                params, dshard.to_shardings(
                    dshard.param_pspecs(params, mesh, cfg), mesh))
            self._cache = jax.device_put(
                self._cache, dshard.to_shardings(self._plan.state_specs(),
                                                 mesh))
        # per-slot sampling page (launch/sampling.py): host-resident like
        # _tok/_pos, shipped [:bb] as a segment operand each dispatch;
        # registered as a slot_state family so its layout is probed, not
        # hand-declared, and it survives admit/evict/compaction/replay by
        # the same bookkeeping as every other per-slot array
        self._samp = sampling.host_page(n_slots)
        # -- self-speculative decoding (SpecDecodeConfig) --
        self._sd = spec_decode
        self._spec_stats = {"rounds": 0, "drafted": 0, "accepted": 0,
                            "emitted": 0, "target_dispatches": 0}
        if spec_decode is not None:
            dcfg = spec_decode.draft_cfg
            self._draft_spec = slot_state.spec_for(dcfg)
            self._draft_plan = _mesh_plan(dcfg, self._draft_spec, {})
            self._draft_bundle = _engine_bundle(dcfg, silvia_passes,
                                                self._lowerings,
                                                self._draft_plan)
            self._sfns = _spec_fns(cfg, silvia_passes, self._lowerings,
                                   self._spec, self._plan)
            self._dfns = _spec_fns(dcfg, silvia_passes, self._lowerings,
                                   self._draft_spec, self._draft_plan)
            # families whose draft state has constant-size leaves need the
            # explicit snapshot-restore dispatch after each round; pure
            # length-paged drafts roll back for free (stale rows masked)
            self._draft_const = any(
                la is None for la in self._draft_spec.length_axes)
            self._draft_params = spec_decode.draft_params
            self._draft_cache = self._draft_spec.init_state(n_slots,
                                                            max_cache_len)
            if self._draft_plan is not None:
                dmesh = self._draft_plan.mesh
                self._draft_params = jax.device_put(
                    self._draft_params, dshard.to_shardings(
                        dshard.param_pspecs(spec_decode.draft_params,
                                            dmesh, dcfg), dmesh))
                self._draft_cache = jax.device_put(
                    self._draft_cache,
                    dshard.to_shardings(self._draft_plan.state_specs(),
                                        dmesh))
        self._tok = np.zeros((n_slots, 1), np.int32)
        self._pos = np.zeros((n_slots,), np.int32)
        self._active = np.zeros((n_slots,), bool)
        self._slot_req: List[Optional[scheduler.Request]] = [None] * n_slots
        self._remaining = np.zeros((n_slots,), np.int64)
        self.finished: List[scheduler.Request] = []
        self.total_generated = 0
        self.compactions = 0
        self.occupancy: List[float] = []
        self._graphs: set = set()
        # -- resilience state (launch/resilience.py) --
        self._res = resilience if resilience is not None \
            else res.ResilienceConfig()
        self._chaos = res.chaos_from_env() if chaos == "env" else chaos
        self._site_counts = {"segment": 0, "prefill": 0, "chunk": 0,
                             "embed": 0, "draft": 0, "verify": 0}
        self._replay: List[List[int]] = [[] for _ in range(n_slots)]
        # score: remaining teacher-forced completion tokens per slot --
        # drained through the SAME single-token chunk path as recovery
        # replay (_drain_replay), logprobs harvested host-side
        self._score: List[List[int]] = [[] for _ in range(n_slots)]
        self._admitting: List[scheduler.Request] = []
        self._rids: set = set()
        self._results: Dict[int, res.RequestResult] = {}
        # per-method admission bucket accounting (launch/methods.py)
        self._method_admits: Dict[str, int] = {m: 0
                                               for m in smethods.METHODS}
        self._robust: Dict[str, int] = {k: 0 for k in (
            "shed", "expired_queued", "expired_inflight", "failed",
            "quarantined", "faults_injected", "errors", "recoveries",
            "replayed_tokens", "replay_divergence", "duplicate_rejects",
            "snapshots", "restores", "drains", "degraded",
            "cancelled_queued", "cancelled_inflight")}
        # -- cross-request prefix cache (launch/prefix_cache.py) --
        self._prefix: Optional[pfx.PrefixCache] = None
        if prefix_cache is not None:
            # chain (per-chunk) sharing needs EVERY leaf length-paged:
            # resuming mid-prompt would otherwise skip the sequential
            # updates a constant-size leaf accumulated over the skipped
            # chunks.  Families with any constant-size state still share
            # at exact-full-prompt (terminal) granularity.
            chain_ok = prefill_chunk is not None and all(
                la is not None for la in self._spec.length_axes)
            self._prefix = pfx.PrefixCache(
                prefix_cache, chunk=prefill_chunk, chain_ok=chain_ok,
                salt=f"{cfg.family}:{prefill_chunk}")
            if self._plan is not None:
                self._prefix.note_remesh(self._plan.key)
        # keys pinned in the pool per slot, released at eviction
        self._slot_pins: List[tuple] = [()] * n_slots
        # -- admission fairness (token budget) --
        self._admit_budget = admit_token_budget
        self._deferrals = 0

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: scheduler.Request) -> str:
        """Validate and enqueue; returns resilience.QUEUED, or
        resilience.SHED when the bounded queue rejects the newcomer under
        the reject-new policy (under drop-oldest the VICTIM is shed and
        the newcomer queued).  Malformed requests and duplicate rids still
        raise -- those are caller bugs, not load conditions (duplicates
        would corrupt per-rid results and recovery bookkeeping)."""
        if req.rid in self._rids:
            self._robust["duplicate_rejects"] += 1
            raise ValueError(
                f"duplicate request id {req.rid}: this engine already "
                f"tracks that rid (rids key structured results and "
                f"recovery requeues)")
        if req.served_len > self.max_cache_len:
            raise ValueError(
                f"request {req.rid}: prompt+gen {req.served_len} exceeds "
                f"max_cache_len {self.max_cache_len}")
        if self.cfg.family == "encdec":
            shape = None if req.features is None \
                else np.asarray(req.features).shape
            if shape is None or len(shape) != 2 \
                    or shape[1] != self.cfg.d_model \
                    or not 1 <= shape[0] <= self.enc_len:
                raise ValueError(
                    f"request {req.rid}: encdec serving needs features of "
                    f"shape [1..enc_len={self.enc_len}, "
                    f"{self.cfg.d_model}], got {shape}")
        elif req.features is not None:
            raise ValueError(f"request {req.rid}: features are encdec-only "
                             f"(family {self.cfg.family!r})")
        if req.deadline is None and self._res.default_ttl_s is not None:
            req.deadline = req.arrival_time + self._res.default_ttl_s
        cap = self._res.max_queue
        if cap is not None and len(self._queue) >= cap:
            if self._res.shed_policy == "reject-new":
                self._robust["shed"] += 1
                self._rids.add(req.rid)
                self._finish(req, req.arrival_time, res.SHED,
                             f"queue full ({cap} queued), policy "
                             f"reject-new")
                return res.SHED
            victim = self._queue.pop_oldest()       # drop-oldest
            if victim is not None:
                self._robust["shed"] += 1
                self._finish(victim, req.arrival_time, res.SHED,
                             f"queue full ({cap} queued), policy "
                             f"drop-oldest")
        self._rids.add(req.rid)
        self._queue.submit(req)
        return res.QUEUED

    def _finish(self, req: scheduler.Request, now: float,
                outcome: str = res.OK,
                error: Optional[str] = None) -> None:
        req.finish_time = now
        req.outcome = outcome
        req.error = error
        if outcome == res.FAILED:
            self._robust["failed"] += 1
        self.finished.append(req)
        self._results[req.rid] = res.RequestResult(
            rid=req.rid, outcome=outcome, tokens=list(req.tokens),
            error=error, retries=req.retries,
            logprobs=list(req.logprobs) if req.logprobs else None,
            embedding=None if req.embedding is None
            else np.asarray(req.embedding, np.float32))

    def _evict(self, slot: int) -> None:
        """Free a page: no scrubbing needed (see module docstring)."""
        self._active[slot] = False
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._replay[slot] = []
        self._score[slot] = []
        sampling.clear_row(self._samp, slot)
        if self._prefix is not None and self._slot_pins[slot]:
            self._prefix.release(self._slot_pins[slot])
        self._slot_pins[slot] = ()

    @staticmethod
    def _stopped(req: scheduler.Request, tok: int) -> bool:
        return bool(req.stop_tokens) and tok in req.stop_tokens

    # -- admission / prefill ------------------------------------------------

    def _compact(self) -> bool:
        """Remap surviving slots downward when eviction holes inflate the
        live batch bucket (the permutation is exact: slot identity is pure
        bookkeeping, every per-slot array moves together)."""
        live = np.nonzero(self._active)[0]
        if live.size == 0 or int(live[-1]) == live.size - 1:
            return False          # already a dense prefix
        cur = scheduler.bucket_pow2(int(live[-1]) + 1,
                                    minimum=self.min_batch_bucket,
                                    maximum=self.n_slots)
        tgt = scheduler.bucket_pow2(int(live.size),
                                    minimum=self.min_batch_bucket,
                                    maximum=self.n_slots)
        if cur <= tgt:
            return False          # hole doesn't change the bucket
        holes = np.asarray([i for i in range(self.n_slots)
                            if not self._active[i]], np.int64)
        perm = np.concatenate([live, holes])
        self._cache = self._spec.permute_slots(self._cache, perm)
        self._samp = sampling.permute(self._samp, perm)
        if self._sd is not None:
            self._draft_cache = self._draft_spec.permute_slots(
                self._draft_cache, perm)
        self._tok = self._tok[perm]
        self._pos = self._pos[perm]
        self._active = self._active[perm]
        self._remaining = self._remaining[perm]
        self._slot_req = [self._slot_req[i] for i in perm]
        self._replay = [self._replay[i] for i in perm]
        self._score = [self._score[i] for i in perm]
        self._slot_pins = [self._slot_pins[i] for i in perm]
        self.compactions += 1
        return True

    def _admit(self, now: float, clock: scheduler.Clock,
               resume_only: bool = False) -> int:
        self._compact()
        # resume_only (drain): only requests a fault recovery requeued --
        # carrying emitted tokens (generate) or a retry count (score/embed
        # leave no token trail) -- are taken; fresh requests keep their
        # queue position
        pred = (lambda r: bool(r.tokens) or r.retries > 0) \
            if resume_only else None
        # embed admission runs FIRST and separately: an embed request is
        # one prefill-shaped dispatch with no decode slot, so embeds admit
        # even when every slot is busy and never count against the slot
        # path's free-list or token budget (its own admission bucket
        # accounting, cache_info()["methods"])
        embeds = self._queue.pop_ready(
            now, limit=self.n_slots,
            predicate=lambda r: r.method == "embed"
            and (pred is None or pred(r)))
        n_embed = self._admit_embed(embeds, clock) if embeds else 0
        free = [i for i in range(self.n_slots) if not self._active[i]]
        ready = self._queue.pop_ready(
            now, limit=len(free),
            predicate=lambda r: r.method != "embed"
            and (pred is None or pred(r)))
        if ready and self._admit_budget is not None:
            ready = self._defer_over_budget(ready)
        if not ready:
            return n_embed
        # popped but not yet registered in a slot: a fault mid-admission
        # leaves the leftovers here for _recover to requeue
        self._admitting = list(ready)
        # group by (prompt bucket, enc bucket) so one compiled prefill
        # graph per (batch bucket, prompt bucket[, enc bucket]) covers
        # the mix
        groups: Dict[tuple, List[scheduler.Request]] = {}
        for r in ready:
            sb = scheduler.bucket_pow2(r.prompt_len,
                                       minimum=self.min_prompt_bucket,
                                       maximum=self.max_cache_len)
            groups.setdefault((sb, self._enc_bucket(r)), []).append(r)
        for (sb, eb), group in sorted(
                groups.items(), key=lambda kv: (kv[0][0], kv[0][1] or 0)):
            self._admit_group(group, sb, eb, free, clock)
        self._admitting = []
        return len(ready) + n_embed

    def _enc_bucket(self, r: scheduler.Request) -> Optional[int]:
        if self.cfg.family != "encdec":
            return None
        return scheduler.bucket_pow2(int(np.asarray(r.features).shape[0]),
                                     minimum=self.enc_buckets[0],
                                     maximum=self.enc_len)

    def _admit_embed(self, group: List[scheduler.Request],
                     clock: scheduler.Clock) -> int:
        """Serve embed requests: per (prompt bucket, enc bucket) group,
        one pooled-embedding dispatch (lm.embed_pool through the bundle)
        whose result finishes each request immediately -- no slot state is
        touched, so embeds coexist with a full decode batch."""
        self._admitting = list(group)
        groups: Dict[tuple, List[scheduler.Request]] = {}
        for r in group:
            sb = scheduler.bucket_pow2(r.prompt_len,
                                       minimum=self.min_prompt_bucket,
                                       maximum=self.max_cache_len)
            groups.setdefault((sb, self._enc_bucket(r)), []).append(r)
        for (sb, eb), g in sorted(
                groups.items(), key=lambda kv: (kv[0][0], kv[0][1] or 0)):
            bb = scheduler.bucket_pow2(len(g), minimum=self._adm_floor,
                                       maximum=self.n_slots)
            inputs, lens = self._prefill_inputs(g, bb, sb, eb)
            self._graphs.add(("embed", bb, sb)
                             + (() if eb is None else (eb,)))
            emb, bad = self._guarded("embed", self._bundle.embed,
                                     self.params, inputs,
                                     jnp.asarray(lens - 1))
            emb = np.asarray(emb)
            bad = np.asarray(bad)
            now = clock.now()
            for i, r in enumerate(g):
                self._admitting = [x for x in self._admitting if x is not r]
                self._method_admits["embed"] += 1
                if bad[i]:
                    self._robust["quarantined"] += 1
                    self._finish(r, now, res.FAILED,
                                 "non-finite pooled embedding")
                    continue
                r.embedding = np.asarray(emb[i], np.float32)
                r.first_token_time = now
                self._finish(r, now)
        self._admitting = []
        return len(group)

    def _defer_over_budget(
            self, ready: List[scheduler.Request]) -> List[scheduler.Request]:
        """Admission fairness: take ready requests in queue order until
        their summed UNCACHED prompt tokens exceed admit_token_budget,
        then defer the rest back to the queue (ordered re-insertion
        preserves arrival order, so deferral never reorders).  The head
        request always proceeds -- an over-budget prompt stalls behind
        the budget forever otherwise.  With the prefix cache on, a
        request's cost is only its uncached tail (peek, so the budget
        probe never perturbs hit/miss counters or LRU order)."""
        take, spent = [], 0
        for r in ready:
            cost = r.prompt_len
            if self._prefix is not None:
                cost -= min(self._prefix.peek_cached_tokens(r), cost)
            if take and spent + cost > self._admit_budget:
                break
            take.append(r)
            spent += cost
        for r in ready[len(take):]:
            self._deferrals += 1
            self._queue.submit(r)
        return take

    def _prefill_bucket(self, sb: int) -> int:
        """static cache_len for a prefill dispatch.  Families without a
        length axis get cache_len == sb (the arg is unused by their
        blocks, and tying it to the prompt bucket keeps the compiled
        prefill census at one graph per (batch bucket, prompt bucket))."""
        if not self._spec.has_length_axis:
            return sb
        return scheduler.bucket_pow2(sb, minimum=self.min_len_bucket,
                                     maximum=self.max_cache_len)

    def _prefill_inputs(self, group: List[scheduler.Request], bb: int,
                        sb: int, eb: Optional[int] = None):
        prompts = np.zeros((bb, sb), np.int32)
        lens = np.ones((bb,), np.int32)
        for i, r in enumerate(group):
            prompts[i, :r.prompt_len] = r.prompt
            lens[i] = r.prompt_len
        if self.cfg.family != "encdec":
            return jnp.asarray(prompts), lens
        # ragged features, right-padded to the group's enc bucket; the
        # real frame counts ride along and mask the padding to exact
        # zeros inside the encoder and the cross-attention
        eb = eb or self.enc_len
        feats = np.zeros((bb, eb, self.cfg.d_model), np.float32)
        enc_lens = np.ones((bb,), np.int32)
        for i, r in enumerate(group):
            f = np.asarray(r.features, np.float32)
            feats[i, :f.shape[0]] = f
            enc_lens[i] = f.shape[0]
        audio = jnp.asarray(feats).astype(jnp.dtype(self.cfg.dtype))
        return (audio, jnp.asarray(prompts), jnp.asarray(enc_lens)), lens

    def _admit_group(self, group: List[scheduler.Request], sb: int,
                     eb: Optional[int], free: List[int],
                     clock: scheduler.Clock) -> None:
        g = len(group)
        t_pre = self._prefill_bucket(sb)
        if self._prefix is None:
            bb = scheduler.bucket_pow2(g, minimum=self._adm_floor,
                                       maximum=self.n_slots)
            inputs, lens = self._prefill_inputs(group, bb, sb, eb)
            if self.prefill_chunk is None:
                self._graphs.add(("prefill", bb, sb, t_pre)
                                 + (() if eb is None else (eb,)))
                tok0, last, rows, bad0 = self._guarded(
                    "prefill", self._bundle.prefill, self.params, inputs,
                    jnp.asarray(lens - 1), t_pre, self.enc_len)
            else:
                tok0, last, rows, bad0 = self._chunked_prefill(
                    np.asarray(inputs), lens, t_pre)
            tok0 = np.asarray(tok0)
            bad0 = np.asarray(bad0)
            slots = np.asarray([free.pop(0) for _ in range(g)], np.int32)
            # scatter the admitted pages into their slots; leaves without
            # a length axis (SSM/conv state, cross-KV) are reset wholesale
            self._cache = self._spec.admit(self._cache, rows, slots, g,
                                           t_pre=t_pre)
            if self._sd is not None:
                # draft prefill: same prompts, same bucket, same slots --
                # draft and target stay position-synchronized (they share
                # self._pos) from admission through every round/replay
                self._graphs.add(("dprefill", bb, sb, t_pre))
                _, _, d_rows, _ = self._guarded(
                    "draft", self._draft_bundle.prefill,
                    self._draft_params, inputs, jnp.asarray(lens - 1),
                    t_pre, None)
                self._draft_cache = self._draft_spec.admit(
                    self._draft_cache, d_rows, slots, g, t_pre=t_pre)
            pins: List[tuple] = [()] * g
        elif self.prefill_chunk is not None:
            tok0, bad0, slots, pins, last = self._prefix_admit_chunked(
                group, sb, t_pre, free)
        else:
            tok0, bad0, slots, pins, last = self._prefix_admit_full(
                group, sb, eb, t_pre, free)
        # registration time is read AFTER the admitting dispatch, so a
        # request's TTFT (first_token_time - arrival) includes its own
        # prefill cost -- the time a prefix hit actually saves
        self._register_admitted(group, tok0, bad0, slots, pins, free,
                                clock.now(), last=last)

    def _register_admitted(self, group: List[scheduler.Request],
                           tok0: np.ndarray, bad0: np.ndarray,
                           slots: np.ndarray, pins: List[tuple],
                           free: List[int], now: float,
                           last=None) -> None:
        """Per-request bookkeeping once a group's pages are in their
        slots -- the shared tail of the cold and prefix-cache admission
        paths: quarantine, recovery-replay scheduling, fresh-stream
        start.  `last` gives each row's final prefill logits (an array or
        an {index: row} dict); score admissions read their first logprob
        from it (score rows never take the terminal-hit shortcut, so the
        row is always present for them)."""
        # writable copy: np.asarray over a device array is read-only, and
        # sampled admissions override their row's tok0 below
        tok0 = np.array(tok0)
        for i, r in enumerate(group):
            slot = int(slots[i])
            self._admitting = [x for x in self._admitting if x is not r]
            self._method_admits[r.method] += 1
            if bad0[i]:
                # quarantine at prefill: structured FAILED outcome, and
                # the slot's freshly-scattered pages are scrubbed -- the
                # mask zeroes stale FINITE values exactly, but 0*NaN=NaN
                # would leak into a later tenant's softmax.  The slot
                # never owned its pins (release directly)
                if self._prefix is not None and pins[i]:
                    self._prefix.release(pins[i])
                self._robust["quarantined"] += 1
                self._finish(r, now, res.FAILED,
                             "non-finite logits at prefill")
                self._scrub(slot)
                free.append(slot)
                free.sort()
                continue
            # pins transfer to the slot BEFORE any eviction path below,
            # so _evict is the single release point for owned pins
            self._slot_pins[slot] = tuple(pins[i])
            if r.method == "generate" and not sampling.is_greedy(r):
                # sampled first token, recomputed host-side from this
                # row's final prefill logits at generated-token index 0
                # (bitwise the in-scan sample: the sampler is per-row).
                # Non-greedy rows never take the terminal-hit shortcut,
                # so the row is always present; the pool keeps the GREEDY
                # argmax token, so cached entries stay policy-free
                tok0[i, 0] = sampling.expected_token(r, last[i], 0)
            # the slot's sampling-page row: policy + counter key +
            # prompt_len, consumed by every segment/spec dispatch
            sampling.write_row(self._samp, slot, r)
            if r.method == "score":
                # teacher-forced scoring: the prefill's last logits row is
                # the distribution completion[0] is scored under; the rest
                # of the completion drains through the replay chunk path.
                # A recovery re-admission recomputes bitwise-identical
                # rows, so resetting logprobs repeats the lost floats.
                comp = list(r.score_tokens)
                row = np.asarray(last[i], np.float32)
                r.logprobs = [smethods.logprob_from_logits(row, comp[0])]
                if r.first_token_time is None:
                    r.first_token_time = now
                if len(comp) == 1:
                    self._finish(r, now)
                    self._evict(slot)
                    free.append(slot)
                    free.sort()
                    continue
                self._slot_req[slot] = r
                self._active[slot] = True
                self._pos[slot] = r.prompt_len
                self._tok[slot] = comp[0]
                self._remaining[slot] = 0
                self._score[slot] = [int(t) for t in comp[1:]]
                continue
            if r.tokens:
                # recovery-as-replay: this request was requeued by
                # _recover with its already-emitted tokens.  The prefill
                # above bitwise repeated its original admission (original
                # prompt -> same prompt bucket -> same compiled graph);
                # verify the regenerated first token and schedule the
                # remaining recorded tokens for teacher-forced replay
                # through the decode path (_drain_replay)
                if int(tok0[i, 0]) != r.tokens[0]:
                    self._robust["replay_divergence"] += 1
                self._slot_req[slot] = r
                self._active[slot] = True
                self._pos[slot] = r.prompt_len
                self._tok[slot] = r.tokens[0]
                self._remaining[slot] = r.max_new_tokens - len(r.tokens)
                self._replay[slot] = [int(t) for t in r.tokens[1:]]
                continue
            r.tokens = [int(tok0[i, 0])]
            r.first_token_time = now
            self.total_generated += 1
            if r.max_new_tokens == 1 or self._stopped(r, r.tokens[0]):
                self._finish(r, now)
                self._evict(slot)
                free.append(slot)
                free.sort()
                continue
            self._slot_req[slot] = r
            self._active[slot] = True
            self._pos[slot] = r.prompt_len
            self._tok[slot] = tok0[i]
            self._remaining[slot] = r.max_new_tokens - 1

    def _concat_pages(self, entries: List[pfx.Entry]) -> list:
        """Concatenate consecutive chain entries' pages along each leaf's
        length axis (host-side; chain entries exist only for all-length-
        paged families, so no leaf is None)."""
        out = []
        for j, la in enumerate(self._spec.length_axes):
            ps = [e.pages[j] for e in entries]
            out.append(ps[0] if len(ps) == 1 else np.concatenate(ps,
                                                                 axis=la))
        return out

    def _chunk_pages(self, span: list, j: int, c: int) -> list:
        """Host-side chunk j of an extracted multi-chunk span."""
        out = []
        for la, p in zip(self._spec.length_axes, span):
            if p is None:
                out.append(None)
                continue
            idx = [slice(None)] * p.ndim
            idx[la] = slice(j * c, (j + 1) * c)
            out.append(np.ascontiguousarray(p[tuple(idx)]))
        return out

    def _reshard_state(self) -> None:
        """Host-sourced page writes re-enter device state under the
        CURRENT plan's PartitionSpecs (the _scrub pattern) -- this is
        where pooled pages get 're-sharded' after an elastic degrade."""
        if self._plan is not None:
            self._cache = jax.device_put(
                self._cache, dshard.to_shardings(self._plan.state_specs(),
                                                 self._plan.mesh))

    def _prefix_admit_full(self, group: List[scheduler.Request], sb: int,
                           eb: Optional[int], t_pre: int,
                           free: List[int]):
        """Prefix-cache admission for full-prefill engines (every family,
        including sequential-state ones): an exact-repeat (terminal) hit
        copies its pooled pages -- KV rows plus constant-size state
        snapshots -- straight into the slot, ZERO prefill dispatches; the
        misses prefill as one smaller bucketed sub-group (batch
        composition cannot perturb a row, module docstring, so the
        shrunken bucket is bit-safe) and donate their pages back to the
        pool."""
        g = len(group)
        slots = np.asarray([free.pop(0) for _ in range(g)], np.int32)
        tok0 = np.zeros((g, 1), np.int32)
        bad0 = np.zeros((g,), bool)
        pins: List[tuple] = [()] * g
        last: Dict[int, np.ndarray] = {}
        miss_idx: List[int] = []
        wrote = False
        for i, r in enumerate(group):
            # score requests need the final LOGITS row, which pooled pages
            # don't carry -- they always take the prefill path (and still
            # donate their pages for later generate hits); skipping lookup
            # keeps their traffic out of the hit/miss stats and LRU order.
            # Sampled (non-greedy) requests also need the row: a pooled
            # entry's tok0 is the GREEDY token, theirs must be re-sampled
            hit = self._prefix.lookup(r) \
                if r.method != "score" and sampling.is_greedy(r) else None
            if hit is None or hit.terminal is None:
                miss_idx.append(i)
                continue
            ent = hit.terminal
            self._cache = self._spec.write_row_pages(
                self._cache, int(slots[i]), 0, ent.pages)
            wrote = True
            tok0[i, 0] = ent.tok0
            pins[i] = self._prefix.pin([ent.key])
            self._prefix.note_skip(r.prompt_len)
        if miss_idx:
            sub = [group[i] for i in miss_idx]
            bb = scheduler.bucket_pow2(len(sub), minimum=self._adm_floor,
                                       maximum=self.n_slots)
            inputs, lens = self._prefill_inputs(sub, bb, sb, eb)
            self._graphs.add(("prefill", bb, sb, t_pre)
                             + (() if eb is None else (eb,)))
            stok0, slast, rows, sbad0 = self._guarded(
                "prefill", self._bundle.prefill, self.params, inputs,
                jnp.asarray(lens - 1), t_pre, self.enc_len)
            stok0 = np.asarray(stok0)
            sbad0 = np.asarray(sbad0)
            need_last = any(group[i].method == "score"
                            or not sampling.is_greedy(group[i])
                            for i in miss_idx)
            slast_np = np.asarray(slast) if need_last else None
            sub_slots = slots[np.asarray(miss_idx, np.int64)]
            self._cache = self._spec.admit(self._cache, rows, sub_slots,
                                           len(sub), t_pre=t_pre)
            for j, i in enumerate(miss_idx):
                tok0[i, 0] = stok0[j, 0]
                bad0[i] = sbad0[j]
                if slast_np is not None:
                    last[i] = slast_np[j]
                if not sbad0[j]:
                    r = group[i]
                    self._prefix.insert_terminal(
                        r, self._spec.extract_row_pages(
                            rows, j, 0, r.prompt_len),
                        int(stok0[j, 0]))
        if wrote:
            self._reshard_state()
        return tok0, bad0, slots, pins, last

    def _prefix_admit_chunked(self, group: List[scheduler.Request],
                              sb: int, t_pre: int, free: List[int]):
        """Prefix-cache admission for chunked-prefill engines: each row
        resumes at its first uncached chunk -- pooled chunk pages are
        copied in below the resume point (copy-on-write: everything at or
        past it is computed into the row's private pages) -- and a chunk
        dispatch is skipped outright once every row is past it.  The rows
        that do run go through the SAME compiled ("chunk", bb, c, t_pre)
        graph as a cold admission; copied pages are bitwise what this
        row's own chunks would have written (KV purity), and masking
        hides batch composition, so the harvested logits -- and every
        downstream token -- are bit-identical to the cold path."""
        g = len(group)
        bb = scheduler.bucket_pow2(g, minimum=self._adm_floor,
                                   maximum=self.n_slots)
        c = min(self.prefill_chunk, sb)
        n_chunks = sb // c
        prompts = np.zeros((bb, sb), np.int32)
        lens = np.ones((bb,), np.int32)
        for i, r in enumerate(group):
            prompts[i, :r.prompt_len] = r.prompt
            lens[i] = r.prompt_len
        last_chunk = (lens - 1) // c
        cache = self._spec.init_state(bb, t_pre)
        resume = np.full((bb,), n_chunks, np.int64)  # padding: never runs
        term: List[Optional[pfx.Entry]] = [None] * g
        n_chain = [0] * g
        pin_keys: List[List[bytes]] = [[] for _ in range(g)]
        for i, r in enumerate(group):
            if r.method == "score":
                # score needs the final logits row: run every chunk cold
                # (resume stays 0; pages still donate back to the pool)
                # without touching the pool's stats or LRU order
                resume[i] = 0
                continue
            hit = self._prefix.lookup(r)
            if hit.terminal is not None and sampling.is_greedy(r):
                # terminal shortcut is greedy-only: the pooled tok0 is
                # the argmax token.  A sampled request still rides any
                # chain hits below and re-runs its final chunk, which
                # recovers the logits row its tok0 is sampled from
                cache = self._spec.write_row_pages(cache, i, 0,
                                                   hit.terminal.pages)
                term[i] = hit.terminal
                pin_keys[i].append(hit.terminal.key)
                self._prefix.note_skip(r.prompt_len)
                continue
            if hit.chain:
                # one write per leaf for the whole cached span (chunk
                # pages concatenated host-side), not one per chunk
                cache = self._spec.write_row_pages(
                    cache, i, 0, self._concat_pages(hit.chain))
                pin_keys[i].extend(ent.key for ent in hit.chain)
            n_chain[i] = len(hit.chain)
            # resume at the first uncached chunk; a chain covering the
            # final chunk still re-runs it (rewriting identical bits)
            # to recover the first-token logits
            resume[i] = min(len(hit.chain), int(last_chunk[i]))
            self._prefix.note_skip(int(resume[i]) * c)
        last: Dict[int, object] = {}
        for k in range(n_chunks):
            act = (resume <= k) & (k <= last_chunk)
            act[g:] = False
            if not act.any():
                continue    # every row is past this chunk: no dispatch
            self._graphs.add(("chunk", bb, c, t_pre))
            toks = jnp.asarray(prompts[:, k * c:(k + 1) * c])
            pos = jnp.full((bb,), k * c, jnp.int32)
            logits, cache = self._guarded(
                "chunk", self._bundle.chunk_step, self.params, toks,
                cache, pos, jnp.asarray(act))
            hit_rows = np.nonzero((last_chunk == k) & act)[0]
            if hit_rows.size:
                # harvest on the host: a device gather would compile one
                # program per hit-row arity, and argmax over the exact
                # same bits is order-free either way
                lg = np.asarray(logits)
                for b in hit_rows:
                    last[int(b)] = lg[int(b), int((lens[b] - 1) % c)]
        tok0 = np.zeros((g, 1), np.int32)
        bad0 = np.zeros((g,), bool)
        for i in range(g):
            if term[i] is not None:
                tok0[i, 0] = term[i].tok0
                continue
            row = np.asarray(last[i])
            # host argmax over identical logits bits == the device argmax
            # (comparison-based, no float accumulation; same argument as
            # _replay_step)
            tok0[i, 0] = int(np.argmax(row))
            bad0[i] = not bool(np.all(np.isfinite(row)))
        # donate computed pages back to the pool (never from a faulted
        # dispatch -- an exception above unwinds before this point)
        for i in range(g):
            if term[i] is not None or bad0[i]:
                continue
            r = group[i]
            # ONE extraction (and one blocking device transfer) per miss
            # row: the terminal pages cover [0, prompt_len), and chain
            # chunk pages are host-side slices of them (chain_ok engines
            # have every leaf length-paged, so the slices line up)
            full = self._spec.extract_row_pages(cache, i, 0, r.prompt_len)
            n_full = r.prompt_len // c
            if self._prefix.chain_ok and n_full > n_chain[i]:
                keys = self._prefix.chain_keys(r.prompt, req=r)
                for k in range(n_chain[i], n_full):
                    self._prefix.insert_chain(
                        keys[k], self._chunk_pages(full, k, c))
            self._prefix.insert_terminal(r, full, int(tok0[i, 0]))
        pins = [self._prefix.pin(pk) for pk in pin_keys]
        slots = np.asarray([free.pop(0) for _ in range(g)], np.int32)
        self._cache = self._spec.admit(self._cache, cache, slots, g,
                                       t_pre=t_pre)
        self._reshard_state()
        return tok0, bad0, slots, pins, last

    def _chunked_prefill(self, prompts: np.ndarray, lens: np.ndarray,
                         t_pre: int):
        """Prefill through the decode path, `prefill_chunk` tokens per
        dispatch -- the same compiled family (and bucket shapes) as decode
        segments, so prefill work interleaves instead of needing its own
        wide graphs."""
        bb, sb = prompts.shape
        c = min(self.prefill_chunk, sb)
        assert sb % c == 0, (sb, c)
        cache = self._spec.init_state(bb, t_pre)
        active = jnp.ones((bb,), bool)
        # only each row's last-real-position logits are needed; harvest
        # them per chunk so one [bb, c, V] block is ever live
        last = [None] * bb
        self._graphs.add(("chunk", bb, c, t_pre))
        for k in range(sb // c):
            toks = jnp.asarray(prompts[:, k * c:(k + 1) * c])
            pos = jnp.full((bb,), k * c, jnp.int32)
            logits, cache = self._guarded(
                "chunk", self._bundle.chunk_step, self.params, toks,
                cache, pos, active)
            hit = np.nonzero((lens - 1) // c == k)[0]
            if hit.size:
                sel = logits[jnp.asarray(hit),
                             jnp.asarray((lens[hit] - 1) % c)]
                for j, b in enumerate(hit):
                    last[b] = sel[j]
        stack = jnp.stack(last)
        tok0 = jnp.argmax(stack, axis=-1)
        bad0 = ~jnp.all(jnp.isfinite(stack), axis=-1)
        return tok0.astype(jnp.int32)[:, None], stack, cache, bad0

    # -- decode segments ----------------------------------------------------

    def _segment_shape(self):
        """(bb, t_b) for the next segment; t_b is None for constant-size
        state (no length bucketing -- batch-bucket-only graph growth)."""
        hi = int(np.max(np.nonzero(self._active)[0])) + 1
        bb = scheduler.bucket_pow2(hi, minimum=self.min_batch_bucket,
                                   maximum=self.n_slots)
        if not self._spec.has_length_axis:
            return bb, None
        need = int(np.max(self._pos[:bb][self._active[:bb]])) \
            + self.segment_len
        t_b = scheduler.bucket_pow2(min(need, self.max_cache_len),
                                    minimum=self.min_len_bucket,
                                    maximum=self.max_cache_len)
        return bb, t_b

    def _begin_segment(self) -> "_PendingSegment":
        """DISPATCH one fused decode segment over the bucketed active
        prefix and return immediately -- the outputs stay device arrays
        (JAX async dispatch), so the host is free to do other work while
        the device crunches.  `_finish_segment` is the blocking sync."""
        bb, t_b = self._segment_shape()
        n_steps = self.segment_len
        self._graphs.add(("segment", bb, t_b, n_steps))
        fast = bb == self.n_slots and (t_b is None
                                       or t_b == self.max_cache_len)
        cache_in = self._cache if fast else \
            self._spec.slice_live(self._cache, bb, t_b)
        seq, tok, cache_out, pos, bad = self._guarded(
            "segment", self._bundle.segment,
            self.params, jnp.asarray(self._tok[:bb]), cache_in,
            jnp.asarray(self._pos[:bb]), jnp.asarray(self._active[:bb]),
            sampling.operand(self._samp, bb), n_steps)
        if fast:
            self._cache = cache_out
        else:
            self._cache = self._spec.merge_live(self._cache, cache_out,
                                                bb, t_b)
        self.occupancy.append(float(np.sum(self._active)) / self.n_slots)
        return _PendingSegment(bb=bb, seq=seq, tok=tok, pos=pos, bad=bad)

    def _finish_segment(self, p: "_PendingSegment",
                        clock: scheduler.Clock) -> None:
        """Block on a dispatched segment's outputs and harvest.  An
        eviction between begin and finish (cancel/expire) is safe: the
        tok/pos writeback lands stale values on the freed slot, but an
        inactive slot's tok/pos are dead state -- admission overwrites
        them before the slot decodes again, and _harvest skips slots
        whose request is gone."""
        self._tok[:p.bb] = np.asarray(p.tok)
        self._pos[:p.bb] = np.asarray(p.pos)
        self._harvest(np.asarray(p.seq), np.asarray(p.bad), clock.now())

    def _harvest(self, seq: np.ndarray, bad: np.ndarray,
                 now: float) -> None:
        n_steps, bb = seq.shape
        for slot in range(bb):
            req = self._slot_req[slot]
            if req is None:
                continue
            if bad[slot]:
                # quarantine: this slot's logits went non-finite during
                # the segment.  Masking isolation means no OTHER slot saw
                # it, but this segment's tokens for the slot are not
                # trustworthy (the flag is per-segment, not per-step), so
                # the request fails with the tokens it had, and its pages
                # are scrubbed before reuse (_scrub)
                self._robust["quarantined"] += 1
                self._finish(req, now, res.FAILED,
                             "non-finite logits during decode")
                self._evict(slot)
                self._scrub(slot)
                continue
            take = int(min(self._remaining[slot], n_steps))
            toks = seq[:take, slot]
            done = False
            if req.stop_tokens:
                hits = np.nonzero(np.isin(toks, req.stop_tokens))[0]
                if hits.size:
                    toks = toks[:int(hits[0]) + 1]   # stop token included
                    done = True
            req.tokens.extend(int(t) for t in toks)
            self.total_generated += len(toks)
            self._remaining[slot] -= len(toks)
            if done or self._remaining[slot] == 0:
                self._finish(req, now)
                self._evict(slot)

    # -- self-speculative decoding (SpecDecodeConfig) ------------------------

    def _spec_round(self, clock: scheduler.Clock) -> None:
        """One speculative round: the draft free-runs k+1 sampled steps
        (k drafts, plus the consumption step a full acceptance needs),
        the target verifies all k drafts in ONE batched dispatch, and
        both states roll back to the accepted prefix in-graph -- SILVIA's
        speculatively-pack / verify-legality / roll-back-on-conflict
        rewrite at the serve-loop level (DESIGN.md sec. 12).

        Emitted tokens are always the TARGET's g_seq tokens under a
        teacher-forced prefix, so streams are byte-identical to the
        non-speculative engine no matter how often the draft is right;
        acceptance only changes how many tokens one target dispatch
        yields (tokens-per-dispatch, benchmarks/spec_decode.py).  Both
        models sample under the SAME per-slot counter keys, so acceptance
        is a pure function of (seed, rid, token prefix) -- recovery
        replay is therefore acceptance-history-exact by construction."""
        k = self._sd.k
        hi = int(np.max(np.nonzero(self._active)[0])) + 1
        bb = scheduler.bucket_pow2(hi, minimum=self.min_batch_bucket,
                                   maximum=self.n_slots)
        t_b = None
        if self._spec.has_length_axis:
            # the verify scan writes rows pos..pos+k (overruns clamp into
            # the slot's own discarded row, as in decode_scan)
            need = int(np.max(self._pos[:bb][self._active[:bb]])) + k + 1
            t_b = scheduler.bucket_pow2(min(need, self.max_cache_len),
                                        minimum=self.min_len_bucket,
                                        maximum=self.max_cache_len)
        self._graphs.add(("draft", bb, t_b, k + 1))
        self._graphs.add(("verify", bb, t_b, k + 1))
        samp = sampling.operand(self._samp, bb)
        tok = jnp.asarray(self._tok[:bb])
        pos = jnp.asarray(self._pos[:bb])
        active = jnp.asarray(self._active[:bb])
        fast = bb == self.n_slots and (t_b is None
                                       or t_b == self.max_cache_len)
        d_in = self._draft_cache if fast else \
            self._draft_spec.slice_live(self._draft_cache, bb, t_b)
        d_seq, d_cache, d_snaps = self._guarded(
            "draft", self._dfns.draft, self._draft_params, tok, d_in,
            pos, active, samp, k + 1)
        # the verify dispatch consumes the pending token then the k
        # drafts, teacher-forced
        xs = jnp.concatenate([tok[None], d_seq[:k, :, None]], axis=0)
        c_in = self._cache if fast else \
            self._spec.slice_live(self._cache, bb, t_b)
        g_seq, m, c_out, pos_out, bad = self._guarded(
            "verify", self._sfns.verify, self.params, c_in, pos, active,
            samp, xs)
        if self._draft_const:
            # constant-size draft leaves restore from the per-step
            # snapshots; pure length-paged drafts roll back for free
            self._graphs.add(("rollback", bb, t_b, k + 1))
            d_cache = self._dfns.rollback(d_cache, d_snaps, m)
        if fast:
            self._cache = c_out
            self._draft_cache = d_cache
        else:
            self._cache = self._spec.merge_live(self._cache, c_out,
                                                bb, t_b)
            self._draft_cache = self._draft_spec.merge_live(
                self._draft_cache, d_cache, bb, t_b)
        self.occupancy.append(float(np.sum(self._active)) / self.n_slots)
        self._pos[:bb] = np.asarray(pos_out)
        self._spec_harvest(np.asarray(g_seq), np.asarray(m),
                           np.asarray(bad), clock.now())

    def _spec_harvest(self, g_seq: np.ndarray, m: np.ndarray,
                      bad: np.ndarray, now: float) -> None:
        """Host bookkeeping after a round: per live slot, emit the m+1
        target tokens the round settled (the accepted drafts' positions
        plus the first disagreeing/extending target token) -- the same
        stop-token/remaining logic as _harvest, so streams truncate
        identically."""
        k1, bb = g_seq.shape
        self._spec_stats["rounds"] += 1
        self._spec_stats["target_dispatches"] += 1
        for slot in range(bb):
            req = self._slot_req[slot]
            if req is None or not self._active[slot]:
                continue
            if bad[slot]:
                self._robust["quarantined"] += 1
                self._finish(req, now, res.FAILED,
                             "non-finite logits during decode")
                self._evict(slot)
                self._scrub(slot)
                continue
            self._spec_stats["drafted"] += k1 - 1
            self._spec_stats["accepted"] += int(m[slot])
            e = int(m[slot]) + 1
            take = int(min(self._remaining[slot], e))
            toks = g_seq[:take, slot]
            done = False
            if req.stop_tokens:
                hits = np.nonzero(np.isin(toks, req.stop_tokens))[0]
                if hits.size:
                    toks = toks[:int(hits[0]) + 1]
                    done = True
            req.tokens.extend(int(t) for t in toks)
            self.total_generated += len(toks)
            self._spec_stats["emitted"] += len(toks)
            self._remaining[slot] -= len(toks)
            if done or self._remaining[slot] == 0:
                self._finish(req, now)
                self._evict(slot)
                continue
            # the new pending token: the target's token right after the
            # last accepted draft (pos was advanced to p+m+1 in-graph)
            self._tok[slot] = g_seq[e - 1, slot]

    # -- resilience: chaos sites, expiry, replay, recovery ------------------

    def _guarded(self, kind: str, fn, *args):
        """Every device dispatch funnels through here: count the per-kind
        site, give the chaos schedule its shot at it, then dispatch.  The
        check fires BEFORE the call, so an injected fault never leaves a
        donated buffer half-consumed; failures unwind to step()/drain(),
        which recover."""
        idx = self._site_counts[kind]
        self._site_counts[kind] = idx + 1
        if self._chaos is not None:
            self._chaos.check_site(f"{kind}:{idx}")
        return fn(*args)

    def _expire(self, now: float) -> int:
        """EXPIRED outcomes for requests past their deadline: queued ones
        never dispatch; in-flight ones are cancelled by slot eviction,
        keeping the tokens already emitted."""
        n = 0
        for req in self._queue.pop_expired(now):
            self._robust["expired_queued"] += 1
            self._finish(req, now, res.EXPIRED,
                         "deadline exceeded in queue")
            n += 1
        for slot in range(self.n_slots):
            req = self._slot_req[slot]
            if req is not None and req.expired(now):
                self._robust["expired_inflight"] += 1
                self._finish(req, now, res.EXPIRED,
                             "deadline exceeded in flight")
                self._evict(slot)
                n += 1
        return n

    def _scrub(self, slot: int) -> None:
        """Overwrite a quarantined slot's pages with freshly initialized
        state.  Normal eviction never scrubs (stale FINITE values are
        masked to exact zeros -- module docstring), but non-finite pages
        would survive the mask: a masked softmax weight is an exact 0,
        and 0 * NaN = NaN."""
        zeros = self._spec.init_state(1, self.max_cache_len)
        self._cache = self._spec.admit(self._cache, zeros,
                                       np.asarray([slot], np.int32), 1)
        if self._plan is not None:
            self._cache = jax.device_put(
                self._cache, dshard.to_shardings(self._plan.state_specs(),
                                                 self._plan.mesh))
        if self._sd is not None:
            # the draft saw the same poisoned row: scrub its page too
            dz = self._draft_spec.init_state(1, self.max_cache_len)
            self._draft_cache = self._draft_spec.admit(
                self._draft_cache, dz, np.asarray([slot], np.int32), 1)
            if self._draft_plan is not None:
                self._draft_cache = jax.device_put(
                    self._draft_cache,
                    dshard.to_shardings(self._draft_plan.state_specs(),
                                        self._draft_plan.mesh))

    def _drain_replay(self, clock: scheduler.Clock) -> None:
        """Teacher-forced replay of recovered requests' recorded tokens,
        one single-token chunk dispatch at a time, through the SAME
        compiled decode family as live traffic.  Replaying -- rather than
        re-prefilling prompt+emitted in one go -- is what keeps recovery
        bit-exact for EVERY family: prefill and stepwise decode are
        different floating-point reduction orders for sequential state
        (slot_state.FamilyState.prefill_chunkable), but a replayed step
        repeats the fault-free step's ops bitwise.  Each replayed token is
        verified against the recorded stream (`replay_divergence` --
        determinism doubling as the recovery proof obligation, DESIGN.md
        sec. 8).

        Score requests drain through the SAME dispatches: teacher-forcing
        a fixed completion is exactly replay with the expected token
        supplied by the caller instead of the recorded stream, plus a
        host logprob harvested from each step's logits row
        (methods.logprob_from_logits)."""
        while any(self._replay) or any(self._score):
            self._replay_step(clock.now())

    def _replay_step(self, now: float) -> None:
        hi = int(np.max(np.nonzero(self._active)[0])) + 1
        bb = scheduler.bucket_pow2(hi, minimum=self.min_batch_bucket,
                                   maximum=self.n_slots)
        t_b = None
        if self._spec.has_length_axis:
            need = int(np.max(self._pos[:bb][self._active[:bb]])) + 1
            t_b = scheduler.bucket_pow2(min(need, self.max_cache_len),
                                        minimum=self.min_len_bucket,
                                        maximum=self.max_cache_len)
        self._graphs.add(("chunk", bb, 1, t_b))
        # only slots mid-replay (or mid-score) are active in this
        # dispatch: co-resident caught-up requests neither advance nor
        # perturb (masking + batch composition invariants, module
        # docstring)
        replaying = np.asarray([bool(self._replay[s])
                                or bool(self._score[s])
                                for s in range(bb)])
        fast = bb == self.n_slots and (t_b is None
                                       or t_b == self.max_cache_len)
        cache_in = self._cache if fast else \
            self._spec.slice_live(self._cache, bb, t_b)
        logits, cache_out = self._guarded(
            "chunk", self._bundle.chunk_step,
            self.params, jnp.asarray(self._tok[:bb]), cache_in,
            jnp.asarray(self._pos[:bb]), jnp.asarray(replaying))
        if fast:
            self._cache = cache_out
        else:
            self._cache = self._spec.merge_live(self._cache, cache_out,
                                                bb, t_b)
        if self._sd is not None:
            # the draft teacher-forces the same token at the same
            # position, so draft state stays replay-synchronized and the
            # post-recovery rounds draft from exactly the state a
            # fault-free run would have -- acceptance-history-exact
            self._graphs.add(("dchunk", bb, 1, t_b))
            d_in = self._draft_cache if fast else \
                self._draft_spec.slice_live(self._draft_cache, bb, t_b)
            _, d_out = self._guarded(
                "draft", self._draft_bundle.chunk_step,
                self._draft_params, jnp.asarray(self._tok[:bb]), d_in,
                jnp.asarray(self._pos[:bb]), jnp.asarray(replaying))
            if fast:
                self._draft_cache = d_out
            else:
                self._draft_cache = self._draft_spec.merge_live(
                    self._draft_cache, d_out, bb, t_b)
        last = logits[:, -1, :]
        nxt = np.asarray(jnp.argmax(last, axis=-1))
        bad = np.asarray(~jnp.all(jnp.isfinite(last), axis=-1))
        # full rows transfer when a score slot needs its logprob, or a
        # sampled slot needs replay verification (sampling.sample_host)
        need_rows = any(self._score[s] for s in range(bb)) or any(
            self._replay[s] and self._slot_req[s] is not None
            and not sampling.is_greedy(self._slot_req[s])
            for s in range(bb))
        last_np = np.asarray(last) if need_rows else None
        for slot in range(bb):
            if not replaying[slot]:
                continue
            if bad[slot]:
                self._robust["quarantined"] += 1
                self._finish(self._slot_req[slot], now, res.FAILED,
                             "non-finite logits during replay")
                self._evict(slot)
                self._scrub(slot)
                continue
            if self._score[slot]:
                req = self._slot_req[slot]
                tok = self._score[slot].pop(0)
                req.logprobs.append(
                    smethods.logprob_from_logits(last_np[slot], tok))
                self._tok[slot] = tok      # teacher forcing
                self._pos[slot] += 1
                if not self._score[slot]:
                    self._finish(req, now)
                    self._evict(slot)
                continue
            expect = self._replay[slot].pop(0)
            self._robust["replayed_tokens"] += 1
            req = self._slot_req[slot]
            # greedy: host argmax over identical logits bits == the
            # in-scan argmax (comparison-based, no float accumulation).
            # Sampled: recompute the token through the SAME jitted
            # sampler on this row (sampling.expected_token) -- the
            # counter key needs only (seed, rid, t), no sampler state
            if sampling.is_greedy(req):
                actual = int(nxt[slot])
            else:
                actual = sampling.expected_token(
                    req, last_np[slot],
                    int(self._pos[slot]) - req.prompt_len + 1)
            if actual != expect:
                self._robust["replay_divergence"] += 1
            self._tok[slot] = expect       # teacher forcing
            self._pos[slot] += 1

    def _degrade(self, exc: "delastic.DeviceLoss") -> None:
        """Elastic re-shard after device loss (distributed/elastic.py).

        SILVIA rebinds ops to fewer DSPs with identical results; this
        rebinds slots to fewer devices with identical tokens (DESIGN.md
        sec. 9).  The health registry drops the lost devices, the planner
        picks the largest valid healthy sub-mesh (dp floor + tp
        divisibility respected), and the engine rebuilds itself on it:
        new `_MeshPlan` (its `key` makes the decode-bundle LRU compile a
        FRESH bundle -- a bundle built for the dead mesh is never
        dispatched again), re-bucketed admission floors (the dp floor may
        shrink), params re-sharded onto the survivors
        (fault.elastic_remesh = param_pspecs on the new mesh), and a
        cleared graph census (every old graph targeted dead devices).
        `_recover` then rebuilds slot state under the NEW plan and
        replays in-flight requests bit-exactly -- no operator in the
        loop."""
        t0 = time.perf_counter()
        lost = self._health.kill(exc.n_lost)
        old = self._plan
        new_mesh = delastic.plan_degraded_mesh(
            old.mesh, self._health.healthy(), dp_axes=old.dp_axes,
            model_axis=old.model_axis, n_slots=self.n_slots, cfg=self.cfg)
        with dctx.mesh_scope(new_mesh, old.dp_axes, old.model_axis):
            self._plan = _mesh_plan(self.cfg, self._spec, self._init_kwargs)
        dp = self._plan.dp_size
        scheduler.validate_slot_sharding(self.n_slots, dp)
        self.min_batch_bucket = min(max(self._user_min_batch, dp),
                                    self.n_slots)
        self._adm_floor = min(dp, self.n_slots)
        self.batch_buckets = scheduler.bucket_set(self.min_batch_bucket,
                                                  self.n_slots)
        self._bundle = _engine_bundle(self.cfg, self.silvia_passes,
                                      self._lowerings, self._plan)
        self.params = dfault.elastic_remesh(self.params, new_mesh, self.cfg)
        if self._sd is not None:
            dcfg = self._sd.draft_cfg
            with dctx.mesh_scope(new_mesh, old.dp_axes, old.model_axis):
                self._draft_plan = _mesh_plan(dcfg, self._draft_spec, {})
            self._draft_bundle = _engine_bundle(dcfg, self.silvia_passes,
                                                self._lowerings,
                                                self._draft_plan)
            self._sfns = _spec_fns(self.cfg, self.silvia_passes,
                                   self._lowerings, self._spec, self._plan)
            self._dfns = _spec_fns(dcfg, self.silvia_passes,
                                   self._lowerings, self._draft_spec,
                                   self._draft_plan)
            self._draft_params = dfault.elastic_remesh(
                self._draft_params, new_mesh, dcfg)
        self._graphs = set()
        self._robust["degraded"] += 1
        if self._prefix is not None:
            # pooled pages are host-resident and mesh-free: nothing to
            # invalidate, they re-shard through the NEW plan's
            # PartitionSpecs on the next write-back (_reshard_state);
            # the pool records the new fingerprint for observability
            self._prefix.note_remesh(self._plan.key)
        self._reshard_s += time.perf_counter() - t0
        del lost  # recorded in self._health.dead_ids (cache_info)

    def _recover(self, exc: Exception, now: float) -> None:
        """Requeue every in-flight (and mid-admission) request with its
        already-emitted tokens, then rebuild the slot state from scratch.
        The rebuilt state is NEVER derived from the old buffers: a failed
        dispatch may already have consumed its donated cache argument.
        Requeued requests re-enter through normal admission and REPLAY
        their recorded tokens before generating new ones, so surviving
        streams stay bit-identical to a fault-free run.  Device-loss
        faults additionally re-plan the mesh FIRST (`_degrade`), so the
        rebuilt state and the replay both land on the degraded mesh."""
        key = "faults_injected" if isinstance(exc, SimulatedFailure) \
            else "errors"
        self._robust[key] += 1
        self._robust["recoveries"] += 1
        if isinstance(exc, delastic.DeviceLoss) and self._plan is not None:
            self._degrade_at.append(now)
            self._degrade(exc)
        victims = [r for r in self._slot_req if r is not None]
        seen = {id(r) for r in victims}
        victims += [r for r in self._admitting
                    if id(r) not in seen and r.outcome is None]
        self._admitting = []
        for r in victims:
            r.retries += 1
            if r.retries > self._res.max_recoveries:
                self._finish(r, now, res.FAILED,
                             f"recovery budget "
                             f"({self._res.max_recoveries}) exhausted; "
                             f"last error: {exc}")
            else:
                self._queue.submit(r)
        self._cache = self._spec.init_state(self.n_slots,
                                            self.max_cache_len)
        if self._plan is not None:
            self._cache = jax.device_put(
                self._cache, dshard.to_shardings(self._plan.state_specs(),
                                                 self._plan.mesh))
        if self._sd is not None:
            self._draft_cache = self._draft_spec.init_state(
                self.n_slots, self.max_cache_len)
            if self._draft_plan is not None:
                self._draft_cache = jax.device_put(
                    self._draft_cache,
                    dshard.to_shardings(self._draft_plan.state_specs(),
                                        self._draft_plan.mesh))
        self._samp = sampling.host_page(self.n_slots)
        self._tok[:] = 0
        self._pos[:] = 0
        self._active[:] = False
        self._remaining[:] = 0
        self._slot_req = [None] * self.n_slots
        self._replay = [[] for _ in range(self.n_slots)]
        self._score = [[] for _ in range(self.n_slots)]
        if self._prefix is not None:
            for pk in self._slot_pins:
                if pk:
                    self._prefix.release(pk)
        self._slot_pins = [()] * self.n_slots

    # -- driver -------------------------------------------------------------

    def step(self, clock: Optional[scheduler.Clock] = None) -> bool:
        """Admit what has arrived, then run one decode segment.  Returns
        False when there was nothing to do (caller should wait for the next
        arrival).  Dispatch failures -- injected or real -- never escape:
        `_recover` requeues the in-flight work and subsequent steps replay
        it bit-exactly.  Equivalent to step_begin + an immediate
        step_finish (same dispatch order, same bits)."""
        clock = clock or scheduler.Clock()
        try:
            pending, progressed = self._step_begin_inner(clock)
            if pending is None:
                return progressed
            self._finish_segment(pending, clock)
            return True
        except Exception as e:  # noqa: BLE001 -- the serve loop survives
            self._recover(e, clock.now())
            return True

    def step_begin(self, clock: Optional[scheduler.Clock] = None):
        """First half of step(): expire/admit/replay, then DISPATCH one
        decode segment WITHOUT syncing on it.  Returns (pending,
        progressed); pending is None when no segment ran.  While the
        segment is in flight, the host may submit(), cancel(), publish
        already-harvested tokens and run admission_plan() -- the
        double-buffered serve pipeline (launch/frontend.py) -- then MUST
        call step_finish(pending).  Failures surfacing at dispatch
        recover here (returning (None, True)); failures surfacing at the
        blocking sync recover in step_finish."""
        clock = clock or scheduler.Clock()
        try:
            return self._step_begin_inner(clock)
        except Exception as e:  # noqa: BLE001
            self._recover(e, clock.now())
            return None, True

    def step_finish(self, pending,
                    clock: Optional[scheduler.Clock] = None) -> bool:
        """Second half of step(): block on the dispatched segment and
        harvest its tokens."""
        clock = clock or scheduler.Clock()
        try:
            self._finish_segment(pending, clock)
        except Exception as e:  # noqa: BLE001
            self._recover(e, clock.now())
        return True

    def _step_begin_inner(self, clock: scheduler.Clock,
                          resume_only: bool = False):
        now = clock.now()
        expired = self._expire(now)
        admitted = self._admit(now, clock, resume_only=resume_only)
        self._drain_replay(clock)
        if not self._active.any():
            return None, bool(admitted or expired)
        if self._sd is not None:
            # speculative rounds are synchronous (draft -> verify ->
            # rollback -> harvest); there is no pending segment to
            # double-buffer, the round IS the step
            self._spec_round(clock)
            return None, True
        return self._begin_segment(), True

    def _step_inner(self, clock: scheduler.Clock,
                    resume_only: bool = False) -> bool:
        pending, progressed = self._step_begin_inner(clock, resume_only)
        if pending is None:
            return progressed
        self._finish_segment(pending, clock)
        return True

    def cancel(self, rid: int, now: float = 0.0,
               reason: Optional[str] = None) -> bool:
        """Cancel a request by rid (stream disconnects, client aborts).
        Queued: removed before it ever dispatches.  In flight: the slot
        is evicted mid-stream and the request finishes CANCELLED with the
        tokens (or logprobs) harvested so far -- per-slot state isolation
        means the surviving batch mates are not perturbed by even one ULP
        (module docstring).  Returns False when the rid is not live
        (unknown, or already finished)."""
        req = self._queue.remove(rid)
        if req is not None:
            self._robust["cancelled_queued"] += 1
            self._finish(req, now, res.CANCELLED,
                         reason or "cancelled while queued")
            return True
        for slot in range(self.n_slots):
            req = self._slot_req[slot]
            if req is not None and req.rid == rid:
                self._robust["cancelled_inflight"] += 1
                self._finish(req, now, res.CANCELLED,
                             reason or "cancelled in flight")
                self._evict(slot)
                return True
        return False

    def drain(self, clock: Optional[scheduler.Clock] = None) -> None:
        """Finish all in-flight work WITHOUT admitting fresh requests
        (recovering requests -- requeued by a fault mid-drain with
        emitted tokens or a retry count -- are still re-admitted so their
        streams complete).  Fresh queued requests stay queued; pair with
        snapshot()/restore() for rolling restarts."""
        clock = clock or scheduler.Clock()
        self._robust["drains"] += 1
        while True:
            try:
                self._step_inner(clock, resume_only=True)
            except Exception as e:  # noqa: BLE001
                self._recover(e, clock.now())
                continue
            if not self._active.any() and not any(
                    r.tokens or r.retries > 0
                    for r in self._queue.pending()):
                return

    def snapshot(self, ckpt_dir: str, step: int = 0) -> str:
        """Persist queue + per-slot request state atomically through
        checkpoint/ckpt.py (launch/resilience.py encoding).  In-flight
        requests are stored WITH their emitted tokens and resume on
        restore() through the bit-exact recovery/replay path, so device
        state never needs serializing.  The snapshot is stamped with the
        CURRENT mesh topology (observability only): because request state
        is mesh-free, a snapshot taken on mesh A restores onto mesh B --
        including a single device -- with bit-identical tokens
        (tests/test_elastic.py)."""
        reqs = [r for r in self._slot_req if r is not None] \
            + list(self._queue.pending())
        self._robust["snapshots"] += 1
        extra = None
        if self._plan is not None:
            p = self._plan
            extra = {"mesh": {
                "shape": {n: p.mesh.shape[n] for n in p.mesh.axis_names},
                "dp_axes": list(p.dp_axes), "model_axis": p.model_axis,
                "dead_devices": list(self._health.dead_ids),
            }}
        return res.snapshot_requests(ckpt_dir, step, reqs, extra=extra)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Load a snapshot into this (fresh or drained) engine's queue;
        returns the number of requests restored."""
        reqs = res.restore_requests(ckpt_dir, step=step)
        for r in reqs:
            if r.rid in self._rids:
                raise ValueError(
                    f"restore: rid {r.rid} is already tracked by this "
                    f"engine (restore targets a fresh or drained engine)")
            self._rids.add(r.rid)
            self._queue.submit(r)
        self._robust["restores"] += 1
        return len(reqs)

    def results(self) -> Dict[int, res.RequestResult]:
        """Structured terminal outcome per finished request, keyed by rid
        (resilience.RequestResult: outcome OK/SHED/EXPIRED/FAILED/
        CANCELLED, tokens, logprobs, embedding, error, retries)."""
        return dict(self._results)

    def result(self, rid: int) -> Optional[res.RequestResult]:
        """The structured result of one request, or None while it is
        still queued/in flight."""
        return self._results.get(rid)

    def next_arrival(self, now: float) -> Optional[float]:
        """Earliest future arrival_time in the queue (None when nothing
        is in transit) -- the front-end's idle-wait target."""
        return self._queue.next_arrival(now)

    def admission_plan(self) -> int:
        """Host-side admission planning that is safe to run while a
        dispatched segment is in flight (between step_begin and
        step_finish): precompute and memoize the prefix-cache content
        digests of queued requests, so the NEXT admission starts with its
        sha256 work already done.  Pure host bookkeeping -- no device
        dispatch, no admission decision, no LRU mutation -- so running it
        mid-segment cannot perturb a single bit of the served streams.
        Returns the number of requests whose digests were warmed."""
        if self._prefix is None:
            return 0
        return sum(1 for r in self._queue.pending()
                   if self._prefix.warm_digest(r))

    def run(self, requests: Sequence[scheduler.Request] = (),
            clock: Optional[scheduler.Clock] = None) -> Dict[int, np.ndarray]:
        """Serve until the queue drains; returns {rid: generated tokens}."""
        for r in requests:
            self.submit(r)
        clock = clock or scheduler.Clock()
        while True:
            if not self.step(clock):
                nxt = self._queue.next_arrival(clock.now())
                if nxt is not None:
                    clock.wait_until(nxt)
                    continue
                if not len(self._queue) and not self._active.any():
                    break
        return {r.rid: np.asarray(r.tokens, np.int32) for r in self.finished}

    # -- observability ------------------------------------------------------

    @property
    def prompt_buckets(self) -> tuple:
        return scheduler.bucket_set(self.min_prompt_bucket,
                                    self.max_cache_len)

    @property
    def admission_batch_buckets(self) -> tuple:
        return scheduler.bucket_set(self._adm_floor, self.n_slots)

    def graph_bound(self) -> int:
        """Upper bound on distinct compiled graphs: the segment bucket grid
        (batch buckets only for constant-size state) plus one prefill (or
        chunk) graph per (admission batch bucket, prompt bucket[, enc
        bucket]) -- what `warmup()` walks -- plus the same-size embed grid
        and the single-token chunk grid that BOTH recovery replay and the
        score method walk (score traffic can arrive on any engine, so the
        chunk grid is always in the bound)."""
        enc = max(1, len(self.enc_buckets))
        seg = len(self.batch_buckets) * max(1, len(self.len_buckets))
        pre = len(self.admission_batch_buckets) \
            * len(self.prompt_buckets) * enc
        bound = seg + pre + seg + pre
        if self._sd is not None:
            # draft/verify/rollback round grids (segments themselves
            # never dispatch on a spec engine, but their term stays in
            # the base bound), plus the draft prefill and draft replay
            # chunk grids
            bound += 3 * seg + pre + seg
        return bound

    def _warmup_prefill_inputs(self, bb: int, sb: int,
                               eb: Optional[int] = None):
        prompts = jnp.zeros((bb, sb), jnp.int32)
        if self.cfg.family != "encdec":
            return prompts
        eb = eb or self.enc_len
        audio = jnp.zeros((bb, eb, self.cfg.d_model),
                          jnp.dtype(self.cfg.dtype))
        return (audio, prompts, jnp.full((bb,), eb, jnp.int32))

    def warmup(self, prompt_lens: Optional[Sequence[int]] = None,
               methods: Sequence[str] = ("generate",)) -> int:
        """Pre-compile the (batch bucket x length bucket) segment grid on
        throwaway state, plus -- when the expected prompt-length mix is
        known -- the prefill graphs it maps to; returns the number of
        graphs compiled.  `methods` names the servable methods the
        traffic will use: "score" additionally warms the single-token
        chunk grid its teacher-forcing drains through, "embed" the pooled
        embedding graphs (launch/methods.py) -- without these a
        multi-method front-end pays their compiles mid-traffic."""
        n = 0
        state0 = self._spec.init_state(self.n_slots, self.max_cache_len)
        if self._plan is not None:
            state0 = jax.device_put(
                state0, dshard.to_shardings(self._plan.state_specs(),
                                            self._plan.mesh))
        if self._sd is None:
            for bb in self.batch_buckets:
                for t_b in (self.len_buckets or (None,)):
                    key = ("segment", bb, t_b, self.segment_len)
                    if key in self._graphs:
                        continue
                    # feed the segment the same state the serve loop
                    # will: the live slot state (plan-sharded on a mesh)
                    # for the "fast" full combo, a slice_live view
                    # otherwise -- compiling on a fresh unsharded
                    # init_state would leave the sharded variant to
                    # lazy-compile mid-traffic
                    fast = (bb == self.n_slots
                            and t_b in (None, self.max_cache_len))
                    cache = state0 if fast else \
                        self._spec.slice_live(state0, bb, t_b)
                    out = self._bundle.segment(
                        self.params, jnp.zeros((bb, 1), jnp.int32), cache,
                        jnp.zeros((bb,), jnp.int32),
                        jnp.zeros((bb,), bool),
                        sampling.null_operand(bb), self.segment_len)
                    jax.block_until_ready(out[0])
                    self._graphs.add(key)
                    n += 1
                    # also pre-compile the eager merge wrapper a
                    # non-"fast" segment step runs on the FULL slot
                    # state, with the segment's own output sub-state as
                    # the merge source -- exactly the operands the serve
                    # loop hands it
                    if not fast:
                        state0 = self._spec.merge_live(state0, out[2],
                                                       bb, t_b)
        else:
            # a spec-decode engine never dispatches plain segments: warm
            # the draft/verify(/rollback) round grid instead, on the same
            # state shapes _spec_round slices
            k = self._sd.k
            dstate0 = self._draft_spec.init_state(self.n_slots,
                                                  self.max_cache_len)
            if self._draft_plan is not None:
                dstate0 = jax.device_put(
                    dstate0,
                    dshard.to_shardings(self._draft_plan.state_specs(),
                                        self._draft_plan.mesh))
            for bb in self.batch_buckets:
                for t_b in (self.len_buckets or (None,)):
                    key = ("verify", bb, t_b, k + 1)
                    if key in self._graphs:
                        continue
                    fast = (bb == self.n_slots
                            and t_b in (None, self.max_cache_len))
                    d_in = dstate0 if fast else \
                        self._draft_spec.slice_live(dstate0, bb, t_b)
                    c_in = state0 if fast else \
                        self._spec.slice_live(state0, bb, t_b)
                    samp = sampling.null_operand(bb)
                    zt = jnp.zeros((bb, 1), jnp.int32)
                    zp = jnp.zeros((bb,), jnp.int32)
                    za = jnp.zeros((bb,), bool)
                    d_seq, d_cache, d_snaps = self._dfns.draft(
                        self._draft_params, zt, d_in, zp, za, samp, k + 1)
                    xs = jnp.concatenate([zt[None], d_seq[:k, :, None]],
                                         axis=0)
                    out = self._sfns.verify(self.params, c_in, zp, za,
                                            samp, xs)
                    if self._draft_const:
                        d_cache = self._dfns.rollback(d_cache, d_snaps,
                                                      out[1])
                        self._graphs.add(("rollback", bb, t_b, k + 1))
                    jax.block_until_ready(out[0])
                    self._graphs.add(("draft", bb, t_b, k + 1))
                    self._graphs.add(key)
                    n += 2
                    if not fast:
                        state0 = self._spec.merge_live(state0, out[2],
                                                       bb, t_b)
                        dstate0 = self._draft_spec.merge_live(
                            dstate0, d_cache, bb, t_b)
        if self._chaos is not None or "score" in methods:
            # a chaos-armed engine WILL recover, and recovery replays
            # through single-token chunk dispatches: pre-compile that grid
            # too, so the census stays warm-bounded under injected faults
            # (tier1-chaos runs the warmup-census tests unchanged).
            # Scoring teacher-forces completions through the SAME grid.
            for bb in self.batch_buckets:
                for t_b in (self.len_buckets or (None,)):
                    key = ("chunk", bb, 1, t_b)
                    if key in self._graphs:
                        continue
                    cache = self._spec.init_state(
                        bb, t_b or self.max_cache_len)
                    out = self._bundle.chunk_step(
                        self.params, jnp.zeros((bb, 1), jnp.int32), cache,
                        jnp.zeros((bb,), jnp.int32),
                        jnp.zeros((bb,), bool))
                    jax.block_until_ready(out[0])
                    self._graphs.add(key)
                    n += 1
                    if self._sd is not None:
                        # replay advances the draft through the same
                        # single-token grid
                        dcache = self._draft_spec.init_state(
                            bb, t_b or self.max_cache_len)
                        dout = self._draft_bundle.chunk_step(
                            self._draft_params,
                            jnp.zeros((bb, 1), jnp.int32), dcache,
                            jnp.zeros((bb,), jnp.int32),
                            jnp.zeros((bb,), bool))
                        jax.block_until_ready(dout[0])
                        self._graphs.add(("dchunk", bb, 1, t_b))
                        n += 1
        if prompt_lens is None:
            return n
        sbs = sorted({scheduler.bucket_pow2(pl,
                                            minimum=self.min_prompt_bucket,
                                            maximum=self.max_cache_len)
                      for pl in prompt_lens})
        # encdec admission groups ragged features by enc bucket, and the
        # compile cache keys on the audio operand shape: warm every
        # bucket or ragged traffic pays the smaller ones mid-stream
        ebs = self.enc_buckets or (None,)
        for bb in self.admission_batch_buckets:
            for sb in sbs:
                for eb in ebs:
                    t_pre = self._prefill_bucket(sb)
                    lens = jnp.ones((bb,), jnp.int32)
                    if self.prefill_chunk is None:
                        key = ("prefill", bb, sb, t_pre) \
                            + (() if eb is None else (eb,))
                        if key in self._graphs:
                            continue
                        out = self._bundle.prefill(
                            self.params,
                            self._warmup_prefill_inputs(bb, sb, eb),
                            lens - 1, t_pre, self.enc_len)
                    else:
                        key = ("chunk", bb, min(self.prefill_chunk, sb),
                               t_pre)
                        if key in self._graphs:
                            continue
                        out = self._chunked_prefill(
                            np.zeros((bb, sb), np.int32),
                            np.asarray(lens), t_pre)
                    jax.block_until_ready(out[0])
                    self._graphs.add(key)
                    n += 1
                    if self._sd is not None:
                        dkey = ("dprefill", bb, sb, t_pre)
                        if dkey not in self._graphs:
                            dout = self._draft_bundle.prefill(
                                self._draft_params,
                                self._warmup_prefill_inputs(bb, sb, eb),
                                lens - 1, t_pre, None)
                            jax.block_until_ready(dout[0])
                            self._graphs.add(dkey)
                            n += 1
        if "embed" in methods:
            for bb in self.admission_batch_buckets:
                for sb in sbs:
                    for eb in ebs:
                        key = ("embed", bb, sb) \
                            + (() if eb is None else (eb,))
                        if key in self._graphs:
                            continue
                        lens = jnp.ones((bb,), jnp.int32)
                        out = self._bundle.embed(
                            self.params,
                            self._warmup_prefill_inputs(bb, sb, eb),
                            lens - 1)
                        jax.block_until_ready(out[0])
                        self._graphs.add(key)
                        n += 1
        if self._prefix is not None:
            # pre-compile the pool's page ops.  The dynamic_slice /
            # dynamic_update_slice programs are keyed by the FULL operand
            # shape, not just the page size, so the warm set must cover
            # every state shape admission actually touches: the
            # (bb, t_pre) local prefill states (chunked path + full-path
            # extraction from prefill rows) and the engine's own
            # (n_slots, max_cache_len) slot state (full-path terminal
            # writes).  Sizes are the advertised prompt lengths plus every
            # whole-chunk span up to the longest.
            sizes = {int(pl) for pl in prompt_lens}
            if self.prefill_chunk is not None:
                cc = self.prefill_chunk
                sizes |= {k * cc for k in range(1, max(sizes) // cc + 1)}
            big = self._spec.init_state(self.n_slots, self.max_cache_len)
            if self._plan is not None:
                # the live slot state is sharded: warm the sharded
                # variant of the programs, not the host one
                big = jax.device_put(
                    big, dshard.to_shardings(self._plan.state_specs(),
                                             self._plan.mesh))
            for s in sorted(sizes):
                pages = self._spec.extract_row_pages(big, 0, 0, s)
                big = self._spec.write_row_pages(big, 0, 0, pages)
            for bb in self.admission_batch_buckets:
                for t in sorted({self._prefill_bucket(sb) for sb in sbs}):
                    local = self._spec.init_state(bb, t)
                    for s in sorted(x for x in sizes if x <= t):
                        pages = self._spec.extract_row_pages(
                            local, 0, 0, s)
                        local = self._spec.write_row_pages(
                            local, 0, 0, pages)
                    if (self._plan is not None
                            and self.prefill_chunk is not None):
                        # what admission actually scatters is the CHUNK
                        # DISPATCH's output state, whose leaves carry the
                        # shard_map out-shardings -- run one chunk on the
                        # written state (an already-warmed graph key) so
                        # the admit below compiles on those shardings
                        cands = [min(self.prefill_chunk, sb) for sb in sbs
                                 if self._prefill_bucket(sb) == t]
                        if cands:
                            c = max(cands)
                            _, local = self._bundle.chunk_step(
                                self.params,
                                jnp.zeros((bb, c), jnp.int32), local,
                                jnp.zeros((bb,), jnp.int32),
                                jnp.zeros((bb,), bool))
                    # admission also scatters the local rows into the
                    # slot state with one eager program per admitted
                    # GROUP SIZE (the slots index array is [g]):
                    # pre-compile every arity so neither the warm nor
                    # the cold serving path pays it mid-run
                    for g in range(1, min(bb, self.n_slots) + 1):
                        big = self._spec.admit(
                            big, local,
                            np.arange(g, dtype=np.int32), g, t_pre=t)
            jax.block_until_ready(jax.tree_util.tree_leaves(big))
        return n

    def cache_info(self) -> dict:
        """Compiled-graph census: engine shape keys (bounded by the bucket
        sets), the active kernel lowering per packed op (the registry
        resolution every compiled graph in this census was traced under),
        the serve-module decode-bundle LRU, and -- with SILVIA passes on --
        the pass pipeline's own trace-cache counters."""
        info = {
            "family": self.cfg.family,
            "has_length_axis": self._spec.has_length_axis,
            "graphs": len(self._graphs),
            "graph_bound": self.graph_bound(),
            "graph_keys": sorted(self._graphs,
                                 key=lambda k: tuple(str(x) for x in k)),
            "batch_buckets": list(self.batch_buckets),
            "len_buckets": list(self.len_buckets),
            "enc_buckets": list(self.enc_buckets),
            "compactions": self.compactions,
            "methods": {"admits": dict(self._method_admits)},
            "lowerings": dict(self._lowerings),
            "decode_bundle_lru": serve.decode_cache_info(),
            "robustness": dict(self._robust),
            "dispatch_sites": dict(self._site_counts),
            "admission": {
                "token_budget": self._admit_budget,
                "deferrals": self._deferrals,
            },
            "resilience": {
                "max_queue": self._res.max_queue,
                "shed_policy": self._res.shed_policy,
                "default_ttl_s": self._res.default_ttl_s,
                "max_recoveries": self._res.max_recoveries,
                "chaos": None if self._chaos is None else {
                    "sites": list(self._chaos.fail_at_sites),
                    "rate": self._chaos.rate,
                    "seed": self._chaos.seed,
                    "max_failures": self._chaos.max_failures,
                    "fired": sorted(self._chaos.failed),
                },
            },
        }
        if self._prefix is not None:
            info["prefix_cache"] = self._prefix.info()
        if self._sd is not None:
            s = dict(self._spec_stats)
            s["k"] = self._sd.k
            s["draft"] = getattr(self._sd.draft_cfg, "name",
                                 str(self._sd.draft_cfg))
            s["acceptance_rate"] = (s["accepted"] / s["drafted"]) \
                if s["drafted"] else 0.0
            s["tokens_per_dispatch"] = (
                s["emitted"] / s["target_dispatches"]) \
                if s["target_dispatches"] else 0.0
            info["spec_decode"] = s
        chaos = info["resilience"]["chaos"]
        if chaos is not None and isinstance(self._chaos,
                                            delastic.DeviceLossInjector):
            chaos["lose_at_sites"] = [list(x)
                                      for x in self._chaos.lose_at_sites]
            chaos["lose_rate"] = self._chaos.lose_rate
            chaos["lost_sites"] = dict(self._chaos.lost_sites)
        if self._plan is not None:
            p = self._plan
            info["mesh"] = {
                "shape": {n: p.mesh.shape[n] for n in p.mesh.axis_names},
                "dp_axes": list(p.dp_axes),
                "model_axis": p.model_axis,
                "dp_size": p.dp_size,
                "tp_size": p.tp.size,
                "tp_attn": p.tp.attn,
                "tp_ssm": p.tp.ssm,
                "n_devices": int(p.mesh.devices.size),
                "dead_devices": list(self._health.dead_ids),
                "degraded": self._robust["degraded"],
                "reshard_s": self._reshard_s,
                "degrade_at": list(self._degrade_at),
            }
        if hasattr(self._bundle.decode_fn, "cache_info"):
            info["silvia"] = self._bundle.decode_fn.cache_info()
        return info

    @property
    def n_active(self) -> int:
        return int(np.sum(self._active))

    @property
    def n_queued(self) -> int:
        return len(self._queue)
