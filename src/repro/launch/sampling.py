"""Per-request sampling policies for the serve engine and static path.

SILVIA's packing transformation must leave every covered op's result
bit-identical; the sampling layer carries that obligation into stochastic
decoding by making every sampled token a PURE FUNCTION of
(seed, rid, token index, logits row):

* the per-token RNG key is counter-based -- ``fold_in(fold_in(
  PRNGKey(seed), rid), t)`` with ``t`` the generated-token index -- so no
  sampler state ever needs checkpointing: chaos recovery replay and
  prefix-cache warm admissions recompute the exact key from values they
  already carry;
* temperature / top-k / top-p truncation and the Gumbel-max draw are all
  per-row ops with no cross-row reduction, so a row samples the same
  token bits regardless of batch composition, mesh sharding, or whether
  it is evaluated in-scan ([B,V]) or host-side on a [1,V] slice
  (`sample_host`, the replay-verification path);
* greedy rows (temperature <= 0, the default) take the literal
  ``jnp.argmax`` path through a ``jnp.where`` select, keeping greedy
  streams bit-identical to the pre-sampling engine.

The per-slot sampling state -- base key, temperature, top-k, top-p,
prompt length -- is registered through `models/slot_state.py` as its own
constant-size slot page family (``"sampling"``), so its probed
`SlotStateSpec` gives the engine the same admit/permute/slice operations
the model caches use and the page survives admit/evict/compaction/replay
by construction (tests/test_sampling.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.scheduler import GREEDY, SamplingParams
from repro.models import slot_state

# operand order of the page leaves (one flat tuple everywhere: host page,
# device operand, shard_map specs)
PAGE_LEAVES = ("key", "temp", "top_k", "top_p", "plen")


@dataclasses.dataclass(frozen=True)
class _SamplingPageCfg:
    """Minimal config handle so `slot_state.spec_for` can probe the
    sampling page like any model family's cache."""
    family: str = "sampling"


def _init_page(cfg, n_slots: int, max_cache_len: int):
    del cfg, max_cache_len            # constant-size: no length axis
    return (jnp.zeros((n_slots, 2), jnp.uint32),    # fold_in(seed, rid)
            jnp.zeros((n_slots,), jnp.float32),     # temperature
            jnp.zeros((n_slots,), jnp.int32),       # top_k (0 = off)
            jnp.ones((n_slots,), jnp.float32),      # top_p (1 = off)
            jnp.zeros((n_slots,), jnp.int32))       # prompt_len


slot_state.register("sampling", _init_page)


def page_spec() -> slot_state.SlotStateSpec:
    """The probed SlotStateSpec of the sampling page (all leaves slot-axis
    0, no length axis -- a constant-size page)."""
    return slot_state.spec_for(_SamplingPageCfg())


# ---------------------------------------------------------------------------
# host-side page bookkeeping
# ---------------------------------------------------------------------------

def params_of(req) -> SamplingParams:
    return req.sampling if req.sampling is not None else GREEDY


def is_greedy(req) -> bool:
    """Whether this request's stream is the argmax stream (score/embed
    never sample)."""
    return req.method != "generate" or params_of(req).greedy


@functools.lru_cache(maxsize=8192)
def base_key(seed: int, rid: int) -> tuple:
    """fold_in(PRNGKey(seed), rid) as a hashable uint32 pair."""
    k = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    return tuple(int(x) for x in np.asarray(k, np.uint32))


def host_page(n_slots: int) -> list:
    """Fresh host-resident sampling page (numpy leaves, PAGE_LEAVES
    order), built by the registered slot-state init so layout cannot
    drift from the probed spec."""
    return [np.array(leaf)   # np.array copies: jax arrays are read-only
            for leaf in page_spec().init_state(n_slots, 1)]


def write_row(page: list, slot: int, req) -> None:
    """Admit one request's policy into its slot row."""
    p = params_of(req)
    page[0][slot] = np.asarray(base_key(p.seed, req.rid), np.uint32)
    page[1][slot] = np.float32(p.temperature)
    page[2][slot] = np.int32(p.top_k)
    page[3][slot] = np.float32(p.top_p)
    page[4][slot] = np.int32(req.prompt_len)


def clear_row(page: list, slot: int) -> None:
    """Evict: reset the row to the greedy defaults."""
    page[0][slot] = 0
    page[1][slot] = 0.0
    page[2][slot] = 0
    page[3][slot] = 1.0
    page[4][slot] = 0


def permute(page: list, perm) -> list:
    """Slot compaction (the host mirror of SlotStateSpec.permute_slots)."""
    return [leaf[np.asarray(perm)] for leaf in page]


def operand(page: list, bb: int) -> tuple:
    """The [:bb] device operand tuple a bucketed dispatch consumes."""
    return tuple(jnp.asarray(leaf[:bb]) for leaf in page)


def null_operand(bb: int) -> tuple:
    """All-greedy operand (warmup: graphs key on shapes, not values)."""
    return operand(host_page(bb), bb)


# ---------------------------------------------------------------------------
# the sampler
# ---------------------------------------------------------------------------

def sample(last, key, temp, top_k, top_p, t):
    """Next tokens [B] int32 from logits rows `last` [B,V].

    Greedy rows (temp <= 0) are the literal argmax of the raw logits --
    the same op as the pre-sampling engine, selected by `jnp.where`, so
    greedy bits cannot move.  Sampled rows divide by temperature in
    float32, mask everything outside the top-k/top-p truncation to -inf,
    and take the Gumbel-max argmax under the per-row key folded with the
    token index `t` [B].  Every op is per-row: a row's token is invariant
    to batch composition (the engine's packing invariant)."""
    v = last.shape[-1]
    arg = jnp.argmax(last, axis=-1).astype(jnp.int32)

    x = last.astype(jnp.float32) / jnp.maximum(temp, 1e-6)[:, None]
    srt = jnp.sort(x, axis=-1)[:, ::-1]
    # top-k threshold: the kth largest value (0 or oversize k = disabled)
    kk = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    thr_k = jnp.take_along_axis(srt, (kk - 1)[:, None], axis=-1)
    # top-p nucleus: keep the smallest sorted prefix with mass >= top_p;
    # the EXCLUSIVE cumsum keeps at least the first entry
    probs = jax.nn.softmax(srt, axis=-1)
    excl = jnp.cumsum(probs, axis=-1) - probs
    kept = excl < top_p[:, None]
    thr_p = jnp.min(jnp.where(kept, srt, jnp.inf), axis=-1, keepdims=True)
    keep = (x >= thr_k) & (x >= thr_p)

    kt = jax.vmap(jax.random.fold_in)(key, t)
    gum = jax.vmap(
        lambda k: jax.random.gumbel(k, (v,), jnp.float32))(kt)
    smp = jnp.argmax(jnp.where(keep, x + gum, -jnp.inf),
                     axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0.0, smp, arg)


@jax.jit
def _sample_jit(last, key, temp, top_k, top_p, t):
    return sample(last, key, temp, top_k, top_p, t)


def sample_host(row, params: SamplingParams, rid: int, t: int) -> int:
    """Recompute ONE row's sampled token host-side -- the replay
    verification / sampled-tok0 path.  Runs the same jitted sampler on a
    [1,V] slice: `sample` has no cross-row reduction, so the result is
    bitwise the in-scan batch row's token."""
    row = jnp.asarray(np.asarray(row, np.float32))[None]
    out = _sample_jit(
        row, jnp.asarray(np.asarray(base_key(params.seed, rid),
                                    np.uint32))[None],
        jnp.full((1,), params.temperature, jnp.float32),
        jnp.full((1,), params.top_k, jnp.int32),
        jnp.full((1,), params.top_p, jnp.float32),
        jnp.full((1,), t, jnp.int32))
    return int(out[0])


def expected_token(req, row, t: int) -> int:
    """The token request `req` emits at generated-token index `t` from
    logits row `row` -- host argmax for greedy rows (comparison-based, no
    float accumulation, so it equals the in-scan argmax), `sample_host`
    otherwise.  This is the single verification oracle replay and
    admission share."""
    row = np.asarray(row, np.float32)
    if is_greedy(req):
        return int(np.argmax(row))
    return sample_host(row, params_of(req), req.rid, t)


def static_operand(reqs_or_params, prompt_len: int, rids=None) -> Optional[tuple]:
    """Batch sampling operand for the STATIC `serve.generate` path: one
    SamplingParams (or None) per row, rid defaulting to the row index.
    Returns None when every row is greedy -- the caller then keeps the
    untouched greedy fused loop."""
    ps = [p if isinstance(p, SamplingParams) else GREEDY
          for p in (reqs_or_params or [])]
    if all(p.greedy for p in ps):
        return None
    rids = list(rids) if rids is not None else list(range(len(ps)))
    key = np.asarray([base_key(p.seed, r) for p, r in zip(ps, rids)],
                     np.uint32)
    return (jnp.asarray(key),
            jnp.asarray([p.temperature for p in ps], jnp.float32),
            jnp.asarray([p.top_k for p in ps], jnp.int32),
            jnp.asarray([p.top_p for p in ps], jnp.float32),
            jnp.full((len(ps),), prompt_len, jnp.int32))
