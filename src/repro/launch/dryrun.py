import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), print
memory_analysis / cost_analysis, and extract the three roofline terms from
the partitioned HLO (launch/hlo_analysis.py).

The two lines above MUST run before any jax import: jax locks the device
count at first init, and the production meshes need 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    ... --multi-pod        (2 x 16 x 16 pod mesh instead of 16 x 16)
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.shapes import SHAPES, cells_for_arch
from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        param_pspecs, to_shardings)
from repro.launch import hlo_analysis
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init
from repro.quant.qtensor import quantize_tree_for_serving
from repro.training import TrainConfig, make_train_step

# v5e-class hardware constants (per chip), from the brief.
HW = dict(peak_flops_bf16=197e12, hbm_bw=819e9, ici_bw=50e9)

# Per-arch training memory levers (state dtype / microbatching) -- these are
# the configurations REPORTED in EXPERIMENTS.md; see the memory analysis.
TRAIN_OVERRIDES = {
    "arctic-480b": dict(microbatches=8, state_dtype="bfloat16"),
    "qwen2-vl-72b": dict(microbatches=4, state_dtype="float32"),
    "command-r-35b": dict(microbatches=2, state_dtype="float32"),
    "jamba-v0.1-52b": dict(microbatches=4, state_dtype="float32"),
}


def abstract(f, *args, **kwargs):
    return jax.eval_shape(functools.partial(f, **kwargs), *args)


def train_seq_for(cfg: ModelConfig, seq: int) -> int:
    return seq


def make_batch_avals(cfg: ModelConfig, batch: int, seq: int, kind: str):
    """ShapeDtypeStruct stand-ins for one input batch."""
    if cfg.family == "encdec":
        return {
            "audio": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                          jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((batch, seq // 4 + 1), jnp.int32),
        }
    if cfg.frontend == "vision":
        if kind == "train":
            return {
                "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
        return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
    if kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((batch, seq + 1), jnp.int32)}
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


VARIANTS = {
    # hillclimb levers, composable: --variant moe_grouped,kv8
    "moe_grouped": "GShard-style grouped (shard-local) MoE dispatch",
    "pure_dp": "no TP: FSDP over all axes (for TP-unfriendly models)",
    "kv8": "int8 KV cache (+per-position scales)",
    "mb2": "train with 2 microbatches",
    "mb1": "train without microbatching",
    "cf10": "MoE capacity factor 1.0",
    "noremat": "disable activation rematerialization",
    "kv_seq_model": "shard decode KV cache sequence dim over the model axis",
    "chunked_attn": "scan causal attention over 1024-wide query chunks "
                    "(flash-attention memory behaviour)",
    "moe_shardmap": "explicitly-collective MoE dispatch under shard_map "
                    "(local scatters; all-gather weights + psum combine)",
}


def apply_variants(cfg: ModelConfig, overrides: dict, variants):
    for v in variants:
        if v == "moe_grouped" and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, dispatch="grouped"))
        elif v == "moe_shardmap" and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, dispatch="shard_map"))
        elif v == "pure_dp":
            overrides["sharding_mode"] = "pure_dp"
        elif v == "kv8":
            cfg = dataclasses.replace(cfg, serve_kv_dtype="int8")
        elif v == "mb2":
            overrides["microbatches"] = 2
        elif v == "mb1":
            overrides["microbatches"] = 1
        elif v == "cf10" and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=1.0))
        elif v == "noremat":
            overrides["remat"] = False
        elif v == "kv_seq_model":
            overrides["kv_seq_axis"] = "model"
        elif v == "chunked_attn":
            cfg = dataclasses.replace(cfg, attn_q_chunk=1024)
        elif v:
            raise ValueError(f"unknown variant {v}")
    return cfg, overrides


def build_case(cfg: ModelConfig, shape_name: str, mesh, *,
               quant: str | None = None, overrides: dict | None = None):
    """Returns (jitted_fn, arg_avals tuple) ready to .lower()."""
    cell = SHAPES[shape_name]
    seq, batch = cell.seq_len, cell.global_batch
    max_seq = max(seq + 1, 8)
    mode = (overrides or {}).get("sharding_mode", "2d")
    rng = jax.random.PRNGKey(0)
    params_avals = jax.eval_shape(
        lambda: lm.init_params(rng, cfg, max_seq=max_seq))
    pspecs = param_pspecs(params_avals, mesh, cfg, mode=mode)
    bspec_fn = batch_pspec(mesh, mode=mode)

    if cell.kind == "train":
        ov = dict(TRAIN_OVERRIDES.get(cfg.name, {}))
        ov.update(overrides or {})
        tcfg = TrainConfig(
            microbatches=ov.get("microbatches", 1),
            optimizer=AdamWConfig(
                state_dtype=ov.get("state_dtype", "float32")),
            remat=ov.get("remat", True))
        opt_avals = jax.eval_shape(
            lambda p: adamw_init(p, tcfg.optimizer), params_avals)
        opt_specs = param_pspecs(opt_avals, mesh, cfg, mode=mode)
        batch_avals = make_batch_avals(cfg, batch, seq, "train")
        bspecs = jax.tree_util.tree_map(bspec_fn, batch_avals)
        step = make_train_step(cfg, tcfg)
        fn = jax.jit(
            step,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(opt_specs, mesh),
                          to_shardings(bspecs, mesh)),
            donate_argnums=(0, 1))
        return fn, (params_avals, opt_avals, batch_avals)

    fmt = quant or cfg.serve_fmt
    qparams_avals = jax.eval_shape(
        lambda p: quantize_tree_for_serving(p, fmt), params_avals)
    qspecs = param_pspecs(qparams_avals, mesh, cfg, mode=mode)

    if cell.kind == "prefill":
        batch_avals = make_batch_avals(cfg, batch, seq, "prefill")
        bspecs = jax.tree_util.tree_map(bspec_fn, batch_avals)

        def prefill_fn(p, inputs):
            if cfg.family == "encdec":
                inputs = (inputs["audio"], inputs["tokens"][:, :-1])
            return lm.prefill(p, inputs, cfg, cache_len=seq)

        fn = jax.jit(prefill_fn,
                     in_shardings=(to_shardings(qspecs, mesh),
                                   to_shardings(bspecs, mesh)))
        return fn, (qparams_avals, batch_avals)

    # decode: one new token against a seq-length cache
    s_enc = seq if cfg.family == "encdec" else None
    cache_avals = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, seq, s_enc=s_enc))
    seq_shard = batch == 1
    cspecs = cache_pspecs(cache_avals, mesh, cfg, seq_shard=seq_shard,
                          mode=mode,
                          seq_axis=(overrides or {}).get("kv_seq_axis"))
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    from jax.sharding import PartitionSpec as P
    tok_aval = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_aval = jax.ShapeDtypeStruct((batch,), jnp.int32)
    tok_spec = P(None, None) if seq_shard else P(dp, None)
    pos_spec = P(None) if seq_shard else P(dp)

    def decode_fn(p, tok, cache, pos):
        return lm.decode_step(p, tok, cache, pos, cfg)

    fn = jax.jit(decode_fn,
                 in_shardings=(to_shardings(qspecs, mesh),
                               to_shardings(tok_spec, mesh),
                               to_shardings(cspecs, mesh),
                               to_shardings(pos_spec, mesh)),
                 donate_argnums=(2,))
    return fn, (qparams_avals, tok_aval, cache_avals, pos_aval)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (N_active for MoE), 2*N*D serve,
    plus the quadratic attention term where applicable (global, all chips)."""
    cell = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    b, s = cell.global_batch, cell.seq_len
    attn_layers = (cfg.n_layers // (cfg.hybrid.period if cfg.hybrid else 1)
                   if cfg.family == "hybrid" else
                   0 if cfg.family == "ssm" else cfg.n_layers)
    if cell.kind == "train":
        tokens = b * s
        attn = 0.5 * 4 * b * s * s * cfg.q_dim * attn_layers * 3  # fwd+bwd
        return 6.0 * n_act * tokens + attn
    if cell.kind == "prefill":
        tokens = b * s
        attn = 0.5 * 4 * b * s * s * cfg.q_dim * attn_layers
        return 2.0 * n_act * tokens + attn
    tokens = b * 1
    attn = 4 * b * s * cfg.q_dim * attn_layers  # read the whole KV cache
    return 2.0 * n_act * tokens + attn


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str | None = None, overrides: dict | None = None,
             keep_hlo: bool = False, variants=()) -> dict:
    cfg = configs.get_config(arch)
    overrides = dict(overrides or {})
    cfg, overrides = apply_variants(cfg, overrides, variants)
    cell_status = cells_for_arch(cfg)[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "variants": list(variants), "quant": quant,
           "status": cell_status}
    if cell_status != "run":
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = np.prod(mesh.devices.shape)
    try:
        from repro.distributed import context as dctx
        t0 = time.time()
        with dctx.mesh_scope(mesh, dp_axes(mesh), "model"):
            fn, avals = build_case(cfg, shape_name, mesh, quant=quant,
                                   overrides=overrides)
            lowered = fn.lower(*avals)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = hlo_analysis.analyze_hlo(compiled.as_text())
        terms = {
            "compute_s": hlo.dot_flops / HW["peak_flops_bf16"],
            "memory_s": hlo.hbm_bytes / HW["hbm_bw"],
            "collective_s": hlo.coll_bytes / HW["ici_bw"],
        }
        dominant = max(terms, key=terms.get)
        mf = model_flops(cfg, shape_name)
        total_dot = hlo.dot_flops * n_chips
        rec.update({
            "ok": True,
            "n_chips": int(n_chips),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "args_gb": ma.argument_size_in_bytes / 2**30,
                "temp_gb": ma.temp_size_in_bytes / 2**30,
                "out_gb": ma.output_size_in_bytes / 2**30,
                "total_gb": (ma.argument_size_in_bytes
                             + ma.temp_size_in_bytes) / 2**30,
            },
            "xla_cost_analysis": {"flops": ca.get("flops"),
                                  "bytes_out": ca.get("bytes accessedout{}")},
            "hlo": {
                "dot_flops_per_chip": hlo.dot_flops,
                "coll_bytes_per_chip": hlo.coll_bytes,
                "hbm_bytes_per_chip": hlo.hbm_bytes,
                "coll_by_kind": {k: round(v) for k, v in
                                 hlo.coll_by_kind.items()},
                "n_while": hlo.n_while,
                "trip_counts": hlo.trip_counts,
            },
            "roofline": {
                **{k: v for k, v in terms.items()},
                "dominant": dominant,
                "bound_s": max(terms.values()),
            },
            "model_flops_global": mf,
            "hlo_flops_global": total_dot,
            "useful_flops_ratio": (mf / total_dot) if total_dot else None,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        })
        if keep_hlo:
            rec["hlo_text_len"] = len(compiled.as_text())
    except Exception as e:  # noqa: BLE001 -- report per-cell failures
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", default=None, choices=[None, "bf16", "w8a8",
                                                      "w4a8"])
    ap.add_argument("--variant", default="",
                    help="comma-separated hillclimb levers: "
                         + ", ".join(VARIANTS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    variants = tuple(v for v in args.variant.split(",") if v)

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    def save(results):
        if not args.out:
            return
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        keyed = {(r["arch"], r["shape"], r["mesh"],
                  ",".join(r.get("variants", [])), r.get("quant") or ""): r
                 for r in existing}
        for r in results:
            keyed[(r["arch"], r["shape"], r["mesh"],
                   ",".join(r.get("variants", [])), r.get("quant") or "")] = r
        with open(args.out, "w") as f:
            json.dump(list(keyed.values()), f, indent=1)

    results = []
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp, quant=args.quant,
                           variants=variants)
            results.append(rec)
            save(results)      # incremental: survive crashes/kills
            status = rec.get("status")
            if status != "run":
                print(f"[SKIP] {arch:22s} {shape:12s} {rec['mesh']:8s} "
                      f"{status}", flush=True)
                continue
            if rec.get("ok"):
                r = rec["roofline"]
                print(f"[ OK ] {arch:22s} {shape:12s} {rec['mesh']:8s} "
                      f"compile={rec['compile_s']:6.1f}s "
                      f"mem={rec['memory']['total_gb']:7.2f}GB "
                      f"compute={r['compute_s']:.2e}s "
                      f"mem_t={r['memory_s']:.2e}s "
                      f"coll={r['collective_s']:.2e}s "
                      f"dom={r['dominant']}", flush=True)
            else:
                print(f"[FAIL] {arch:22s} {shape:12s} {rec['mesh']:8s} "
                      f"{rec['error'][:160]}", flush=True)
    if args.out:
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
