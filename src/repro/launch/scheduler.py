"""Request-level scheduling for the continuous-batching serve engine.

The paper's economy is packing independent narrow ops into one wide DSP;
the serving analogue packs independent requests into one compiled decode
dispatch.  This module owns the request-side half of that analogy:

* `Request` / `RequestQueue`: FIFO admission with arrival-time gating, so
  synthetic Poisson traffic (or a real frontend) can feed the engine.
* shape **buckets**: batch sizes and cache/prompt lengths are rounded up to
  a small power-of-two set, so the trace cache and `jax.jit` only ever see
  a handful of aval signatures -- the AutoDSE-style "pay once" philosophy
  applied to compiled-graph count instead of synthesis runs.
* `synthetic_traffic`: Poisson arrivals with mixed prompt/gen lengths, the
  ragged mix that leaves a static batch (one wide "DSP") mostly idle.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# shape buckets
# ---------------------------------------------------------------------------

def bucket_pow2(n: int, minimum: int = 1, maximum: Optional[int] = None) -> int:
    """Smallest power of two >= n, clamped to [minimum, maximum].

    `minimum` must itself be a power of two; `maximum` need not be -- it is
    an inclusive cap (the physical slot count / cache capacity)."""
    if n < 0:
        raise ValueError(f"bucket_pow2: negative size {n}")
    b = max(minimum, 1)
    while b < n:
        b *= 2
    if maximum is not None:
        if n > maximum:
            raise ValueError(f"size {n} exceeds bucket cap {maximum}")
        b = min(b, maximum)
    return b


def validate_slot_sharding(n_slots: int, dp_size: int) -> None:
    """Mesh-aware engines shard the slot axis over `dp_size` data shards:
    every batch bucket must split evenly, so the bucket FLOOR becomes
    dp_size and n_slots (the bucket cap, included verbatim in the bucket
    set) must be a multiple of it.  dp_size must be a power of two so the
    floored power-of-two bucket set stays shard-divisible throughout."""
    if dp_size < 1 or dp_size & (dp_size - 1):
        raise ValueError(
            f"sharded serve needs a power-of-two data-shard count, got "
            f"{dp_size} (mesh dp axes)")
    if n_slots % dp_size:
        raise ValueError(
            f"n_slots {n_slots} is not a multiple of the data-shard count "
            f"{dp_size}: the slot axis cannot split evenly over the mesh")


def largest_valid_dp(n_slots: int, max_dp: int) -> int:
    """Largest data-shard count that `validate_slot_sharding` accepts
    with at most `max_dp` shards: a power of two dividing n_slots (>= 1).
    The degraded-mesh planner (distributed/elastic.py) uses this to pick
    the widest data extent a shrunken device budget still supports."""
    d = 1
    while d * 2 <= max_dp and n_slots % (d * 2) == 0:
        d *= 2
    return d


def bucket_set(minimum: int, maximum: int) -> tuple:
    """All buckets bucket_pow2 can produce in [minimum, maximum]: the
    powers of two in range plus the cap itself.  The compiled-graph count
    is bounded by products of these sets."""
    out = []
    b = max(minimum, 1)
    while b < maximum:
        out.append(b)
        b *= 2
    out.append(maximum)
    return tuple(out)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy (launch/sampling.py executes it).

    temperature <= 0 selects greedy argmax -- bit-identical to the
    pre-sampling engine.  With temperature > 0, logits are divided by the
    temperature, truncated to the `top_k` highest entries (0 = disabled)
    and to the smallest `top_p` nucleus (1.0 = disabled), and sampled via
    Gumbel-max with a counter-based key folded from (seed, rid, token
    index) -- so a request's stream is a pure function of (seed, rid,
    token prefix), which is what lets chaos recovery replay and
    prefix-cache warm runs reproduce byte-identical sampled tokens."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass(eq=False)      # identity eq: prompt arrays don't
class Request:                        # support elementwise == in `in`/remove
    """One generation request.  `prompt` is a 1-D int token array; the
    engine generates up to `max_new_tokens` greedy tokens, stopping the
    segment a token in `stop_tokens` is emitted (the stop token is the
    last token of the output).  `features` carries per-request modality
    inputs for encoder-decoder families (whisper: [enc_len, d_model]
    precomputed frame embeddings).  `deadline` is an absolute time in the
    serving clock's domain (same domain as `arrival_time`); past it the
    request is EXPIRED instead of (further) served -- the engine fills it
    from its default TTL when left None (launch/resilience.py).

    `method` selects the servable method (launch/methods.py):
      generate  greedy decode of up to max_new_tokens (the default);
      score     teacher-force `score_tokens` and report their per-token
                logprobs (no sampling; max_new_tokens is unused);
      embed     pooled final-hidden-state embedding of the prompt (one
                prefill-shaped dispatch, no decode slot consumed)."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float = 0.0
    stop_tokens: Optional[Sequence[int]] = None
    features: Optional[np.ndarray] = None
    deadline: Optional[float] = None
    method: str = "generate"
    score_tokens: Optional[Sequence[int]] = None
    # per-request sampling policy; None means greedy (generate only --
    # score teacher-forces and embed never samples)
    sampling: Optional[SamplingParams] = None
    # filled in by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    outcome: Optional[str] = None      # resilience.OK/SHED/EXPIRED/...
    error: Optional[str] = None
    retries: int = 0                   # fault recoveries survived
    logprobs: List[float] = dataclasses.field(default_factory=list)
    embedding: Optional[np.ndarray] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")
        if self.stop_tokens is not None:
            self.stop_tokens = tuple(int(t) for t in self.stop_tokens)
        if self.method not in ("generate", "score", "embed"):
            raise ValueError(
                f"request {self.rid}: unknown method {self.method!r} "
                f"(want generate/score/embed)")
        if self.method == "score":
            if self.score_tokens is None or len(self.score_tokens) == 0:
                raise ValueError(
                    f"request {self.rid}: score needs score_tokens")
            self.score_tokens = tuple(int(t) for t in self.score_tokens)
        elif self.score_tokens is not None:
            raise ValueError(
                f"request {self.rid}: score_tokens only valid with "
                f"method='score'")
        if self.sampling is not None:
            if not isinstance(self.sampling, SamplingParams):
                raise ValueError(
                    f"request {self.rid}: sampling must be a "
                    f"SamplingParams, got {type(self.sampling).__name__}")
            if self.method != "generate" and not self.sampling.greedy:
                raise ValueError(
                    f"request {self.rid}: sampling is generate-only "
                    f"(method {self.method!r} never samples)")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def served_len(self) -> int:
        """Cache positions the request actually needs under its method
        (what the engine validates against max_cache_len)."""
        if self.method == "score":
            return self.prompt_len + len(self.score_tokens)
        if self.method == "embed":
            return self.prompt_len
        return self.total_len

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline

    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time


class RequestQueue:
    """FIFO queue with arrival-time gating."""

    _ORDER = staticmethod(lambda r: (r.arrival_time, r.rid))

    def __init__(self, requests: Sequence[Request] = ()):
        self._pending: List[Request] = sorted(requests, key=self._ORDER)

    def submit(self, req: Request) -> None:
        bisect.insort(self._pending, req, key=self._ORDER)

    def __len__(self) -> int:
        return len(self._pending)

    def next_arrival(self, now: float) -> Optional[float]:
        """Earliest arrival time still in the future (None if the queue is
        empty or something is already ready)."""
        if not self._pending:
            return None
        t = self._pending[0].arrival_time
        return None if t <= now else t

    def pop_ready(self, now: float, limit: int,
                  predicate=None) -> List[Request]:
        """Up to `limit` requests whose arrival_time <= now, FIFO order.
        With `predicate`, only matching requests are taken (non-matching
        arrived requests keep their queue position)."""
        out: List[Request] = []
        i = 0
        while i < len(self._pending) and len(out) < limit \
                and self._pending[i].arrival_time <= now:
            if predicate is None or predicate(self._pending[i]):
                out.append(self._pending.pop(i))
            else:
                i += 1
        return out

    def pop_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose deadline passed
        (arrived or not: a deadline can lapse while still in transit)."""
        out = [r for r in self._pending if r.expired(now)]
        if out:
            dead = {id(r) for r in out}
            self._pending = [r for r in self._pending
                             if id(r) not in dead]
        return out

    def pop_oldest(self) -> Optional[Request]:
        """Remove and return the head of the queue (drop-oldest load
        shedding); None when empty."""
        return self._pending.pop(0) if self._pending else None

    def remove(self, rid: int) -> Optional[Request]:
        """Remove and return the queued request with this rid (client
        cancellation before admission); None if not queued."""
        for i, r in enumerate(self._pending):
            if r.rid == rid:
                return self._pending.pop(i)
        return None

    def pending(self) -> tuple:
        """Snapshot view of the queued requests (FIFO order)."""
        return tuple(self._pending)


# ---------------------------------------------------------------------------
# synthetic traffic
# ---------------------------------------------------------------------------

def synthetic_traffic(seed: int, n_requests: int, rate: float,
                      prompt_lens: Sequence[int], gen_lens: Sequence[int],
                      vocab: int,
                      ttls: Optional[Sequence[Optional[float]]] = None,
                      sampling_mix: Optional[Sequence[
                          Optional[SamplingParams]]] = None,
                      ) -> List[Request]:
    """Poisson arrivals (exponential inter-arrival gaps at `rate` req/s)
    with prompt/gen lengths drawn uniformly from the given mixes.  With
    `ttls`, each request draws a TTL from the mix (None entries mean no
    deadline) -- the deadline mix for resilience benchmarks/tests.  With
    `sampling_mix`, each request draws a SamplingParams from the mix
    (None entries mean greedy) -- the policy mix for sampling tests."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        pl = int(rng.choice(np.asarray(prompt_lens)))
        gl = int(rng.choice(np.asarray(gen_lens)))
        prompt = rng.integers(0, vocab, size=pl, dtype=np.int32)
        deadline = None
        if ttls is not None:
            ttl = ttls[int(rng.integers(0, len(ttls)))]
            deadline = None if ttl is None else t + float(ttl)
        sampling = None
        if sampling_mix is not None:
            sampling = sampling_mix[int(rng.integers(0, len(sampling_mix)))]
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gl,
                            arrival_time=t, deadline=deadline,
                            sampling=sampling))
    return reqs


def shared_prefix_traffic(seed: int, n_requests: int, rate: float,
                          n_prefixes: int, prefix_len: int,
                          tail_lens: Sequence[int],
                          gen_lens: Sequence[int], vocab: int,
                          zipf_a: float = 1.2,
                          ttls: Optional[Sequence[Optional[float]]] = None,
                          ) -> List[Request]:
    """Poisson arrivals whose prompts share prefixes zipfian-style: each
    request draws one of `n_prefixes` fixed prefix token blocks with
    P(k) proportional to 1/(k+1)^zipf_a (a few system prompts dominate,
    a long tail of rare ones -- the real-traffic shape that makes a
    cross-request prefix cache pay), then appends a fresh random tail of
    a length drawn from `tail_lens`.  Same rate/gen/TTL machinery as
    synthetic_traffic."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=prefix_len, dtype=np.int32)
                for _ in range(n_prefixes)]
    w = 1.0 / np.arange(1, n_prefixes + 1, dtype=np.float64) ** zipf_a
    w /= w.sum()
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        pre = prefixes[int(rng.choice(n_prefixes, p=w))]
        tl = int(rng.choice(np.asarray(tail_lens)))
        tail = rng.integers(0, vocab, size=tl, dtype=np.int32)
        gl = int(rng.choice(np.asarray(gen_lens)))
        deadline = None
        if ttls is not None:
            ttl = ttls[int(rng.integers(0, len(ttls)))]
            deadline = None if ttl is None else t + float(ttl)
        reqs.append(Request(rid=i, prompt=np.concatenate([pre, tail]),
                            max_new_tokens=gl, arrival_time=t,
                            deadline=deadline))
    return reqs


def method_traffic(seed: int, n_requests: int, rate: float,
                   prompt_lens: Sequence[int], gen_lens: Sequence[int],
                   vocab: int,
                   method_mix: Optional[Sequence] = None,
                   score_lens: Sequence[int] = (4, 8),
                   ) -> List[Request]:
    """Poisson open-loop traffic mixing servable methods: each request
    draws a method from `method_mix` -- a sequence of (method, weight)
    pairs (default: 70% generate / 20% score / 10% embed).  Score
    requests carry a random completion of a length drawn from
    `score_lens`.  This is the trace shape `benchmarks/serve_latency.py`
    replays against the async front-end."""
    if method_mix is None:
        method_mix = (("generate", 0.7), ("score", 0.2), ("embed", 0.1))
    names = [m for m, _ in method_mix]
    w = np.asarray([float(p) for _, p in method_mix], np.float64)
    w /= w.sum()
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        pl = int(rng.choice(np.asarray(prompt_lens)))
        gl = int(rng.choice(np.asarray(gen_lens)))
        prompt = rng.integers(0, vocab, size=pl, dtype=np.int32)
        method = names[int(rng.choice(len(names), p=w))]
        score_tokens = None
        if method == "score":
            sl = int(rng.choice(np.asarray(score_lens)))
            score_tokens = rng.integers(0, vocab, size=sl,
                                        dtype=np.int32).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gl,
                            arrival_time=t, method=method,
                            score_tokens=score_tokens))
    return reqs


# ---------------------------------------------------------------------------
# clocks (real serving vs fast-forward benchmarking)
# ---------------------------------------------------------------------------

class Clock:
    """Wall clock: now() advances with real time, wait_until() sleeps."""

    def __init__(self):
        import time
        self._time = time
        self._t0 = time.monotonic()

    def now(self) -> float:
        return self._time.monotonic() - self._t0

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            self._time.sleep(dt)


class FastForwardClock(Clock):
    """Clock for benchmarks: compute time is measured for real, but idle
    waits (no request in flight, none arrived yet) are skipped by jumping
    the clock forward, so a simulated Poisson trace replays instantly."""

    def __init__(self):
        super().__init__()
        self._skew = 0.0

    def now(self) -> float:
        return super().now() + self._skew

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            self._skew += dt
