"""Serving driver: quantized weights + batched prefill/decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --quant w4a8 --batch 4 --prompt-len 64 --gen 32 [--silvia all]

The serving path is where the paper's technique lives end to end:

* weights are quantized offline (w8a8 / w4a8 packed -- two int4 per int8
  word, the DSP-packing insight applied to HBM);
* with --silvia, the decode step function is rewritten by the SILVIA passes
  (core/pipeline.py) before jit, packing any narrow-int ops the quantized
  graph exposes -- the `SILVIA::csynth_design` drop-in, one flag.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import core as silvia
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving

SILVIA_PASS_SETS = {
    "off": [],
    "muladd": [silvia.PassConfig(op="muladd")],
    "add": [silvia.PassConfig(op="add", op_size=8),
            silvia.PassConfig(op="add", op_size=16)],
    "all": list(silvia.DEFAULT_PASSES),
}


def generate(params, prompts, cfg, *, gen: int, cache_len: int,
             silvia_passes="off"):
    """Greedy generation: prefill + gen decode steps."""
    b, s = prompts.shape
    logits, cache = lm.prefill(params, prompts, cfg, cache_len=cache_len)

    def decode_fn(p, tok, kv, pos):
        return lm.decode_step(p, tok, kv, pos, cfg)

    passes = SILVIA_PASS_SETS[silvia_passes]
    if passes:
        decode_fn = silvia.optimize(decode_fn, passes)
    decode_jit = jax.jit(decode_fn, donate_argnums=(2,))

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = jnp.full((b,), s, jnp.int32)
    for i in range(gen - 1):
        logits, cache = decode_jit(params, tok, cache, pos + i)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="w8a8",
                    choices=["bf16", "w8a8", "w4a8"])
    ap.add_argument("--silvia", default="off",
                    choices=list(SILVIA_PASS_SETS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced_config(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    assert cfg.family != "encdec", "use --arch with a decoder-only model"
    rng = jax.random.PRNGKey(args.seed)
    cache_len = args.prompt_len + args.gen
    params = lm.init_params(rng, cfg, max_seq=cache_len + 8)
    if args.quant != "bf16":
        params = quantize_tree_for_serving(params, args.quant)
        print(f"quantized weights to {args.quant}")
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, dtype=jnp.int32)
    t0 = time.time()
    toks = generate(params, prompts, cfg, gen=args.gen, cache_len=cache_len,
                    silvia_passes=args.silvia)
    dt = time.time() - t0
    n_tok = args.batch * args.gen
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batch-aggregate)")
    print("sample tokens:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
