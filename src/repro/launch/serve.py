"""Serving driver: quantized weights + batched prefill/decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --quant w4a8 --batch 4 --prompt-len 64 --gen 32 \
        [--silvia all] [--autotune] [--no-fused-decode]

The serving path is where the paper's technique lives end to end:

* weights are quantized offline (w8a8 / w4a8 packed -- two int4 per int8
  word, the DSP-packing insight applied to HBM);
* with ``--silvia {off,add,muladd,all}``, the decode step function is
  rewritten by the SILVIA passes (core/pipeline.py) before jit, packing any
  narrow-int ops the quantized graph exposes -- the
  `SILVIA::csynth_design` drop-in, one flag.  The pass pipeline's trace
  cache makes this compile-once/run-many: repeated `generate()` calls with
  the same shapes never re-run the passes;
* decode runs as a **fused `jax.lax.scan` loop**: the whole decode phase is
  ONE dispatch with the KV cache donated to the loop, instead of one
  python-level dispatch per generated token (``--no-fused-decode`` restores
  the per-step loop for A/B measurement -- benchmarks/pipeline_overhead.py
  reports both);
* with ``--autotune``, the Pallas kernels (matmuls AND the SWAR units)
  search their block sizes on first use and persist the winners on disk
  (kernels/autotune.py; cache at $REPRO_AUTOTUNE_CACHE or
  ~/.cache/repro/autotune.json, keyed per lowering id + mode);
* every packed op binds to its backend implementation through the
  **lowering registry** (kernels/registry.py): `tpu-pallas` / `gpu-pallas`
  / `cpu-vector` / `ref`, auto-selected per backend.
  ``REPRO_LOWERING=<op>=<id>,...`` (or ``*=<id>``) forces specific
  lowerings -- e.g. ``REPRO_LOWERING='*=ref'`` serves everything on the
  pure-jnp oracle, bit-identically; the census of active lowerings is
  printed per run and reported by the engine's ``cache_info()``.

For ragged multi-request traffic, use the continuous-batching engine
instead of calling `generate()` per batch (see launch/engine.py and
examples/serve_engine.py).  The engine serves every family registered in
models/slot_state.py -- dense/vlm/moe KV pages, pure-SSM and hybrid
state, and (with `enc_len` + per-request `features`) encdec -- through
the same bucketed segment loop::

    from repro.launch.engine import ServeEngine
    from repro.launch.scheduler import Request

    eng = ServeEngine(params, cfg, n_slots=8, max_cache_len=256,
                      segment_len=16, silvia_passes="all")
    eng.submit(Request(rid=0, prompt=prompt_tokens, max_new_tokens=64))
    done = eng.run()          # {rid: np.ndarray of generated tokens}

The engine shares this module's decode-bundle cache: one compiled segment
graph per (batch bucket, cache-length bucket) serves an ever-changing
request mix, token-identically to `generate()`.  Constructed under a
`repro.distributed.context.mesh_scope`, the engine additionally shard_maps
those segment graphs over the mesh (slot axes over the data axes, probed
head/state axes over "model") while staying bit-identical -- see
launch/engine.py and DESIGN.md sec. 7.
"""
from __future__ import annotations

import argparse
import collections
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import core as silvia
from repro.kernels import ops as kops
from repro.kernels import registry
from repro.launch import sampling as sampling_lib
from repro.models import lm
from repro.quant.qtensor import quantize_tree_for_serving

SILVIA_PASS_SETS = {
    "off": [],
    "muladd": [silvia.PassConfig(op="muladd")],
    "add": [silvia.PassConfig(op="add", op_size=8),
            silvia.PassConfig(op="add", op_size=16)],
    "all": list(silvia.DEFAULT_PASSES),
}


class LRUCache:
    """Bounded LRU keyed cache with cache_info()/cache_clear() counters
    mirroring core/pipeline.py's trace-cache bookkeeping.

    Decode bundles hold compiled executables (and, with SILVIA passes on,
    their own trace caches), so an unbounded dict leaks a full compiled
    graph per distinct (cfg, pass set) forever; serving fleets cycle
    through many configs.  Default bound via $REPRO_DECODE_CACHE_SIZE."""

    def __init__(self, maxsize: int = 16):
        self.maxsize = max(1, int(maxsize))
        self._store: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key, builder):
        ent = self._store.get(key)
        if ent is not None:
            self.hits += 1
            self._store.move_to_end(key)
            return ent
        self.misses += 1
        ent = builder()
        self._store[key] = ent
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
            self.evictions += 1
        return ent

    def info(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._store),
                "maxsize": self.maxsize}

    def clear(self) -> None:
        self._store.clear()
        self.hits = self.misses = self.evictions = 0


# (cfg, silvia_passes, lowering fingerprint[, variant]) -> decode bundle.
# ModelConfig is a frozen dataclass, so this composes with the SILVIA trace
# cache to give compile-once/run-many across generate() calls; the serve
# engine stores its segment bundles here too under a "engine" variant key.
# The registry fingerprint keys out forced-lowering changes: a bundle
# compiled under one lowering census is never served under another.
_DECODE_CACHE = LRUCache(
    maxsize=int(os.environ.get("REPRO_DECODE_CACHE_SIZE", "16")))


def decode_cache_info() -> dict:
    """Counters for the decode-bundle LRU (hits/misses/evictions/size)."""
    return _DECODE_CACHE.info()


def decode_cache_clear() -> None:
    _DECODE_CACHE.clear()


def _pin_lowerings(fn, census: dict):
    """Run every call of a bundle callable under the lowering census its
    cache key records.  jit tracing (where the registry is consulted) is
    lazy -- a bundle may first trace, or re-trace for a new shape, long
    after it was built, when the ambient resolution could have changed;
    pinning makes key and trace consistent for the bundle's lifetime."""
    @functools.wraps(fn)
    def pinned(*args, **kwargs):
        with registry.force(**census):
            return fn(*args, **kwargs)
    return pinned


def _decode_bundle(cfg, silvia_passes: str):
    census = registry.active_lowerings()

    def build():
        def decode_fn(p, tok, kv, pos):
            return lm.decode_step(p, tok, kv, pos, cfg)

        passes = SILVIA_PASS_SETS[silvia_passes]
        if passes:
            decode_fn = silvia.optimize(decode_fn, passes)

        @functools.partial(jax.jit, static_argnums=(4,), donate_argnums=(2,))
        def fused_loop(params, tok0, cache, pos0, n_steps):
            def step(carry, i):
                tok, kv = carry
                logits, kv = decode_fn(params, tok, kv, pos0 + i)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1)
                nxt = nxt.astype(jnp.int32)[:, None]
                return (nxt, kv), nxt

            (_, kv), seq = jax.lax.scan(step, (tok0, cache),
                                        jnp.arange(n_steps))
            return seq, kv

        # per-request sampling variant: its own jitted graph, so the
        # greedy fused_loop above stays byte-for-byte the pre-sampling
        # program (greedy rows INSIDE a sampled batch still take the
        # argmax select in sampling.sample)
        @functools.partial(jax.jit, static_argnums=(5,), donate_argnums=(2,))
        def sampled_loop(params, tok0, cache, pos0, samp, n_steps):
            key, temp, top_k, top_p, plen = samp

            def step(carry, i):
                tok, kv = carry
                logits, kv = decode_fn(params, tok, kv, pos0 + i)
                nxt = sampling_lib.sample(logits[:, -1, :], key, temp,
                                          top_k, top_p, pos0 + i - plen + 1)
                return (nxt[:, None], kv), nxt[:, None]

            (_, kv), seq = jax.lax.scan(step, (tok0, cache),
                                        jnp.arange(n_steps))
            return seq, kv

        decode_jit = jax.jit(decode_fn, donate_argnums=(2,))
        return (_pin_lowerings(decode_fn, census),
                _pin_lowerings(decode_jit, census),
                _pin_lowerings(fused_loop, census),
                _pin_lowerings(sampled_loop, census))

    return _DECODE_CACHE.get_or_build(
        (cfg, silvia_passes, tuple(sorted(census.items()))), build)


def get_decode_step(cfg, silvia_passes: str = "off"):
    """The (possibly SILVIA-rewritten) single-token decode step for cfg.

    Cached per (cfg, pass set); the SILVIA wrapper's own trace cache then
    guarantees the passes run once per input-shape signature (inspect via
    `get_decode_step(...).cache_info()` when passes are on)."""
    return _decode_bundle(cfg, silvia_passes)[0]


def generate(params, prompts, cfg, *, gen: int, cache_len: int,
             silvia_passes="off", fused: bool = True,
             sampling=None, rids=None):
    """Generation: prefill + gen decode steps (greedy by default).

    prompts: [B,S] int tokens; encdec families take a tuple
    (features [B,S_enc,d_model], dec_tokens [B,S]) instead.
    fused=True runs the whole decode phase as one `jax.lax.scan` dispatch
    (state cache donated); fused=False is the per-step reference loop.
    `sampling` takes one scheduler.SamplingParams (or None = greedy) per
    row, with `rids` giving each row's request id for key derivation
    (default: the row index) -- the static reference the engine's sampled
    streams are tested against.  All-greedy batches take the original
    argmax graphs untouched."""
    b, s = (prompts[1] if cfg.family == "encdec" else prompts).shape
    logits, cache = lm.prefill(params, prompts, cfg, cache_len=cache_len)
    _, decode_jit, fused_loop, sampled_loop = _decode_bundle(
        cfg, silvia_passes)

    samp = sampling_lib.static_operand(sampling, s, rids) \
        if sampling is not None else None
    pos = jnp.full((b,), s, jnp.int32)
    if samp is None:
        tok = jnp.argmax(logits[:, -1, :],
                         axis=-1).astype(jnp.int32)[:, None]
        if fused:
            seq, _ = fused_loop(params, tok, cache, pos, gen - 1)
            # seq: [gen-1, B, 1] of generated tokens, in step order
            return jnp.concatenate([tok, jnp.moveaxis(seq[:, :, 0], 0, 1)],
                                   axis=1)
        out = [tok]
        for i in range(gen - 1):
            logits, cache = decode_jit(params, tok, cache, pos + i)
            tok = jnp.argmax(logits[:, -1, :],
                             axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1)
    key, temp, top_k, top_p, _ = samp
    tok = sampling_lib.sample(logits[:, -1, :], key, temp, top_k, top_p,
                              jnp.zeros((b,), jnp.int32))[:, None]
    if fused:
        seq, _ = sampled_loop(params, tok, cache, pos, samp, gen - 1)
        return jnp.concatenate([tok, jnp.moveaxis(seq[:, :, 0], 0, 1)],
                               axis=1)
    out = [tok]
    for i in range(gen - 1):
        logits, cache = decode_jit(params, tok, cache, pos + i)
        tok = sampling_lib.sample(logits[:, -1, :], key, temp, top_k,
                                  top_p,
                                  jnp.full((b,), i + 1, jnp.int32))[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="w8a8",
                    choices=["bf16", "w8a8", "w4a8"])
    ap.add_argument("--quant-force", action="store_true",
                    help="drop the quantization size floors (reduced "
                         "configs sit entirely under them; without this, "
                         "--reduced --quant w8a8 serves bf16 graphs with "
                         "zero packed-matmul dispatches)")
    ap.add_argument("--silvia", default="off",
                    choices=list(SILVIA_PASS_SETS))
    ap.add_argument("--autotune", action="store_true",
                    help="tune + persist Pallas kernel block sizes -- "
                         "matmuls and SWAR units (kernels/autotune.py)")
    ap.add_argument("--no-fused-decode", action="store_true",
                    help="per-step decode dispatch instead of the fused "
                         "lax.scan loop (for A/B comparison)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced_config(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    assert cfg.family != "encdec", "use --arch with a decoder-only model"
    if args.autotune:
        kops.set_autotune(True)
    rng = jax.random.PRNGKey(args.seed)
    cache_len = args.prompt_len + args.gen
    params = lm.init_params(rng, cfg, max_seq=cache_len + 8)
    if args.quant != "bf16":
        params = quantize_tree_for_serving(params, args.quant,
                                           force=args.quant_force)
        print(f"quantized weights to {args.quant}"
              + (" (forced floors)" if args.quant_force else ""))
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, dtype=jnp.int32)
    print("active lowerings:", registry.census_str())
    t0 = time.time()
    toks = generate(params, prompts, cfg, gen=args.gen, cache_len=cache_len,
                    silvia_passes=args.silvia,
                    fused=not args.no_fused_decode)
    dt = time.time() - t0
    n_tok = args.batch * args.gen
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batch-aggregate)")
    print("sample tokens:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
