"""Cross-request prefix cache over the paged slot state.

SILVIA's core move is recognizing that independent operations share
structure and computing them ONCE on a shared resource (superwords packed
onto one DSP).  Serve traffic has the same redundancy one level up:
requests share system prompts / few-shot templates / RAG boilerplate, yet
a cold engine re-prefills every prefix from scratch.  This module is the
compute-once-share-pages analogue (DESIGN.md sec. 10): prompt token
chunks are hashed into a content-addressed pool of immutable prefix
pages; admission looks up the longest cached prefix and prefills only the
uncached tail.

Why sharing is EXACT (not approximate): a slot's KV rows [0, L) are a
pure function of the token prefix -- each row is written once by a
per-row `dynamic_update_slice` and attention masks everything beyond a
row's own position (models/attention.py), so pages captured from one
request's prefill are bitwise the pages any other request with the same
prefix would have computed.  Constant-size sequential state (SSM, conv
windows, cross-KV) is a snapshot of the state AFTER the whole prefix, so
it is only shared at exact-full-prompt granularity (a terminal entry);
chunked per-prefix checkpoints exist only for families whose prefill is
chunkable without changing the floating-point reduction order
(slot_state.FamilyState.prefill_chunkable).

Two entry kinds:

* **chain** entries -- one per prefill chunk, keyed by a rolling hash
  h_k = H(h_{k-1} || tokens[kC:(k+1)C)), so a chunk is only reachable
  through the exact token prefix in front of it.  Chain entries hold the
  length-axis page slices of their chunk and exist only for chunked
  engines whose state is entirely length-paged.
* **terminal** entries -- keyed by the full prompt (plus the encoder
  features digest for encdec), holding ALL pages [0:prompt_len) plus
  constant-size state snapshots AND the first sampled token, so an exact
  repeat skips prefill entirely (zero dispatches).

Copy-on-write: pool pages are immutable host-resident numpy; admission
COPIES them into the admitted slot's private state, and decode mutates
only that working copy -- the divergence point is wherever the copied
prefix ends.  Host residency also makes the pool mesh-free: pages survive
elastic degrade untouched and are re-placed under the CURRENT mesh plan's
PartitionSpecs whenever they are written back (the engine records each
re-plan via `note_remesh`, so `info()` always shows which mesh
fingerprint the pool is serving).

Capacity is bounded in page units (1 per entry) with LRU eviction that
skips pinned entries: an entry is pinned while any live slot was admitted
from it and unpinned at eviction/recovery, so a page a replay might need
cannot be evicted mid-flight.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Entry:
    """One pooled page set (a chain chunk or a terminal prefix)."""
    key: bytes
    pages: list                 # slot_state.extract_row_pages output
    kind: str                   # "chain" | "terminal"
    tok0: Optional[int] = None  # terminal only: the first sampled token
    refs: int = 0               # live slots admitted from this entry


@dataclasses.dataclass
class Lookup:
    """Longest cached prefix for one request."""
    terminal: Optional[Entry]
    chain: List[Entry]
    cached_tokens: int

    @property
    def hit(self) -> bool:
        return self.cached_tokens > 0


def _features_digest(features) -> bytes:
    if features is None:
        return b""
    a = np.asarray(features, np.float32)
    return hashlib.sha256(a.tobytes() + str(a.shape).encode()).digest()


class PrefixCache:
    """Content-addressed pool of immutable prefix pages (module docstring).

    chunk: the engine's prefill chunk C (None for full-prefill engines --
    chain entries are then never created).
    chain_ok: chain sharing requires EVERY state leaf to be length-paged
    (a mid-prompt resume re-initializes constant-size leaves, which is
    only correct when there are none); the engine passes the probed
    verdict from its SlotStateSpec.
    """

    def __init__(self, max_pages: int, *, chunk: Optional[int] = None,
                 chain_ok: bool = True, salt: str = ""):
        if max_pages < 1:
            raise ValueError(f"prefix cache needs max_pages >= 1, got "
                             f"{max_pages}")
        self.max_pages = max_pages
        self.chunk = chunk
        self.chain_ok = chain_ok and chunk is not None
        self._salt = salt.encode()
        self._entries: "OrderedDict[bytes, Entry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.tokens_skipped = 0
        self.evicted = 0
        self.insertions = 0
        self.remeshes = 0
        self.mesh_key = None

    # -- keys ---------------------------------------------------------------

    @property
    def _memo_tag(self) -> tuple:
        # digests depend on these; a memo from a differently-configured
        # pool must never be trusted
        return (self._salt, self.chunk, self.chain_ok)

    def warm_digest(self, req) -> bool:
        """Precompute and memoize this request's content digests -- the
        terminal sha256 and the rolling chain keys -- ON the request
        object, so later lookups do no hashing at admission time.  This
        is the host-side admission planning the front-end overlaps with
        an in-flight decode segment (engine.admission_plan): pure
        hashing, no pool mutation, no counters.  Idempotent; returns
        True when work was done, False when already warm."""
        memo = getattr(req, "_prefix_memo", None)
        if memo is not None and memo[0] == self._memo_tag:
            return False
        req._prefix_memo = (self._memo_tag, self._hash_terminal(req),
                            tuple(self._hash_chain(req.prompt)))
        return True

    def _terminal_key(self, req) -> bytes:
        memo = getattr(req, "_prefix_memo", None)
        if memo is not None and memo[0] == self._memo_tag:
            return memo[1]
        return self._hash_terminal(req)

    def _hash_terminal(self, req) -> bytes:
        h = hashlib.sha256()
        h.update(b"terminal:")
        h.update(self._salt)
        h.update(_features_digest(req.features))
        h.update(np.asarray(req.prompt, np.int32).tobytes())
        return h.digest()

    def chain_keys(self, prompt, req=None) -> List[bytes]:
        """Rolling keys for every FULLY-real chunk of `prompt`: chunk k is
        reachable only through the exact tokens [0:(k+1)C).  Pass the
        owning request as `req` to reuse a warm_digest memo."""
        memo = getattr(req, "_prefix_memo", None) if req is not None \
            else None
        if memo is not None and memo[0] == self._memo_tag:
            return list(memo[2])
        return self._hash_chain(prompt)

    def _hash_chain(self, prompt) -> List[bytes]:
        if not self.chain_ok:
            return []
        c = self.chunk
        toks = np.asarray(prompt, np.int32)
        keys, prev = [], b"chain:" + self._salt
        for k in range(len(toks) // c):
            h = hashlib.sha256()
            h.update(prev)
            h.update(toks[k * c:(k + 1) * c].tobytes())
            prev = h.digest()
            keys.append(prev)
        return keys

    # -- lookup / insert ----------------------------------------------------

    def _touch(self, ent: Entry) -> Entry:
        self._entries.move_to_end(ent.key)
        return ent

    def lookup(self, req) -> Lookup:
        """Longest cached prefix for `req`, counting hit/miss and marking
        every returned entry recently-used.  A terminal hit covers the
        whole prompt (and carries tok0); otherwise the chain is walked
        until the first uncached chunk."""
        ent = self._entries.get(self._terminal_key(req))
        if ent is not None:
            self.hits += 1
            return Lookup(self._touch(ent), [], req.prompt_len)
        chain: List[Entry] = []
        for key in self.chain_keys(req.prompt, req=req):
            ce = self._entries.get(key)
            if ce is None:
                break
            chain.append(self._touch(ce))
        if chain:
            self.hits += 1
        else:
            self.misses += 1
        return Lookup(None, chain, len(chain) * (self.chunk or 0))

    def peek_cached_tokens(self, req) -> int:
        """Like lookup().cached_tokens but WITHOUT touching counters or
        LRU order -- for admission token budgeting."""
        if self._terminal_key(req) in self._entries:
            return req.prompt_len
        n = 0
        for key in self.chain_keys(req.prompt, req=req):
            if key not in self._entries:
                break
            n += 1
        return n * (self.chunk or 0)

    def _insert(self, ent: Entry) -> None:
        if ent.key in self._entries:
            self._touch(self._entries[ent.key])
            return
        self._entries[ent.key] = ent
        self.insertions += 1
        self._evict_over_capacity()

    def note_skip(self, n: int) -> None:
        """Engine callback: `n` prompt tokens' prefill work was actually
        skipped (a terminal hit skips the whole prompt; a chain hit skips
        resume-point * chunk tokens -- the engine knows the resume point,
        lookup doesn't)."""
        self.tokens_skipped += int(n)

    def insert_terminal(self, req, pages: list, tok0: int) -> None:
        self._insert(Entry(self._terminal_key(req), pages, "terminal",
                           tok0=int(tok0)))

    def insert_chain(self, key: bytes, pages: list) -> None:
        if self.chain_ok:
            self._insert(Entry(key, pages, "chain"))

    def _evict_over_capacity(self) -> None:
        """LRU-by-refcount: evict least-recently-used UNPINNED entries
        until within capacity; pinned entries (refs > 0 -- a live slot
        was admitted from them) are never evicted, so the pool may
        transiently exceed max_pages under heavy pinning."""
        while len(self._entries) > self.max_pages:
            victim = next((e for e in self._entries.values()
                           if e.refs == 0), None)
            if victim is None:
                return
            del self._entries[victim.key]
            self.evicted += 1

    # -- pinning ------------------------------------------------------------

    def pin(self, keys) -> tuple:
        """Refcount the entries a slot was admitted from; returns the keys
        actually pinned (for the engine's per-slot release list)."""
        pinned = []
        for key in keys:
            ent = self._entries.get(key)
            if ent is not None:
                ent.refs += 1
                pinned.append(key)
        return tuple(pinned)

    def release(self, keys) -> None:
        for key in keys:
            ent = self._entries.get(key)
            if ent is not None and ent.refs > 0:
                ent.refs -= 1
        self._evict_over_capacity()

    # -- elastic mesh bookkeeping -------------------------------------------

    def note_remesh(self, mesh_key) -> None:
        """Record a mesh (re-)plan.  Pages are host-resident numpy and so
        mesh-free -- nothing to invalidate; they re-enter device state
        through the CURRENT plan's PartitionSpecs on the next write-back.
        The fingerprint is kept for observability: info() shows which
        mesh the pool is currently serving."""
        if self.mesh_key is not None and mesh_key != self.mesh_key:
            self.remeshes += 1
        self.mesh_key = mesh_key

    # -- observability ------------------------------------------------------

    def info(self) -> dict:
        looked = self.hits + self.misses
        return {
            "max_pages": self.max_pages,
            "chunk": self.chunk,
            "chain_ok": self.chain_ok,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / looked) if looked else 0.0,
            "tokens_skipped": self.tokens_skipped,
            "pages_resident": len(self._entries),
            "pages_evicted": self.evicted,
            "pages_pinned": sum(1 for e in self._entries.values()
                                if e.refs > 0),
            "insertions": self.insertions,
            "remeshes": self.remeshes,
            "mesh_fingerprint": None if self.mesh_key is None
            else hashlib.sha256(repr(self.mesh_key).encode()).hexdigest()[:12],
        }
