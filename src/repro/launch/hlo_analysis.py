"""Static analyzer over optimized (post-SPMD-partitioning) HLO text.

Why not `compiled.cost_analysis()`: XLA's analysis visits each `while` body
ONCE -- scan-over-layers models (all of ours) would be undercounted by a
factor of n_layers.  This analyzer:

* parses every computation in the HLO module,
* per computation sums
    - dot FLOPs (2 * |output| * contracted-dim size),
    - collective bytes (all-gather / all-reduce / reduce-scatter /
      all-to-all / collective-permute: max(operand, result) bytes),
    - an HBM-traffic proxy (operand+result bytes of dots, fusions,
      gathers/scatters, collectives and plain copies -- elementwise
      instructions inside fusions are excluded by construction),
* resolves the call graph: `call`/`fusion` add the callee once; `while`
  multiplies the body+condition by the trip count recovered from the loop
  condition's comparison constant (scan lengths are static), `conditional`
  takes the max branch.

All quantities are PER DEVICE (the HLO is the per-partition program), so
    compute_term    = dot_flops / peak_flops_per_chip
    memory_term     = hbm_bytes / hbm_bw_per_chip
    collective_term = coll_bytes / ici_bw_per_chip
need no further division by chip count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_COLL_RE = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start|-done)?\(")
_DOT_RE = re.compile(r"= [a-z0-9]+\[[0-9,]*\][^=]* dot\(")
_CALLEE_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r" while\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_FUSION_RE = re.compile(r"= [^=]*fusion\(")
_CALL_RE = re.compile(r"= [^=]*\bcall\(")
_CONDITIONAL_RE = re.compile(r" conditional\(")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _all_shapes(line: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(line)]


_DOT_OPERANDS_RE = re.compile(r"\bdot\(([^)]*)\)")


def _elems(dims: str) -> int:
    n = 1
    for d in (dims.split(",") if dims else []):
        n *= int(d)
    return n


def _dot_flops(line: str, shape_env: dict) -> float:
    """2 * |out| * contracted-dim size.  Operand shapes come from the
    operand tokens themselves when the HLO prints them inline
    (`dot(f32[4,32] %a, ...)`, newer XLA) and from the computation-local
    name->shape environment otherwise."""
    shapes = _all_shapes(line)
    if not shapes:
        return 0.0
    out_elems = _elems(shapes[0][1])
    ops = _DOT_OPERANDS_RE.search(line)
    inline, names = [], []
    if ops:
        arg_str = ops.group(1)
        arg_shapes = list(_SHAPE_RE.finditer(arg_str))
        if arg_shapes:
            # newer XLA prints operand shapes inline:
            #   dot(f32[4,32]{1,0} %a, f32[32,32]{1,0} %b)
            inline = [m.group(2) for m in arg_shapes]
        else:
            names = [s.strip().lstrip("%") for s in arg_str.split(",")]
    contract = None
    for side, idx in (("lhs", 0), ("rhs", 1)):
        m = re.search(side + r"_contracting_dims=\{([0-9,]*)\}", line)
        if not (m and m.group(1)):
            continue
        if idx < len(inline):
            dims_str = inline[idx]
        elif idx < len(names):
            dims_str = shape_env.get(names[idx])
        else:
            continue
        if dims_str is None:
            continue
        dims = dims_str.split(",") if dims_str else []
        c = 1
        ok = True
        for i in m.group(1).split(","):
            if int(i) < len(dims):
                c *= int(dims[int(i)])
            else:
                ok = False
        if ok:
            contract = c
            break
    if contract is None:
        contract = 1
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: float = 0.0
    hbm_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)   # (kind, name[, cond])
    max_const: int = 1


def _parse_computations(text: str) -> dict[str, CompStats]:
    comps: dict[str, CompStats] = {}
    cur: Optional[CompStats] = None
    shape_env: dict[str, str] = {}
    comment_re = re.compile(r"/\*.*?\*/")
    inst_re = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
    for raw in text.splitlines():
        line = comment_re.sub("", raw.strip())
        # computation header: "[ENTRY ]%name (params...) -> type {"
        if line.endswith("{") and "->" in line and "=" not in line.split("->")[0]:
            toks = line.split()
            name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 else toks[0]
            name = name.lstrip("%")
            if "(" in name:
                name = name.split("(")[0]
            if name:
                cur = comps.setdefault(name, CompStats())
                shape_env = {}
                continue
        if cur is None or not line or line == "}":
            if line == "}":
                cur = None
            continue
        im = inst_re.match(line)
        if im:
            shape_env[im.group(1)] = im.group(3)
        for m in _CONST_RE.finditer(line):
            v = int(m.group(1))
            if v < (1 << 24):
                cur.max_const = max(cur.max_const, v)
        shapes = _all_shapes(line)
        out_bytes = _shape_bytes(*shapes[0]) if shapes else 0.0
        opnd_bytes = sum(_shape_bytes(dt, dm) for dt, dm in shapes[1:])
        cm = _COLL_RE.search(line)
        if cm:
            b = max(opnd_bytes, out_bytes)
            cur.coll_bytes += b
            cur.coll_by_kind[cm.group(1)] = \
                cur.coll_by_kind.get(cm.group(1), 0.0) + b
            cur.hbm_bytes += out_bytes + opnd_bytes
            continue
        if _WHILE_RE.search(line):
            body = _BODY_RE.search(line)
            cond = _COND_RE.search(line)
            if body:
                cur.calls.append(("while", body.group(1),
                                  cond.group(1) if cond else None))
            continue
        if _CONDITIONAL_RE.search(line):
            bm = _BRANCHES_RE.search(line)
            if bm:
                names = [s.strip().lstrip("%") for s in bm.group(1).split(",")]
                cur.calls.append(("cond", tuple(names), None))
            continue
        if " dot(" in line:
            cur.dot_flops += _dot_flops(line, shape_env)
            cur.hbm_bytes += out_bytes + opnd_bytes
            callee = _CALLEE_RE.search(line)
            continue
        if _FUSION_RE.search(line) or _CALL_RE.search(line):
            callee = _CALLEE_RE.search(line)
            if callee:
                cur.calls.append(("call", callee.group(1), None))
            cur.hbm_bytes += out_bytes + opnd_bytes
            continue
        if any(op in line for op in (" copy(", " gather(", " scatter(",
                                     " dynamic-slice(", " dynamic-update-slice(",
                                     " sort(", " convolution(")):
            cur.hbm_bytes += out_bytes + opnd_bytes
            if " convolution(" in line:
                cur.dot_flops += 2 * out_bytes  # rough; convs are rare here
            callee = _CALLEE_RE.search(line)
            if callee:
                cur.calls.append(("call", callee.group(1), None))
    return comps


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    coll_bytes: float
    hbm_bytes: float
    coll_by_kind: dict
    n_while: int
    trip_counts: list


def analyze_hlo(text: str, entry: Optional[str] = None) -> HloCosts:
    comps = _parse_computations(text)
    if not comps:
        return HloCosts(0, 0, 0, {}, 0, [])
    memo: dict[str, tuple] = {}
    trip_counts: list[int] = []
    n_while = 0

    def trip_of(cond_name: Optional[str]) -> int:
        if cond_name and cond_name in comps:
            return max(1, comps[cond_name].max_const)
        return 1

    def total(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0.0, {})
        c = comps[name]
        f, cb, hb = c.dot_flops, c.coll_bytes, c.hbm_bytes
        kinds = dict(c.coll_by_kind)
        for kind, callee, cond in c.calls:
            if kind == "while":
                nonlocal_trip = trip_of(cond)
                sf, scb, shb, sk = total(callee, stack + (name,))
                f += sf * nonlocal_trip
                cb += scb * nonlocal_trip
                hb += shb * nonlocal_trip
                for k, v in sk.items():
                    kinds[k] = kinds.get(k, 0) + v * nonlocal_trip
            elif kind == "cond":
                best = (0.0, 0.0, 0.0, {})
                for b in callee:
                    cand = total(b, stack + (name,))
                    if cand[0] + cand[2] > best[0] + best[2]:
                        best = cand
                f += best[0]
                cb += best[1]
                hb += best[2]
                for k, v in best[3].items():
                    kinds[k] = kinds.get(k, 0) + v
            else:
                sf, scb, shb, sk = total(callee, stack + (name,))
                f += sf
                cb += scb
                hb += shb
                for k, v in sk.items():
                    kinds[k] = kinds.get(k, 0) + v
        memo[name] = (f, cb, hb, kinds)
        return memo[name]

    # entry: computation named like the module entry; fall back to the one
    # not called by anyone
    called = {callee for c in comps.values() for kind, callee, _ in c.calls
              if kind != "cond"}
    for c in comps.values():
        for kind, callee, cond in c.calls:
            if kind == "while":
                n_while += 1
                trip_counts.append(trip_of(cond))
                called.add(cond)
            if kind == "cond":
                called.update(callee)
    roots = [n for n in comps if n not in called]
    if entry and entry in comps:
        roots = [entry]
    ftot = cbtot = hbtot = 0.0
    ktot: dict = {}
    for r in roots:
        f, cb, hb, kk = total(r)
        ftot += f
        cbtot += cb
        hbtot += hb
        for k, v in kk.items():
            ktot[k] = ktot.get(k, 0) + v
    return HloCosts(ftot, cbtot, hbtot, ktot, n_while, trip_counts)
