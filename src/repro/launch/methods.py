"""Servable methods: request builders + result decoding for the front-end.

The engine serves three request *methods* (scheduler.Request.method), all
through the same compiled decode family:

* ``generate`` -- greedy decode of up to max_new_tokens (streaming or
  batch; the tokens are identical either way, tests/test_frontend.py).
* ``score``    -- per-token logprobs of a FIXED completion under the
  prompt.  The engine teacher-forces the completion through the same
  single-token chunk dispatches recovery replay uses (engine._drain_replay),
  so the logits row each scored token is conditioned on is bitwise the row
  greedy decode would have produced at that position -- scoring is exact
  by construction, not by tolerance.
* ``embed``    -- one pooled vector per request: final-hidden-state masked
  mean over the prompt (lm.embed_pool), a single prefill-shaped dispatch
  that consumes NO decode slot.

``logprob_from_logits`` is THE canonical logits-row -> logprob map: the
engine scores with it and tests recompute references with it, so
score-vs-decode parity is a statement about logits BITS (covered by the
engine's exactness invariants), never about a tolerance on the host math.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.launch import resilience as res
from repro.launch import scheduler

METHODS = ("generate", "score", "embed")


def logprob_from_logits(row, token: int) -> float:
    """log softmax(row)[token] in float32 on the host (max-shifted, one
    np.sum).  Deterministic: bitwise-identical rows give bitwise-identical
    logprobs, which is what lets score parity tests demand exact floats."""
    row = np.asarray(row, np.float32)
    m = row.max()
    z = np.log(np.sum(np.exp(row - m), dtype=np.float32))
    return float(row[int(token)] - m - z)


# -- request builders -------------------------------------------------------

def generate_request(rid: int, prompt, max_new_tokens: int, *,
                     arrival_time: float = 0.0,
                     stop_tokens: Optional[Sequence[int]] = None,
                     features=None,
                     deadline: Optional[float] = None,
                     sampling: Optional[scheduler.SamplingParams] = None,
                     ) -> scheduler.Request:
    """`sampling` carries the per-request policy (temperature / top-k /
    top-p / seed, launch/sampling.py); None (the default) is greedy and
    bit-identical to the pre-sampling engine."""
    return scheduler.Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=int(max_new_tokens),
                             arrival_time=arrival_time,
                             stop_tokens=stop_tokens, features=features,
                             deadline=deadline, sampling=sampling)


def score_request(rid: int, prompt, completion: Sequence[int], *,
                  arrival_time: float = 0.0, features=None,
                  deadline: Optional[float] = None) -> scheduler.Request:
    """Score `completion` under `prompt`; the result's ``logprobs[i]`` is
    the logprob of completion[i] given prompt + completion[:i].
    max_new_tokens is unused by scoring (the completion bounds the work)
    but the Request invariant wants >= 1."""
    return scheduler.Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=1, arrival_time=arrival_time,
                             features=features, deadline=deadline,
                             method="score",
                             score_tokens=tuple(int(t) for t in completion))


def embed_request(rid: int, prompt, *, arrival_time: float = 0.0,
                  features=None,
                  deadline: Optional[float] = None) -> scheduler.Request:
    return scheduler.Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                             max_new_tokens=1, arrival_time=arrival_time,
                             features=features, deadline=deadline,
                             method="embed")


# -- result decoding --------------------------------------------------------

def _check_ok(result: res.RequestResult, want: str) -> None:
    if result.outcome != res.OK:
        raise RuntimeError(
            f"request {result.rid}: {want} unavailable, outcome "
            f"{result.outcome!r} ({result.error})")


def completion_logprobs(result: res.RequestResult) -> list:
    """The per-token logprobs of a finished score request."""
    _check_ok(result, "logprobs")
    if result.logprobs is None:
        raise RuntimeError(f"request {result.rid}: no logprobs recorded "
                           f"(not a score request?)")
    return list(result.logprobs)


def embedding(result: res.RequestResult) -> np.ndarray:
    """The pooled embedding of a finished embed request."""
    _check_ok(result, "embedding")
    if result.embedding is None:
        raise RuntimeError(f"request {result.rid}: no embedding recorded "
                           f"(not an embed request?)")
    return np.asarray(result.embedding, np.float32)
