"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before first init.

Axes:
  single-pod: ("data", "model")        = (16, 16)   -> 256 chips
  multi-pod:  ("pod", "data", "model") = (2, 16, 16) -> 512 chips

`fsdp_axes(mesh)` returns the axis names parameters are fully-sharded over
(the "pod" axis joins data-parallel sharding in the multi-pod mesh).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic restore onto different topology."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    """Axis names carrying the batch dimension."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def fsdp_axes(mesh) -> tuple:
    """Axis names parameters are fully sharded over (ZeRO-3 style)."""
    return dp_axes(mesh)
