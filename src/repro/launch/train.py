"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/run1 \
        --ckpt-every 50 [--simulate-failures 120,220] [--mesh 1x1]

Responsibilities beyond the bare train loop, per the large-scale brief:

* checkpoint/restart: periodic atomic checkpoints; on ANY failure the
  driver restores the latest committed step and resumes (the data pipeline
  is a pure function of step, so the token stream replays exactly);
* straggler detection: per-host step-time tracking (simulated hosts on
  CPU), flags logged;
* elastic restart: if the mesh shape changed between runs, params/opt are
  re-sharded onto the new mesh at restore time;
* SILVIA serving flows live in launch/serve.py; training is bf16.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, configs
from repro.data import DataConfig, make_stream
from repro.distributed.fault import (FailureInjector, RestartPolicy,
                                     SimulatedFailure, StragglerDetector)
from repro.distributed.sharding import param_pspecs, to_shardings
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init
from repro.training import TrainConfig, make_train_step


def build(cfg, tcfg, mesh, seq, rng):
    params = lm.init_params(rng, cfg, max_seq=seq + 8)
    params = jax.device_put(params,
                            to_shardings(param_pspecs(params, mesh, cfg), mesh))
    opt = adamw_init(params, tcfg.optimizer)
    opt = jax.device_put(opt, to_shardings(param_pspecs(opt, mesh, cfg), mesh))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    return params, opt, step_fn


def run(args) -> dict:
    cfg = configs.get_reduced_config(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("data", "model")[:len(mesh_shape)] if len(mesh_shape) <= 2 else \
        ("pod", "data", "model")
    mesh = make_mesh(mesh_shape, axes)
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        optimizer=AdamWConfig(lr=args.lr),
        schedule_warmup=min(50, args.steps // 10 + 1),
        schedule_total=args.steps)
    rng = jax.random.PRNGKey(args.seed)
    stream = make_stream(DataConfig(args.seq, args.batch, cfg.vocab,
                                    seed=args.seed))
    injector = FailureInjector(tuple(
        int(s) for s in args.simulate_failures.split(",") if s))
    policy = RestartPolicy(max_restarts=args.max_restarts)
    detector = StragglerDetector(n_hosts=args.sim_hosts)

    history: list[float] = []
    n_restores = 0
    while True:
        try:
            with mesh:
                params, opt, step_fn = build(cfg, tcfg, mesh, args.seq, rng)
                restored, start = checkpoint.restore_checkpoint(
                    args.ckpt_dir, {"params": params, "opt": opt})
                if restored is not None:
                    params, opt = restored["params"], restored["opt"]
                    n_restores += 1
                    print(f"[restore] resumed from step {start}")
                step0 = (start or 0)
                for step in range(step0, args.steps):
                    t0 = time.time()
                    injector.check(step)
                    batch = {"tokens": jnp.asarray(stream.batch_at(step))}
                    params, opt, metrics = step_fn(params, opt, batch)
                    dt = time.time() - t0
                    detector.report(step, step % args.sim_hosts, dt)
                    if step % args.log_every == 0:
                        loss = float(metrics["loss"])
                        history.append(loss)
                        strag = detector.stragglers(step)
                        print(f"step {step:5d} loss {loss:.4f} "
                              f"({dt*1e3:.0f} ms)"
                              + (f" stragglers={strag}" if strag else ""))
                    if args.ckpt_every and step and \
                            step % args.ckpt_every == 0:
                        checkpoint.save_checkpoint(
                            args.ckpt_dir, step,
                            {"params": params, "opt": opt})
                        # committed progress: next incident backs off from
                        # the base again instead of the escalated streak
                        policy.reset()
                if args.ckpt_every:
                    checkpoint.save_checkpoint(
                        args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt})
                final = float(metrics["loss"])
                print(f"done: final loss {final:.4f}, "
                      f"restores={n_restores}, "
                      f"straggler flags={len(detector.flagged)}")
                return {"final_loss": final, "restores": n_restores,
                        "history": history}
        except SimulatedFailure as e:
            print(f"[failure] {e}")
            if not policy.should_restart(e):
                raise
            continue


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-failures", default="")
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("--sim-hosts", type=int, default=4)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
